"""Dependency-free checkpointing: flattened pytree -> .npz (+ manifest).

Arrays are gathered to host (fine at the scales this CPU container trains);
on a real cluster the same path writes per-process shards — the manifest
records the tree structure and is identical either way.
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> Tuple[dict, Any]:
    leaves = {}

    def visit(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        leaves[name] = np.asarray(leaf)
        return None

    jax.tree_util.tree_map_with_path(visit, tree)
    treedef = jax.tree.structure(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten_with_names(tree)
    np.savez(os.path.join(path, "arrays.npz"), **leaves)
    manifest = {
        "step": step,
        "keys": sorted(leaves),
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (names must match)."""
    data = np.load(os.path.join(path, "arrays.npz"))

    def fetch(p, leaf):
        name = "/".join(str(getattr(q, "key", getattr(q, "name", q))) for q in p)
        arr = data[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        return arr

    restored = jax.tree_util.tree_map_with_path(fetch, like)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return restored, manifest["step"]
