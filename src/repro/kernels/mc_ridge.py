"""Pallas kernel for the Monte-Carlo pipelined-SGD ridge simulation.

One call advances EVERY simulation lane (one lane per scenario x rate x
grid point, as laid out by the fleet Monte-Carlo solve in
:mod:`repro.fleet.objective_kernels`) through one SLAB of update slots.
The host precomputes, per slab, the two (slab, L) tables the timeline
fully determines — the sampled training-row index ``ix`` and the
update-live mask ``m`` — so the kernel body is pure f32 training math
with no RNG and no f64: the f64 timeline / f32 training split stays on
the host side of the call.

Layout: lanes-LAST.  The weight block is ``(d, block_l) = (8, 128)`` —
exactly one float32 TPU tile — and every per-lane scalar is a
``(1, block_l)`` row, so all elementwise work is lane-aligned.  The
training-row gather runs as a one-hot matmul on the MXU
(``Xs^T @ onehot``): for a 0/1 f32 one-hot this is BITWISE equal to the
``Xs[ix]`` gather (each output element is one exact product plus exact
zeros), which is what lets interpret-mode tests pin the kernel against
the ``lax.scan`` reference bit-for-bit.

Grid: one program per 128-lane block; lanes are padded to a block
multiple with ``m = 0`` rows (a dead lane's weights pass through both
update forms unchanged).

``fused=True`` applies the update in the algebraically-rearranged
affine form ``W <- c1 * W + c2 * xr`` used by the common-random-numbers
engine; ``fused=False`` replicates
:func:`repro.core.pipeline.ridge_grad_sample`'s op order exactly
(gradient, step, ``where``-mask), matching the exact-RNG scan engine.

``interpret=True`` (the CPU path; also CI) evaluates the kernel with
the Pallas interpreter and switches the lane dot to ``jnp.einsum`` —
bitwise-identical to the reference's vmapped ``jnp.dot`` — while the
compiled TPU path keeps the Mosaic-friendly multiply-reduce form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _mc_ridge_kernel(xs_ref, ys_ref, ix_ref, m_ref, w_ref, o_ref, *,
                     slab: int, n: int, alpha: float, lam: float,
                     fused: bool, mosaic_dot: bool):
    Xs = xs_ref[...]                                   # (d, n) f32
    ys = ys_ref[...]                                   # (1, n) f32
    c_reg = np.float32(2.0 * alpha * lam / n)
    c_2a = np.float32(-2.0 * alpha)

    def lane_dot(W, xr):
        if mosaic_dot:  # lane-aligned multiply-reduce (compiled TPU path)
            return jnp.sum(W * xr, axis=0, keepdims=True)
        # interpret path: bitwise == the reference's vmapped jnp.dot
        return jnp.einsum("dl,dl->l", W, xr)[None, :]

    def body(j, W):
        bl = W.shape[1]
        ixr = pl.load(ix_ref, (pl.ds(j, 1), slice(None)))   # (1, bl) i32
        mr = pl.load(m_ref, (pl.ds(j, 1), slice(None)))     # (1, bl) f32
        iota = jax.lax.broadcasted_iota(jnp.int32, (n, bl), 0)
        oh = (iota == ixr).astype(jnp.float32)              # (n, bl)
        xr = jnp.dot(Xs, oh, preferred_element_type=jnp.float32)  # (d, bl)
        yr = jnp.dot(ys, oh, preferred_element_type=jnp.float32)  # (1, bl)
        dot = lane_dot(W, xr)
        if fused:
            c1 = 1.0 - mr * c_reg
            c2 = mr * c_2a * (dot - yr)
            return W * c1 + xr * c2
        g = 2.0 * (dot - yr) * xr + 2.0 * lam / n * W
        return jnp.where(mr > 0.0, W - alpha * g, W)

    o_ref[...] = jax.lax.fori_loop(0, slab, body, w_ref[...])


@functools.partial(jax.jit, static_argnames=("alpha", "lam", "fused",
                                             "interpret", "block_l"))
def mc_ridge_slab(W, Xs, ys, ix, m, *, alpha: float, lam: float,
                  fused: bool, interpret: bool = False,
                  block_l: int = 128):
    """Advance all lanes through one slab of update slots.

    ``W``: (L, d) f32 per-lane weights; ``Xs``: (n, d) f32 permuted
    training rows; ``ys``: (n,) f32 targets; ``ix``: (slab, L) int32
    sampled row per (slot, lane); ``m``: (slab, L) f32, 1.0 where the
    lane updates at that slot.  Returns the updated (L, d) weights.
    """
    L, d = W.shape
    n = Xs.shape[0]
    slab = ix.shape[0]
    pad = (-L) % block_l
    Wt = W.T                                           # (d, L) lanes-last
    if pad:
        Wt = jnp.pad(Wt, ((0, 0), (0, pad)))
        ix = jnp.pad(ix, ((0, 0), (0, pad)))
        m = jnp.pad(m, ((0, 0), (0, pad)))             # dead lanes: m = 0
    lp = L + pad

    kernel = functools.partial(
        _mc_ridge_kernel, slab=slab, n=n, alpha=float(alpha),
        lam=float(lam), fused=fused, mosaic_dot=not interpret)
    out = pl.pallas_call(
        kernel,
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((slab, block_l), lambda i: (0, i)),
            pl.BlockSpec((slab, block_l), lambda i: (0, i)),
            pl.BlockSpec((d, block_l), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((d, block_l), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((d, lp), jnp.float32),
        interpret=interpret,
    )(Xs.T.astype(jnp.float32), ys[None, :].astype(jnp.float32),
      ix.astype(jnp.int32), m.astype(jnp.float32), Wt)
    return out[:, :L].T
