"""Jit'd wrappers exposing the Pallas kernels in model-layout form.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python for correctness validation; on TPU the same code
compiles to Mosaic.  ``interpret`` defaults to the current backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, q_block=512, kv_block=512, interpret=None):
    """Model layout: q (B, S, H, D); k, v (B, S, Hkv, D) -> (B, S, H, D)."""
    interpret = _default_interpret() if interpret is None else interpret
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window, softcap=softcap, scale=scale,
        q_block=q_block, kv_block=kv_block, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def ssd_scan(x, dt, a, b, c, *, chunk=256, interpret=None):
    """Model layout: x (B, L, H, P); b, c (B, L, G, N) (groups broadcast)."""
    interpret = _default_interpret() if interpret is None else interpret
    h = x.shape[2]
    g = b.shape[2]
    if g != h:
        b = jnp.repeat(b, h // g, axis=2)
        c = jnp.repeat(c, h // g, axis=2)
    y, state = ssd_scan_fwd(x, dt, a, b, c, chunk=chunk, interpret=interpret)
    return y.astype(x.dtype), state
