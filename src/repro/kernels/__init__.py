# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# mc_ridge: Pallas slab kernel for the fleet Monte-Carlo ridge-SGD
# simulation (the `mc_impl="pallas"` engine of the montecarlo solve).
from repro.kernels.mc_ridge import mc_ridge_slab  # noqa: F401
