"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, num_chunks) — the chunk dimension is innermost and
sequential, so the inter-chunk SSM state (head_dim x state_dim) is carried
in VMEM scratch across chunk steps.  Each step computes the within-chunk
quadratic (attention-like) term on the MXU plus the state contribution, and
updates the running state — one HBM pass over x/B/C/dt.

VMEM working set per step (chunk=256, P=64, N=128, f32):
  x (256x64) + B,C (2x256x128) + M (256x256) + state (64x128) ~ 0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
                *, chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (chunk, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (chunk,)
    a = a_ref[0].astype(jnp.float32)              # () decay rate (negative)
    bm = b_ref[0, :, 0].astype(jnp.float32)       # (chunk, N)
    cm = c_ref[0, :, 0].astype(jnp.float32)       # (chunk, N)

    da = dt * a                                   # (chunk,) log-decay per step
    cum = jnp.cumsum(da)                          # inclusive
    total = cum[-1]
    xbar = x * dt[:, None]

    # intra-chunk: M[t, s] = exp(cum_t - cum_s) * (C_t . B_s) for s <= t
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = row >= col
    decay = cum[:, None] - cum[None, :]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))   # (chunk, chunk)
    m = jnp.where(causal, cb * jnp.exp(decay), 0.0)
    y_intra = jax.lax.dot(m, xbar)                                # (chunk, P)

    # inter-chunk: y_inter[t] = exp(cum_t) * C_t . state^T
    state = state_scr[...]                                        # (P, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())))                      # (chunk, P)

    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(total) * S + sum_s exp(total - cum_s) B_s (x) xbar_s
    w = jnp.exp(total - cum)                                      # (chunk,)
    state_new = jnp.exp(total) * state + jax.lax.dot_general(
        xbar, w[:, None] * bm, (((0,), (0,)), ((), ())))          # (P, N)
    state_scr[...] = state_new

    @pl.when(ic == num_chunks - 1)
    def _final():
        st_ref[0, 0] = state_new.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(x, dt, a, b, c, *, chunk: int = 256, interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, H, N)
    (groups pre-broadcast to heads) -> y: (B, L, H, P), state: (B, H, P, N).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ic: (b_, ic, h_)),
            pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ic: (b_, ic, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, state
