"""Pallas TPU flash-attention forward kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
innermost and sequential on TPU, so the running-softmax statistics live in
VMEM scratch across kv steps.  Whole kv blocks strictly in the causal future
are skipped with ``pl.when`` (the FLOPs the pure-XLA blockwise path cannot
elide).  GQA is handled by the k/v index_map (q head -> kv head = h // group).

BlockSpec VMEM tiling: q tile (q_block, head_dim), k/v tiles
(kv_block, head_dim); defaults 512x128 keep the working set
(2*512*128 + 2*512*128 + 512*512) * 4B  ~ 2.1 MB well under the ~16 MB VMEM
budget of a TPU v5e core while keeping the MXU contraction dims at 128+.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, q_block: int, kv_block: int,
                      causal: bool, window, softcap, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * q_block
    k_start = ik * kv_block

    # whole-block skip: block fully in the causal future, or fully outside
    # the sliding window
    run = jnp.asarray(True)
    if causal:
        run &= k_start <= q_start + q_block - 1
    if window is not None:
        # newest query is q_start + q_block - 1; oldest useful key is
        # q_newest - window + 1; skip blocks entirely older than that
        run &= k_start + kv_block - 1 >= q_start - window + 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (qb, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (kb, d)
        v = v_ref[0, 0].astype(jnp.float32)             # (kb, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qb, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (qb, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                           # (qb, kb)
        corr = jnp.exp(m_prev - m_new)                   # (qb, 1)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_block",
                     "kv_block", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        softcap=None, scale=None, q_block: int = 512,
                        kv_block: int = 512, interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = float(scale) if scale is not None else d ** -0.5
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    nq, nk = s // q_block, s // kv_block

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, q_block=q_block, kv_block=kv_block,
        causal=causal, window=window, softcap=softcap, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
