"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D).  Naive softmax attention."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def mc_ridge_ref(W, Xs, ys, ix, m, *, alpha, lam, fused):
    """Sequential numpy oracle of one :func:`mc_ridge_slab` call.

    W: (L, d); Xs: (n, d); ys: (n,); ix: (slab, L) int; m: (slab, L)
    update mask.  Float32 throughout (tolerance oracle — the bitwise
    checks run pallas-interpret against the ``lax.scan`` engine).
    """
    import numpy as np

    W = np.array(W, np.float32)
    Xs = np.asarray(Xs, np.float32)
    ys = np.asarray(ys, np.float32)
    n = Xs.shape[0]
    c_reg = np.float32(2.0 * alpha * lam / n)
    for j in range(ix.shape[0]):
        xr = Xs[ix[j]]                                  # (L, d)
        yr = ys[ix[j]]
        mr = np.asarray(m[j], np.float32)
        dot = np.sum(W * xr, axis=1)
        if fused:
            c1 = 1.0 - mr * c_reg
            c2 = mr * np.float32(-2.0 * alpha) * (dot - yr)
            W = W * c1[:, None] + xr * c2[:, None]
        else:
            g = 2.0 * (dot - yr)[:, None] * xr + 2.0 * lam / n * W
            W = np.where((mr > 0)[:, None], W - alpha * g, W)
    return W


def ssd_scan_ref(x, dt, a, b, c):
    """Sequential SSM recurrence (oracle for the SSD kernel).

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, H, N).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])                     # (B, H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", bt, xt * dtt[..., None])
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (x, dt, b, c))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final
