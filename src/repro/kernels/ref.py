"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D).  Naive softmax attention."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c):
    """Sequential SSM recurrence (oracle for the SSD kernel).

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, H, N).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])                     # (B, H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", bt, xt * dtt[..., None])
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (x, dt, b, c))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final
