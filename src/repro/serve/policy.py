"""Pluggable admission policies: which objective/grid-mode per device class.

The serving-side mirror of the link registry (:mod:`repro.core.links`)
and the objective registry (:mod:`repro.core.objectives`): an admission
policy looks at an incoming scenario plus the service's load signal and
decides HOW it should be planned — which registered objective and which
grid mode.  Policies register under a stable string ``policy_id`` via
:func:`register_policy`; the service resolves a policy by id, and a
plugin registered at runtime is immediately selectable (no service code
changes), exactly like a plugged link model or objective.

A policy must expose::

    policy_id: str                                   # registry id
    def admit(scenario, *, load: float) -> AdmissionDecision

``load`` is the service's current queue depth over its flush batch size
(0.0 = idle, >= 1.0 = at least one full micro-batch is already waiting).

Built-ins:

  * ``static`` — one fixed (objective, grid mode) for every request;
  * ``link_aware`` — the serving policy the ROADMAP sketches: exact
    burst-aware ``markov_arq`` planning for STICKY Gilbert-Elliott links
    (burst structure the stationary bound mis-prices), refined
    ``corollary1`` under load (the coarse->fine solve trades a little
    certainty at the basin edges for 2-4x fewer evaluated lanes), dense
    ``corollary1`` otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.core.links import GilbertElliottLink
from repro.core.scenario import Scenario


@dataclass(frozen=True)
class AdmissionDecision:
    """What the policy chose for one request.

    ``action`` is the admission outcome: ``"accept"`` (plan it) or
    ``"shed"`` (reject at the door — the service raises
    ``RequestShed`` to the caller and counts the shed, same outcome as
    a full bounded queue, decided by POLICY instead of capacity).
    """

    objective_id: str
    grid_mode: str
    action: str = "accept"

    def __post_init__(self):
        if self.action not in ("accept", "shed"):
            raise ValueError(
                f"action must be 'accept' or 'shed', got {self.action!r}")

    @property
    def accepted(self) -> bool:
        return self.action == "accept"


@dataclass(frozen=True)
class PolicySpec:
    policy_id: str
    cls: type


_POLICIES: Dict[str, PolicySpec] = {}


def register_policy(cls: type) -> type:
    """Class decorator: register an admission policy under its
    ``policy_id``.  Verifies the interface up front — a policy failing on
    the first request would take the whole ingestion path down."""
    pid = getattr(cls, "policy_id", None)
    if not isinstance(pid, str) or not pid:
        raise TypeError(
            f"{cls.__name__} needs a non-empty string policy_id class var")
    admit = getattr(cls, "admit", None)
    if not callable(admit):
        raise TypeError(f"{cls.__name__} must define "
                        "admit(scenario, *, load) -> AdmissionDecision")
    prior = _POLICIES.get(pid)
    if prior is not None and prior.cls is not cls:
        raise ValueError(
            f"policy_id {pid!r} already registered by {prior.cls.__name__}")
    _POLICIES[pid] = PolicySpec(policy_id=pid, cls=cls)
    return cls


def unregister_policy(policy_id: str) -> None:
    """Remove a policy (plugin teardown / tests).  No-op if absent."""
    _POLICIES.pop(policy_id, None)


def policy_spec(policy_id: str) -> PolicySpec:
    spec = _POLICIES.get(policy_id)
    if spec is None:
        raise KeyError(
            f"unregistered admission policy {policy_id!r}; available: "
            f"{sorted(_POLICIES)}")
    return spec


def registered_policies() -> Tuple[PolicySpec, ...]:
    return tuple(_POLICIES.values())


@register_policy
@dataclass(frozen=True)
class StaticPolicy:
    """Every request gets the same (objective, grid mode)."""

    policy_id = "static"

    objective_id: str = "corollary1"
    grid_mode: str = "dense"

    def admit(self, scenario: Scenario, *, load: float) -> AdmissionDecision:
        del scenario, load
        return AdmissionDecision(self.objective_id, self.grid_mode)


@register_policy
@dataclass(frozen=True)
class LinkAwarePolicy:
    """Route by channel physics and backpressure.

    A Gilbert-Elliott link whose states actually differ
    (``p_good != p_bad``) and whose chain is STICKY — second eigenvalue
    ``1 - p_gb - p_bg`` at least ``sticky_persistence``, i.e. state
    memory long enough that failures cluster — is planned with the exact
    burst-aware ``markov_arq`` objective (same kernel cost as the bound;
    the stationary approximation under-prices exactly these chains).
    Everything else gets ``corollary1``; when the queue backs up past
    ``load_threshold`` flush batches, the coarse->fine ``refine`` mode
    cuts the evaluated lanes per plan, otherwise ``dense`` keeps the
    reference semantics.
    """

    policy_id = "link_aware"

    sticky_persistence: float = 0.2
    load_threshold: float = 1.0
    burst_objective_id: str = "markov_arq"
    default_objective_id: str = "corollary1"

    def admit(self, scenario: Scenario, *, load: float) -> AdmissionDecision:
        link = scenario.link
        objective_id = self.default_objective_id
        if isinstance(link, GilbertElliottLink) \
                and link.p_good != link.p_bad \
                and 1.0 - link.p_gb - link.p_bg >= self.sticky_persistence:
            objective_id = self.burst_objective_id
        mode = "refine" if load >= self.load_threshold else "dense"
        return AdmissionDecision(objective_id, mode)


@register_policy
@dataclass(frozen=True)
class LoadSheddingPolicy:
    """Wrap another registered policy with an overload circuit: once
    the load signal (queued flush batches) reaches ``shed_load``, new
    requests are SHED at admission instead of queued — the service's
    bounded queue is the hard backstop, this is the polite early
    rejection that keeps the queue's tail latency inside the budget.
    """

    policy_id = "load_shedding"

    shed_load: float = 4.0
    inner_policy_id: str = "link_aware"

    def admit(self, scenario: Scenario, *, load: float) -> AdmissionDecision:
        inner = policy_spec(self.inner_policy_id).cls().admit(
            scenario, load=load)
        if load >= self.shed_load:
            return AdmissionDecision(inner.objective_id, inner.grid_mode,
                                     action="shed")
        return inner
