"""Serving statistics: latency histograms, throughput, counters.

The service's counter sink.  :class:`StatsRecorder` is the mutable,
lock-protected accumulator the worker threads write into;
:meth:`StatsRecorder.snapshot` freezes it into a :class:`ServiceStats`
for reporting.  Latencies are ENQUEUE-TO-PLAN: the clock starts when a
request enters the ingestion queue and stops when its plan record is
resolved, so queueing delay, micro-batch formation wait, cache lookup
and the jitted solve are all inside the measured number — the figure an
SLO is actually stated against, not the solve time alone.

Latency distributions live in log-spaced MERGEABLE histograms
(:class:`repro.obs.hist.LogHistogram`) rather than a raw-sample
reservoir: one global histogram plus one per ``(objective, grid_mode,
bucket)`` key, so the per-key distributions roll up into the global one
by addition and the Prometheus export can ship both.  Percentiles are
bucket-interpolated (relative error bounded by the bucket width, ~2.3%
at the 100/decade resolution used here); ``latency_max_ms`` stays exact.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.obs.hist import LogHistogram, percentiles  # noqa: F401
# ``percentiles`` is re-exported: it moved to repro.obs.hist, and
# callers (plan_server, benches) import it from here.

BucketKey = Tuple[str, str, int]

#: Histogram layout for enqueue-to-plan latencies: 10 µs .. 100 s at 100
#: buckets/decade — ±2.3% relative percentile error, 702 counters.
_LAT_LO, _LAT_HI, _LAT_PER_DECADE = 1e-5, 1e2, 100


def _new_hist() -> LogHistogram:
    return LogHistogram(_LAT_LO, _LAT_HI, _LAT_PER_DECADE)


@dataclass(frozen=True)
class ServiceStats:
    """Immutable snapshot of a running (or drained) planning service."""

    n_requests: int            # accepted into the queue
    n_planned: int             # futures resolved with a plan
    n_batches: int             # micro-batches flushed
    queue_depth: int           # requests waiting at snapshot time
    uptime_s: float            # since the recorder (re)started its clock
    plans_per_sec: float       # plans resolved since the clock (re)start
    latency_p50_ms: float      # enqueue-to-plan percentiles
    latency_p99_ms: float
    latency_max_ms: float
    #: per-(objective_id, grid_mode, bucket) request/batch/compile counts
    buckets: Dict[BucketKey, Dict[str, int]] = field(default_factory=dict)
    counters: Dict[str, int] = field(default_factory=dict)
    cache: Dict[str, object] = field(default_factory=dict)
    #: lifetime per-phase durations (seconds) from the span recorder:
    #: batch_wait / pad / cache_lookup / solve / resolve (+ admit,
    #: solve_device, latency, count); empty when spans are off
    phases: Dict[str, float] = field(default_factory=dict)
    #: lifetime solve share of enqueue-to-plan latency (0.0 with no spans)
    solve_fraction: float = 0.0
    #: serialised global latency histogram (LogHistogram.to_dict())
    latency_hist: Dict[str, object] = field(default_factory=dict)
    #: serialised per-(objective, grid_mode, bucket) latency histograms,
    #: keyed "objective/grid_mode/bucket" (JSON-friendly)
    histograms: Dict[str, Dict[str, object]] = field(default_factory=dict)
    #: resilience snapshot (ResilienceManager.snapshot()): fallback
    #: counts by level, retry/backoff totals, breaker states, sheds,
    #: injected-fault counts, health; empty for recorders outside a
    #: service
    resilience: Dict[str, object] = field(default_factory=dict)


class StatsRecorder:
    """Thread-safe accumulator behind :class:`ServiceStats`.

    Keeps one global latency histogram plus one per ``(objective,
    grid_mode, bucket)`` key — bounded memory however long the service
    runs, and the per-key histograms merge into the global by addition
    (asserted by the histogram property tests).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._hist_all = _new_hist()
        self._hist_by_key: Dict[BucketKey, LogHistogram] = {}
        self._counters: Dict[str, int] = {}
        self._buckets: Dict[BucketKey, Dict[str, int]] = {}
        self._t0 = time.perf_counter()
        # counter values at the last clock restart: throughput reports
        # work done SINCE the restart, not lifetime work over a short
        # post-restart window
        self._baseline: Dict[str, int] = {}

    def restart_clock(self) -> None:
        """Reset the throughput clock (called after warmup so reported
        plans/sec describes steady-state serving, not compilation).
        Snapshots the counters as the new baseline: plans_per_sec divides
        post-restart plans by post-restart uptime — previously the
        counter kept its pre-restart value against the fresh clock,
        inflating throughput right after warmup."""
        with self._lock:
            self._t0 = time.perf_counter()
            self._baseline = dict(self._counters)

    def count(self, name: str, k: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + k

    def record_latency(self, seconds: float,
                       key: Optional[BucketKey] = None) -> None:
        """Record one enqueue-to-plan latency into the global histogram
        and, when ``key`` is given, the per-(objective, grid_mode,
        bucket) histogram."""
        with self._lock:
            self._hist_all.record(seconds)
            if key is not None:
                h = self._hist_by_key.get(key)
                if h is None:
                    h = self._hist_by_key[key] = _new_hist()
                h.record(seconds)

    def record_bucket(self, objective_id: str, grid_mode: str, bucket: int,
                      *, requests: int = 0, batches: int = 0,
                      compiles: int = 0) -> None:
        """Accumulate per-(objective, mode, bucket) serving counters."""
        key = (objective_id, grid_mode, int(bucket))
        with self._lock:
            slot = self._buckets.setdefault(
                key, {"requests": 0, "batches": 0, "compiles": 0})
            slot["requests"] += requests
            slot["batches"] += batches
            slot["compiles"] += compiles

    def latency_histograms(self) -> Dict[Optional[BucketKey], LogHistogram]:
        """Copies of the live histograms: ``None`` maps to the global,
        tuple keys to the per-(objective, grid_mode, bucket) ones."""
        with self._lock:
            out: Dict[Optional[BucketKey], LogHistogram] = {
                None: self._hist_all.copy()}
            for k, h in self._hist_by_key.items():
                out[k] = h.copy()
            return out

    def snapshot(self, *, queue_depth: int = 0,
                 cache_stats=None) -> ServiceStats:
        with self._lock:
            uptime = max(time.perf_counter() - self._t0, 1e-9)
            p50 = self._hist_all.percentile(50.0)
            p99 = self._hist_all.percentile(99.0)
            lat_max = self._hist_all.max
            counters = dict(self._counters)
            baseline = dict(self._baseline)
            buckets = {k: dict(v) for k, v in self._buckets.items()}
            lat_hist = self._hist_all.to_dict()
            hists = {"/".join(map(str, k)): h.to_dict()
                     for k, h in self._hist_by_key.items()}
        n_planned = counters.get("planned", 0)
        since_restart = n_planned - baseline.get("planned", 0)
        return ServiceStats(
            n_requests=counters.get("requests", 0),
            n_planned=n_planned,
            n_batches=counters.get("batches", 0),
            queue_depth=queue_depth, uptime_s=uptime,
            plans_per_sec=since_restart / uptime,
            latency_p50_ms=p50 * 1e3, latency_p99_ms=p99 * 1e3,
            latency_max_ms=lat_max * 1e3,
            buckets=buckets, counters=counters,
            cache=dict(cache_stats) if cache_stats else {},
            latency_hist=lat_hist, histograms=hists)


class FederatedRecorder:
    """Thread-safe accumulator for the federated round path.

    Rounds are synchronous population-level requests (one
    ``submit_round`` call = one round), so they get their own counters
    and histograms instead of riding the per-scenario request stats:
    lifetime round / participant / infeasible-round counts, the
    submit-to-record planning latency, and the PLANNED straggler-bounded
    round time — both as mergeable log histograms so the Prometheus
    export ships full distributions (``repro_federated_*`` families, see
    :mod:`repro.serve.export`).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.rounds = 0
        self.participants = 0
        self.infeasible_rounds = 0
        self._latency = _new_hist()
        self._round_time = LogHistogram(1e-2, 1e9, 10)

    def observe(self, record, latency_s: float) -> None:
        """Account one planned round (a :class:`~repro.federated.round.
        RoundRecord`) and its submit-to-record latency."""
        with self._lock:
            self.rounds += 1
            self.participants += int(record.n_participants)
            if not record.feasible:
                self.infeasible_rounds += 1
            else:
                self._round_time.record(float(record.round_time))
            self._latency.record(float(latency_s))

    def snapshot(self) -> Dict[str, object]:
        """Consistent copy of every counter + serialised histograms."""
        with self._lock:
            return {
                "rounds": self.rounds,
                "participants": self.participants,
                "infeasible_rounds": self.infeasible_rounds,
                "latency_hist": self._latency.to_dict(),
                "round_time_hist": self._round_time.to_dict(),
            }
