"""Serving statistics: latency percentiles, throughput, counters.

The service's observability layer.  :class:`StatsRecorder` is the
mutable, lock-protected sink the worker threads write into;
:meth:`StatsRecorder.snapshot` freezes it into a :class:`ServiceStats`
for reporting.  Latencies are ENQUEUE-TO-PLAN: the clock starts when a
request enters the ingestion queue and stops when its plan record is
resolved, so queueing delay, micro-batch formation wait, cache lookup
and the jitted solve are all inside the measured number — the figure an
SLO is actually stated against, not the solve time alone.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class ServiceStats:
    """Immutable snapshot of a running (or drained) planning service."""

    n_requests: int            # accepted into the queue
    n_planned: int             # futures resolved with a plan
    n_batches: int             # micro-batches flushed
    queue_depth: int           # requests waiting at snapshot time
    uptime_s: float            # since the recorder (re)started its clock
    plans_per_sec: float       # n_planned / uptime
    latency_p50_ms: float      # enqueue-to-plan percentiles
    latency_p99_ms: float
    latency_max_ms: float
    #: per-(objective_id, grid_mode, bucket) request/batch/compile counts
    buckets: Dict[Tuple[str, str, int], Dict[str, int]] = \
        field(default_factory=dict)
    counters: Dict[str, int] = field(default_factory=dict)
    cache: Dict[str, object] = field(default_factory=dict)


def percentiles(samples, qs=(50.0, 99.0)) -> Tuple[float, ...]:
    """Percentiles of a sample list; zeros when there are no samples yet
    (a fresh service must report finite stats, never NaN)."""
    if not len(samples):
        return tuple(0.0 for _ in qs)
    arr = np.asarray(samples, np.float64)
    return tuple(float(np.percentile(arr, q)) for q in qs)


class StatsRecorder:
    """Thread-safe accumulator behind :class:`ServiceStats`.

    ``max_samples`` bounds the latency reservoir: an always-on service
    cannot keep every sample, so beyond the cap the buffer keeps the most
    recent window (percentiles then describe recent traffic, which is
    what an SLO dashboard wants anyway).
    """

    def __init__(self, max_samples: int = 65536):
        if max_samples < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self._lock = threading.Lock()
        self._max_samples = max_samples
        self._latencies: list = []
        self._counters: Dict[str, int] = {}
        self._buckets: Dict[Tuple[str, str, int], Dict[str, int]] = {}
        self._t0 = time.perf_counter()

    def restart_clock(self) -> None:
        """Reset the throughput clock (called after warmup so reported
        plans/sec describes steady-state serving, not compilation)."""
        with self._lock:
            self._t0 = time.perf_counter()

    def count(self, name: str, k: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + k

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            self._latencies.append(seconds)
            if len(self._latencies) > self._max_samples:
                del self._latencies[:len(self._latencies) // 2]

    def record_bucket(self, objective_id: str, grid_mode: str, bucket: int,
                      *, requests: int = 0, batches: int = 0,
                      compiles: int = 0) -> None:
        """Accumulate per-(objective, mode, bucket) serving counters."""
        key = (objective_id, grid_mode, int(bucket))
        with self._lock:
            slot = self._buckets.setdefault(
                key, {"requests": 0, "batches": 0, "compiles": 0})
            slot["requests"] += requests
            slot["batches"] += batches
            slot["compiles"] += compiles

    def snapshot(self, *, queue_depth: int = 0,
                 cache_stats=None) -> ServiceStats:
        with self._lock:
            uptime = max(time.perf_counter() - self._t0, 1e-9)
            p50, p99 = percentiles(self._latencies)
            lat_max = max(self._latencies) if self._latencies else 0.0
            counters = dict(self._counters)
            buckets = {k: dict(v) for k, v in self._buckets.items()}
        n_planned = counters.get("planned", 0)
        return ServiceStats(
            n_requests=counters.get("requests", 0),
            n_planned=n_planned,
            n_batches=counters.get("batches", 0),
            queue_depth=queue_depth, uptime_s=uptime,
            plans_per_sec=n_planned / uptime,
            latency_p50_ms=p50 * 1e3, latency_p99_ms=p99 * 1e3,
            latency_max_ms=lat_max * 1e3,
            buckets=buckets, counters=counters,
            cache=dict(cache_stats) if cache_stats else {})
