"""Always-on planning service over the fleet engine.

The serving subsystem: a long-lived :class:`PlanningService` that
ingests planning requests from any thread, forms continuous
size-or-deadline micro-batches grouped by (objective, grid mode), pads
them to power-of-two buckets whose executables were AOT-compiled during
warmup (zero post-warmup ``jax.jit`` traces), routes un-annotated
requests through a pluggable admission policy, and re-plans live
sessions when their observed channel drifts away from what the cached
plan priced.  See ``README.md`` ("Serving") for the architecture sketch.
"""
from repro.serve import export
from repro.serve.batcher import (MicroBatcher, PlanRequest, QueueFull,
                                 group_requests)
from repro.serve.catalogue import (ALL_MODELS, ALL_OBJECTIVES,
                                   FEDERATED_KIND, LINK_FACTORIES,
                                   OBJECTIVE_FACTORIES, RATE_SET,
                                   default_consts, mc_update_floor,
                                   parse_models, resolve_grid_modes,
                                   resolve_objectives, synth_population,
                                   synth_requests)
from repro.serve.policy import (AdmissionDecision, LinkAwarePolicy,
                                LoadSheddingPolicy, PolicySpec,
                                StaticPolicy, policy_spec,
                                register_policy, registered_policies,
                                unregister_policy)
from repro.serve.resilience import (BREAKER_STATES, FALLBACK_LEVELS,
                                    HEALTH_STATES, CircuitBreaker,
                                    DegradationExhausted, HealthReport,
                                    RequestShed, ResilienceManager,
                                    RetryPolicy, SolveTimeEstimator)
from repro.serve.service import PlanningService, ServiceConfig
from repro.serve.sessions import Session, SessionTracker, reestimate_link
from repro.serve.stats import (FederatedRecorder, ServiceStats,
                               StatsRecorder, percentiles)

__all__ = [
    "ALL_MODELS", "ALL_OBJECTIVES", "AdmissionDecision",
    "BREAKER_STATES", "CircuitBreaker", "DegradationExhausted",
    "FALLBACK_LEVELS", "FEDERATED_KIND",
    "FederatedRecorder", "HEALTH_STATES", "HealthReport",
    "LINK_FACTORIES",
    "LinkAwarePolicy", "LoadSheddingPolicy", "MicroBatcher",
    "OBJECTIVE_FACTORIES",
    "PlanRequest", "PlanningService", "PolicySpec", "QueueFull",
    "RATE_SET", "RequestShed", "ResilienceManager", "RetryPolicy",
    "ServiceConfig", "ServiceStats", "Session", "SessionTracker",
    "SolveTimeEstimator", "StaticPolicy", "StatsRecorder",
    "default_consts", "export", "group_requests",
    "mc_update_floor", "parse_models", "percentiles", "policy_spec",
    "reestimate_link", "register_policy", "registered_policies",
    "resolve_grid_modes", "resolve_objectives", "synth_population",
    "synth_requests", "unregister_policy",
]
