"""Deadline-budgeted resilience for the planning service.

The paper's premise is a hard completion deadline; this module makes the
*service* honour one.  Four mechanisms, composed by
:class:`ResilienceManager` and threaded through
``PlanningService._plan_group``:

* **Deadline budgets + degradation ladder.**  Each ``PlanRequest`` may
  carry a latency budget.  When the estimated solve time (a quantile of
  the per-(objective, grid_mode) solve-seconds histogram) exceeds the
  remaining budget, the request degrades along an explicit ladder
  instead of blowing its deadline::

      full ──> cached ──> bound ──> last_good ──> (exhausted)

  ``cached`` re-serves a previously solved plan for the same quantised
  scenario (a non-counting ``PlanCache.peek``, so hit-rate stats stay
  honest); ``bound`` solves the cheap dense Corollary-1 objective (whose
  bucket shapes are part of ``warmup()``'s sweep, so the fallback never
  jit-traces post-warmup); ``last_good`` re-serves the most recent
  record the (objective, grid_mode) group produced.  The level taken is
  stamped on the returned record's ``fallback`` field and counted per
  level.

* **Retry + circuit breaker.**  Transient solve exceptions retry with
  decorrelated-jitter exponential backoff.  ``breaker_threshold``
  consecutive failures trip the per-(objective, grid_mode)
  :class:`CircuitBreaker` (closed -> open -> half-open), routing that
  group straight to the ladder until a half-open probe solve succeeds.

* **Overload shedding.**  The micro-batcher's ingestion queue is
  bounded; an over-capacity ``submit`` raises :class:`RequestShed`
  (explicit, immediate) rather than growing memory without limit.

* **Health.**  ``STARTING``/``READY``/``DEGRADED``/``SHEDDING`` derived
  from warmup state, queue depth, breaker states, and drift backlog.

Everything here is observable through ``repro_resilience_*`` metric
families (see ``repro.serve.export``) and journal events for every
trip, probe, and degrade.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.hist import LogHistogram

# Degradation ladder levels, in the order they are attempted.  "full" is
# the non-degraded fast path; "exhausted" (every rung failed) surfaces
# as a DegradationExhausted on the request future and is counted, never
# raised into the worker.
FALLBACK_LEVELS = ("full", "cached", "bound", "last_good")

# Circuit breaker states.  Transitions never skip a state:
#   closed -> open (threshold consecutive failures)
#   open -> half_open (cooldown elapsed; next allow() is the probe)
#   half_open -> closed (probe succeeded) | open (probe failed)
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"
BREAKER_STATES = (CLOSED, OPEN, HALF_OPEN)

# Health/readiness states, most-healthy last.  Numeric codes are stable
# export values for the repro_resilience_health_state gauge.
HEALTH_STATES = ("STARTING", "READY", "DEGRADED", "SHEDDING")
HEALTH_CODES = {name: i for i, name in enumerate(HEALTH_STATES)}


class RequestShed(RuntimeError):
    """Request rejected at admission (bounded queue full or the
    admission policy returned a shed decision)."""


class DegradationExhausted(RuntimeError):
    """Every rung of the degradation ladder failed for a request."""


@dataclass(frozen=True)
class HealthReport:
    """Point-in-time readiness: ``state`` plus why."""

    state: str
    reasons: Tuple[str, ...] = ()

    @property
    def code(self) -> int:
        return HEALTH_CODES[self.state]

    @property
    def ready(self) -> bool:
        return self.state in ("READY", "DEGRADED")


class CircuitBreaker:
    """closed -> open -> half-open breaker over consecutive failures.

    ``allow()`` answers "may this attempt proceed?"; in the open state
    it flips to half-open once ``cooldown_s`` has elapsed and admits
    exactly one probe.  ``record_success``/``record_failure`` feed the
    outcome back.  The clock is injectable so tests drive time
    explicitly.  Thread-safe; transitions fire ``on_transition(old,
    new)`` outside any lock the caller holds but inside the breaker's
    own (keep callbacks cheap and non-reentrant).
    """

    def __init__(self, threshold: int = 5, cooldown_s: float = 1.0, *,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Optional[
                     Callable[[str, str], None]] = None):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        if cooldown_s < 0.0:
            raise ValueError(
                f"cooldown_s must be >= 0, got {cooldown_s}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._on_transition = on_transition
        self._lock = threading.Lock()
        self.state = CLOSED
        self.failures = 0        # consecutive, while closed
        self.opened_at: Optional[float] = None
        self.trips = 0           # closed -> open transitions
        self.probes = 0          # attempts admitted while half-open
        self.recoveries = 0      # half_open -> closed transitions

    def _transition(self, new: str) -> None:
        old, self.state = self.state, new
        if new == OPEN:
            self.opened_at = self._clock()
            if old == CLOSED:
                self.trips += 1
        elif new == CLOSED:
            self.failures = 0
            self.opened_at = None
            if old == HALF_OPEN:
                self.recoveries += 1
        if self._on_transition is not None:
            self._on_transition(old, new)

    def allow(self) -> bool:
        """True if an attempt may proceed now.  In the open state this
        is what promotes to half-open after the cooldown; the admitted
        attempt is the probe."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                if self._clock() - self.opened_at < self.cooldown_s:
                    return False
                self._transition(HALF_OPEN)
            # half-open: admit the (single-worker) probe.
            self.probes += 1
            return True

    def record_success(self) -> None:
        with self._lock:
            if self.state == HALF_OPEN:
                self._transition(CLOSED)
            else:
                self.failures = 0

    def record_failure(self) -> None:
        with self._lock:
            if self.state == HALF_OPEN:
                self._transition(OPEN)
            elif self.state == CLOSED:
                self.failures += 1
                if self.failures >= self.threshold:
                    self._transition(OPEN)
            else:  # already open: re-arm the cooldown window
                self.opened_at = self._clock()


@dataclass(frozen=True)
class RetryPolicy:
    """Decorrelated-jitter exponential backoff (Brooker): each delay is
    ``min(cap, uniform(base, prev * 3))``, seeded so a given service
    run's backoff sequence is reproducible."""

    attempts: int = 3
    base_s: float = 0.02
    cap_s: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError(
                f"attempts must be >= 1, got {self.attempts}")
        if self.base_s <= 0.0 or self.cap_s < self.base_s:
            raise ValueError(
                f"need 0 < base_s <= cap_s, got base_s={self.base_s} "
                f"cap_s={self.cap_s}")

    def delays(self) -> "_DelayStream":
        return _DelayStream(self)


class _DelayStream:
    """Stateful per-chunk backoff sequence from a :class:`RetryPolicy`."""

    def __init__(self, policy: RetryPolicy):
        self._policy = policy
        self._rng = random.Random(policy.seed)
        self._prev = policy.base_s

    def next_delay(self) -> float:
        p = self._policy
        self._prev = min(p.cap_s,
                         self._rng.uniform(p.base_s, self._prev * 3.0))
        return self._prev


class SolveTimeEstimator:
    """Per-(objective, grid_mode) histogram of observed solve seconds;
    ``estimate`` is the configured quantile (pessimistic by default) so
    budget checks predict the slow tail, not the mean.  No observations
    -> 0.0: be optimistic and attempt the real solve."""

    def __init__(self, quantile: float = 90.0):
        if not 0.0 < quantile <= 100.0:
            raise ValueError(
                f"quantile must be in (0, 100], got {quantile}")
        self.quantile = float(quantile)
        self._lock = threading.Lock()
        self._hists: Dict[Tuple[str, str], LogHistogram] = {}

    def observe(self, objective_id: str, grid_mode: str,
                seconds: float) -> None:
        key = (objective_id, grid_mode)
        with self._lock:
            hist = self._hists.get(key)
            if hist is None:
                hist = self._hists[key] = LogHistogram(1e-5, 1e2, 100)
            hist.record(max(float(seconds), 0.0))

    def estimate(self, objective_id: str, grid_mode: str) -> float:
        with self._lock:
            hist = self._hists.get((objective_id, grid_mode))
            if hist is None or hist.count == 0:
                return 0.0
            return float(hist.percentile(self.quantile))


class ResilienceManager:
    """Composes breaker + retry + estimator + ladder accounting for the
    service.  The service owns the *mechanics* (cache peeks, fallback
    solves, future resolution); this class owns the *decisions* and all
    the counters the ``repro_resilience_*`` export reads."""

    def __init__(self, *,
                 retry: RetryPolicy = RetryPolicy(),
                 breaker_threshold: int = 5,
                 breaker_cooldown_s: float = 1.0,
                 budget_quantile: float = 90.0,
                 budget_safety: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 journal=None,
                 faults=None):
        if budget_safety <= 0.0:
            raise ValueError(
                f"budget_safety must be > 0, got {budget_safety}")
        self.retry = retry
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.budget_safety = float(budget_safety)
        self.estimator = SolveTimeEstimator(quantile=budget_quantile)
        self._clock = clock
        self._journal = journal
        self.faults = faults
        self._lock = threading.Lock()
        self._breakers: Dict[Tuple[str, str], CircuitBreaker] = {}
        self._last_good: Dict[Tuple[str, str], object] = {}
        self.fallbacks: Dict[str, int] = {}      # level -> count
        self.degrade_reasons: Dict[str, int] = {}
        self.retries = 0
        self.backoff_seconds = 0.0
        self.sheds: Dict[str, int] = {}          # reason -> count
        self.budget_exceeded = 0
        self.exhausted = 0
        self._last_health = "STARTING"

    # -- journal helper ------------------------------------------------
    def _emit(self, kind: str, **fields) -> None:
        if self._journal is not None:
            self._journal.emit(kind, **fields)

    # -- circuit breakers ----------------------------------------------
    def breaker(self, objective_id: str,
                grid_mode: str) -> CircuitBreaker:
        key = (objective_id, grid_mode)
        with self._lock:
            brk = self._breakers.get(key)
            if brk is None:
                def _on_transition(old, new, _key=key):
                    self._emit("breaker", objective=_key[0],
                               grid_mode=_key[1], from_state=old,
                               to_state=new)
                brk = CircuitBreaker(
                    self.breaker_threshold, self.breaker_cooldown_s,
                    clock=self._clock, on_transition=_on_transition)
                self._breakers[key] = brk
            return brk

    def breaker_states(self) -> Dict[Tuple[str, str], str]:
        with self._lock:
            return {k: b.state for k, b in self._breakers.items()}

    # -- budget triage -------------------------------------------------
    def split_over_budget(self, requests, objective_id: str,
                          grid_mode: str):
        """Partition a micro-batch group into (solve-now, degrade-now)
        by remaining budget vs the estimated solve time.  Requests with
        no budget always solve."""
        est = (self.estimator.estimate(objective_id, grid_mode)
               * self.budget_safety)
        now = time.perf_counter()
        keep, degrade = [], []
        for req in requests:
            remaining = req.remaining_budget(now)
            if remaining is not None and remaining <= est:
                degrade.append(req)
            else:
                keep.append(req)
        if degrade:
            self.note_budget_exceeded(len(degrade))
        return keep, degrade

    def note_budget_exceeded(self, n: int = 1) -> None:
        with self._lock:
            self.budget_exceeded += n

    # -- retry loop ----------------------------------------------------
    def run_attempts(self, objective_id: str, grid_mode: str,
                     fn: Callable[[], object],
                     sleep: Callable[[float], None] = time.sleep):
        """Run ``fn`` under fault injection, retry/backoff, and breaker
        accounting.  Raises the last exception once attempts are
        exhausted or the breaker denies further tries; the caller then
        walks the degradation ladder."""
        brk = self.breaker(objective_id, grid_mode)
        delays = self.retry.delays()
        attempt = 0
        while True:
            attempt += 1
            try:
                if self.faults is not None:
                    action = self.faults.draw("solve.latency")
                    if action is not None:
                        self._emit("fault", point=action.point,
                                   index=action.index,
                                   duration_s=action.duration_s)
                        sleep(action.duration_s)
                    action = self.faults.draw("solve.error")
                    if action is not None:
                        self._emit("fault", point=action.point,
                                   index=action.index)
                        from repro.chaos import InjectedFault
                        raise InjectedFault(
                            f"injected solve fault "
                            f"(index={action.index})")
                out = fn()
            except Exception as exc:
                brk.record_failure()
                self._emit("solve_failed", objective=objective_id,
                           grid_mode=grid_mode, attempt=attempt,
                           error=f"{type(exc).__name__}: {exc}")
                if attempt >= self.retry.attempts or not brk.allow():
                    raise
                delay = delays.next_delay()
                with self._lock:
                    self.retries += 1
                    self.backoff_seconds += delay
                sleep(delay)
                continue
            brk.record_success()
            return out

    # -- ladder accounting ---------------------------------------------
    def note_last_good(self, objective_id: str, grid_mode: str,
                       record) -> None:
        with self._lock:
            self._last_good[(objective_id, grid_mode)] = record

    def last_good(self, objective_id: str, grid_mode: str):
        with self._lock:
            return self._last_good.get((objective_id, grid_mode))

    def count_fallback(self, level: str, reason: str,
                       n: int = 1) -> None:
        with self._lock:
            self.fallbacks[level] = self.fallbacks.get(level, 0) + n
            self.degrade_reasons[reason] = (
                self.degrade_reasons.get(reason, 0) + n)
        self._emit("degrade", level=level, reason=reason, count=n)

    def note_exhausted(self, n: int = 1) -> None:
        with self._lock:
            self.exhausted += n

    def note_shed(self, reason: str, n: int = 1) -> None:
        with self._lock:
            self.sheds[reason] = self.sheds.get(reason, 0) + n
        self._emit("shed", reason=reason, count=n)

    # -- health --------------------------------------------------------
    def health(self, *, warmed: bool, queue_depth: int,
               max_pending: int, drift_backlog: int = 0,
               drift_backlog_limit: int = 8) -> HealthReport:
        reasons: List[str] = []
        if not warmed:
            state = "STARTING"
            reasons.append("warmup incomplete")
        elif max_pending > 0 and queue_depth >= max_pending:
            state = "SHEDDING"
            reasons.append(
                f"queue at capacity ({queue_depth}/{max_pending})")
        else:
            state = "READY"
            open_keys = [k for k, s in self.breaker_states().items()
                         if s != CLOSED]
            if open_keys:
                state = "DEGRADED"
                reasons.extend(
                    f"breaker {oid}/{mode} not closed"
                    for oid, mode in open_keys)
            if drift_backlog >= max(1, drift_backlog_limit):
                state = "DEGRADED"
                reasons.append(
                    f"drift backlog {drift_backlog} >= "
                    f"{drift_backlog_limit}")
        report = HealthReport(state=state, reasons=tuple(reasons))
        with self._lock:
            changed = report.state != self._last_health
            self._last_health = report.state
        if changed:
            self._emit("health", state=report.state,
                       reasons=list(report.reasons))
        return report

    # -- export snapshot -----------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Counters for ``repro_resilience_*`` export and the CLI
        report.  Breaker states come out as label tuples."""
        with self._lock:
            snap: Dict[str, object] = {
                "fallbacks": dict(self.fallbacks),
                "degrade_reasons": dict(self.degrade_reasons),
                "retries": self.retries,
                "backoff_seconds": self.backoff_seconds,
                "sheds": dict(self.sheds),
                "budget_exceeded": self.budget_exceeded,
                "exhausted": self.exhausted,
                "breakers": {
                    k: {"state": b.state, "trips": b.trips,
                        "probes": b.probes,
                        "recoveries": b.recoveries}
                    for k, b in self._breakers.items()},
                "health": self._last_health,
            }
        if self.faults is not None:
            snap["faults_injected"] = dict(self.faults.fires)
        else:
            snap["faults_injected"] = {}
        return snap
