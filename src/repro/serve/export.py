"""Metric-source adapters: serving state -> ``repro.obs`` metric families.

``repro.obs`` is deliberately standalone (no serve/fleet imports, so the
kernels can use it without cycles); this module is the glue in the other
direction — it knows the serving layer's snapshot shapes
(:class:`~repro.serve.stats.ServiceStats`, ``PlanCache.stats()``,
``SpanRecorder.totals()``, ``EventJournal.counts()``, the fleet trace
events) and renders each as :class:`~repro.obs.metrics.Metric` families
under a stable naming scheme:

================================================  =========  ==========
metric                                            kind       labels
================================================  =========  ==========
``repro_serve_<counter>_total``                   counter    —
``repro_serve_sessions_open``                     gauge      —
``repro_serve_queue_depth``                       gauge      —
``repro_serve_uptime_seconds``                    gauge      —
``repro_serve_plans_per_sec``                     gauge      —
``repro_serve_bucket_{requests,batches,           counter    objective,
compiles}_total``                                            grid_mode,
                                                             bucket
``repro_serve_latency_seconds``                   histogram  —
``repro_serve_bucket_latency_seconds``            histogram  objective,
                                                             grid_mode,
                                                             bucket
``repro_serve_cache_{hits,misses,evictions,       counter    —
invalidations}_total``
``repro_serve_cache_{hits,misses}                 counter    objective
_by_objective_total``
``repro_serve_cache_{entries,maxsize,hit_rate}``  gauge      —
``repro_serve_phase_seconds_total``               counter    phase
``repro_serve_solve_device_seconds_total``        counter    —
``repro_serve_spans_recorded_total``              counter    —
``repro_serve_solve_fraction``                    gauge      —
``repro_serve_events_total``                      counter    kind
``repro_fleet_kernel_traces_total``               counter    kind, shape
``repro_fleet_traces_total``                      counter    —
``repro_federated_rounds_total``                  counter    —
``repro_federated_participants_total``            counter    —
``repro_federated_infeasible_rounds_total``       counter    —
``repro_federated_round_latency_seconds``         histogram  —
``repro_federated_round_time_seconds``            histogram  —
================================================  =========  ==========

:func:`register_service_sources` wires a live
:class:`~repro.serve.service.PlanningService` into its registry;
:func:`oneshot_metrics` builds a standalone registry for the one-shot
``plan_server`` driver; :func:`write_textfile` dumps any registry for
the node-exporter textfile collector.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Tuple

from repro.fleet.tracing import trace_events
from repro.obs import (EventJournal, LogHistogram, Metric, MetricsRegistry,
                       SpanRecorder)
from repro.serve.stats import ServiceStats

if TYPE_CHECKING:  # pragma: no cover - annotation-only import
    from repro.serve.service import PlanningService

#: ``ServiceStats.counters`` entries that are levels, not monotone
#: counts — exported as gauges without the ``_total`` suffix.
GAUGE_COUNTERS = ("sessions_open",)


def service_metrics(stats: ServiceStats) -> List[Metric]:
    """The :class:`ServiceStats` snapshot as metric families: every
    ``counters`` entry, the per-bucket counters, the cache counters and
    the latency histograms.  Phase/span families come from
    :func:`span_metrics` (the span recorder is the source of truth for
    those; the copies on ``stats`` exist for JSON reporting)."""
    out: List[Metric] = []
    for name in sorted(stats.counters):
        v = stats.counters[name]
        if name in GAUGE_COUNTERS:
            out.append(Metric(f"repro_serve_{name}", "gauge",
                              f"service level {name}").add(float(v)))
        else:
            out.append(Metric(f"repro_serve_{name}_total", "counter",
                              f"service counter {name}").add(float(v)))
    out.append(Metric("repro_serve_queue_depth", "gauge",
                      "requests waiting in the ingestion queue")
               .add(float(stats.queue_depth)))
    out.append(Metric("repro_serve_uptime_seconds", "gauge",
                      "seconds since the stats clock (re)started")
               .add(stats.uptime_s))
    out.append(Metric("repro_serve_plans_per_sec", "gauge",
                      "plans resolved per second since the clock restart")
               .add(stats.plans_per_sec))

    for field_name in ("requests", "batches", "compiles"):
        m = Metric(f"repro_serve_bucket_{field_name}_total", "counter",
                   f"per-(objective, grid_mode, bucket) {field_name}")
        for (oid, mode, bucket), slot in sorted(stats.buckets.items()):
            m.add(float(slot[field_name]), objective=oid, grid_mode=mode,
                  bucket=str(bucket))
        if m.samples:
            out.append(m)

    if stats.latency_hist:
        out.append(Metric("repro_serve_latency_seconds", "histogram",
                          "enqueue-to-plan latency")
                   .add(LogHistogram.from_dict(stats.latency_hist)))
    if stats.histograms:
        m = Metric("repro_serve_bucket_latency_seconds", "histogram",
                   "enqueue-to-plan latency per (objective, grid_mode, "
                   "bucket)")
        for key, hd in sorted(stats.histograms.items()):
            oid, mode, bucket = key.rsplit("/", 2)
            m.add(LogHistogram.from_dict(hd), objective=oid,
                  grid_mode=mode, bucket=bucket)
        out.append(m)

    out.extend(cache_metrics(stats.cache))
    return out


def cache_metrics(cache_stats: Dict[str, object]) -> List[Metric]:
    """``PlanCache.stats()`` (or ``ServiceStats.cache``) as families."""
    if not cache_stats:
        return []
    out: List[Metric] = []
    for name in ("hits", "misses", "evictions", "invalidations",
                 "corruptions"):
        if name in cache_stats:
            out.append(Metric(f"repro_serve_cache_{name}_total", "counter",
                              f"plan cache {name}")
                       .add(float(cache_stats[name])))  # type: ignore[arg-type]
    for name in ("hits", "misses"):
        per = cache_stats.get(f"{name}_by_objective") or {}
        if per:
            m = Metric(f"repro_serve_cache_{name}_by_objective_total",
                       "counter", f"plan cache {name} per objective")
            for oid, v in sorted(per.items()):  # type: ignore[union-attr]
                m.add(float(v), objective=str(oid))
            out.append(m)
    gauges = (("size", "entries", "live cache entries"),
              ("maxsize", "maxsize", "cache capacity"),
              ("hit_rate", "hit_rate", "lifetime cache hit rate"))
    for src, dst, help_text in gauges:
        if src in cache_stats:
            out.append(Metric(f"repro_serve_cache_{dst}", "gauge",
                              help_text)
                       .add(float(cache_stats[src])))  # type: ignore[arg-type]
    return out


def tracing_metrics(events: Dict[Tuple, int] = None) -> List[Metric]:
    """The fleet kernel trace counters (``None`` snapshots the live
    process-global events) — the audit trail behind the
    zero-traces-after-warmup SLO."""
    if events is None:
        events = trace_events()
    per_tag = Metric("repro_fleet_kernel_traces_total", "counter",
                     "jit traces per kernel (kind, shape)")
    total = 0
    for tag, n in sorted(events.items(), key=lambda kv: str(kv[0])):
        kind = str(tag[0]) if tag else "unknown"
        shape = ",".join(str(t) for t in tag[1:])
        per_tag.add(float(n), kind=kind, shape=shape)
        total += n
    out = [Metric("repro_fleet_traces_total", "counter",
                  "total jit traces across all fleet kernels")
           .add(float(total))]
    if per_tag.samples:
        out.append(per_tag)
    return out


def span_metrics(spans: SpanRecorder) -> List[Metric]:
    """Lifetime phase totals from the span recorder: the exact
    decomposition of cumulative enqueue-to-plan latency."""
    totals = spans.totals()
    phase = Metric("repro_serve_phase_seconds_total", "counter",
                   "cumulative request time per lifecycle phase "
                   "(admit is pre-enqueue, outside the latency SLO)")
    for name, v in sorted(totals.items()):
        if name in ("count", "solve_device", "latency"):
            continue
        phase.add(v, phase=name)
    return [
        phase,
        Metric("repro_serve_solve_device_seconds_total", "counter",
               "block_until_ready-fenced device portion of solve time")
        .add(totals["solve_device"]),
        Metric("repro_serve_span_latency_seconds_total", "counter",
               "cumulative enqueue-to-plan latency over all spans")
        .add(totals["latency"]),
        Metric("repro_serve_spans_recorded_total", "counter",
               "request spans recorded (lifetime, ring may hold fewer)")
        .add(float(totals["count"])),
        Metric("repro_serve_solve_fraction", "gauge",
               "lifetime solve share of enqueue-to-plan latency")
        .add(spans.solve_fraction),
    ]


def federated_metrics(recorder) -> List[Metric]:
    """The federated round path's counters and distributions (a
    :class:`~repro.serve.stats.FederatedRecorder` snapshot) as
    ``repro_federated_*`` families."""
    snap = recorder.snapshot()
    out = [
        Metric("repro_federated_rounds_total", "counter",
               "federated rounds planned").add(float(snap["rounds"])),
        Metric("repro_federated_participants_total", "counter",
               "participants selected across all rounds")
        .add(float(snap["participants"])),
        Metric("repro_federated_infeasible_rounds_total", "counter",
               "rounds with no deadline-feasible participant")
        .add(float(snap["infeasible_rounds"])),
    ]
    if snap["latency_hist"]:
        out.append(Metric("repro_federated_round_latency_seconds",
                          "histogram", "submit_round latency")
                   .add(LogHistogram.from_dict(snap["latency_hist"])))
    if snap["round_time_hist"]:
        out.append(Metric("repro_federated_round_time_seconds",
                          "histogram",
                          "planned straggler-bounded round time")
                   .add(LogHistogram.from_dict(snap["round_time_hist"])))
    return out


def resilience_metrics(service: "PlanningService") -> List[Metric]:
    """The resilience layer as ``repro_resilience_*`` families:

    ================================================  =========  ========
    metric                                            kind       labels
    ================================================  =========  ========
    ``repro_resilience_fallbacks_total``              counter    level
    ``repro_resilience_degrade_reasons_total``        counter    reason
    ``repro_resilience_retries_total``                counter    —
    ``repro_resilience_backoff_seconds_total``        counter    —
    ``repro_resilience_shed_total``                   counter    reason
    ``repro_resilience_budget_exceeded_total``        counter    —
    ``repro_resilience_exhausted_total``              counter    —
    ``repro_resilience_breaker_state``                gauge      objective,
                                                                 grid_mode
    ``repro_resilience_breaker_{trips,probes,         counter    objective,
    recoveries}_total``                                          grid_mode
    ``repro_resilience_faults_injected_total``        counter    point
    ``repro_resilience_health_state``                 gauge      —
    ``repro_resilience_health``                       gauge      state
    ================================================  =========  ========

    Breaker state gauges encode closed=0 / open=1 / half_open=2;
    ``health_state`` encodes STARTING=0 / READY=1 / DEGRADED=2 /
    SHEDDING=3 (plus the one-hot ``health{state=...}`` for dashboards
    that match on labels).  ``health()`` is evaluated at collect time,
    so a scrape always sees current readiness.
    """
    from repro.serve.resilience import (BREAKER_STATES, FALLBACK_LEVELS,
                                        HEALTH_STATES)

    snap = service.resilience.snapshot()
    health = service.health()
    out: List[Metric] = []

    # every ladder level is pre-declared at 0 (a dashboard's rate()
    # needs the zero sample BEFORE the first degrade, not after)
    m = Metric("repro_resilience_fallbacks_total", "counter",
               "degraded responses per fallback level")
    for level in FALLBACK_LEVELS[1:]:
        m.add(float(snap["fallbacks"].get(level, 0)), level=level)
    for level, n in sorted(snap["fallbacks"].items()):
        if level not in FALLBACK_LEVELS[1:]:
            m.add(float(n), level=str(level))
    out.append(m)

    m = Metric("repro_resilience_degrade_reasons_total", "counter",
               "ladder entries per degrade reason")
    for reason, n in sorted(snap["degrade_reasons"].items()):
        m.add(float(n), reason=str(reason))
    if m.samples:
        out.append(m)

    out.append(Metric("repro_resilience_retries_total", "counter",
                      "transient solve retries")
               .add(float(snap["retries"])))
    out.append(Metric("repro_resilience_backoff_seconds_total", "counter",
                      "cumulative retry backoff sleep")
               .add(float(snap["backoff_seconds"])))
    out.append(Metric("repro_resilience_budget_exceeded_total", "counter",
                      "requests degraded for deadline-budget pressure")
               .add(float(snap["budget_exceeded"])))
    out.append(Metric("repro_resilience_exhausted_total", "counter",
                      "requests that exhausted every ladder rung")
               .add(float(snap["exhausted"])))

    m = Metric("repro_resilience_shed_total", "counter",
               "requests shed at admission, per reason")
    for reason, n in sorted(snap["sheds"].items()):
        m.add(float(n), reason=str(reason))
    if m.samples:
        out.append(m)

    if snap["breakers"]:
        state_codes = {s: i for i, s in enumerate(BREAKER_STATES)}
        gauge = Metric("repro_resilience_breaker_state", "gauge",
                       "circuit breaker state "
                       "(0=closed, 1=open, 2=half_open)")
        per = {name: Metric(f"repro_resilience_breaker_{name}_total",
                            "counter", f"breaker {name}")
               for name in ("trips", "probes", "recoveries")}
        for (oid, mode), b in sorted(snap["breakers"].items()):
            labels = dict(objective=str(oid), grid_mode=str(mode))
            gauge.add(float(state_codes[b["state"]]), **labels)
            for name in ("trips", "probes", "recoveries"):
                per[name].add(float(b[name]), **labels)
        out.append(gauge)
        out.extend(per.values())

    m = Metric("repro_resilience_faults_injected_total", "counter",
               "chaos faults fired per injection point")
    enabled = tuple(service.faults.rules) if service.faults is not None \
        else ()
    for point in sorted(set(enabled) | set(snap["faults_injected"])):
        m.add(float(snap["faults_injected"].get(point, 0)),
              point=str(point))
    if m.samples:
        out.append(m)

    out.append(Metric("repro_resilience_health_state", "gauge",
                      "service readiness (0=STARTING, 1=READY, "
                      "2=DEGRADED, 3=SHEDDING)")
               .add(float(health.code)))
    one_hot = Metric("repro_resilience_health", "gauge",
                     "service readiness, one-hot by state label")
    for state in HEALTH_STATES:
        one_hot.add(1.0 if state == health.state else 0.0, state=state)
    out.append(one_hot)
    return out


def journal_metrics(journal: EventJournal) -> List[Metric]:
    """Lifetime per-kind event counts from the audit journal."""
    m = Metric("repro_serve_events_total", "counter",
               "journal events per kind")
    for kind, n in sorted(journal.counts().items()):
        m.add(float(n), kind=kind)
    out = [Metric("repro_serve_events_emitted_total", "counter",
                  "journal events emitted (lifetime)")
           .add(float(journal.emitted))]
    if m.samples:
        out.append(m)
    return out


def register_service_sources(registry: MetricsRegistry,
                             service: "PlanningService") -> None:
    """Wire a live service's four counter surfaces into its registry.
    Sources pull at collect time, so every export is a fresh snapshot."""
    registry.register_source(
        "service", lambda: service_metrics(service.stats()))
    registry.register_source("tracing", tracing_metrics)
    registry.register_source(
        "spans", lambda: span_metrics(service.spans))
    registry.register_source(
        "events", lambda: journal_metrics(service.journal))
    registry.register_source(
        "federated", lambda: federated_metrics(service.federated))
    registry.register_source(
        "resilience", lambda: resilience_metrics(service))


def oneshot_metrics(stats, cache=None) -> MetricsRegistry:
    """A standalone registry for the one-shot ``plan_server`` driver's
    :class:`~repro.launch.plan_server.ServeStats` — same naming scheme,
    ``repro_plan_server_`` prefix so a host running both exporters never
    collides."""
    def collect() -> List[Metric]:
        out = [
            Metric("repro_plan_server_requests_total", "counter",
                   "requests served").add(float(stats.n_requests)),
            Metric("repro_plan_server_batches_total", "counter",
                   "micro-batches planned").add(float(stats.n_batches)),
            Metric("repro_plan_server_seconds", "gauge",
                   "serve loop wall clock").add(stats.seconds),
            Metric("repro_plan_server_plans_per_sec", "gauge",
                   "serve loop throughput").add(stats.plans_per_sec),
            Metric("repro_plan_server_cache_hit_rate", "gauge",
                   "stream cache hit rate").add(stats.cache_hit_rate),
            Metric("repro_plan_server_batch_latency_p99_ms", "gauge",
                   "per-micro-batch p99 latency").add(stats.batch_p99_ms),
        ]
        for label, per in (("model", stats.requests_per_model),
                           ("objective", stats.requests_per_objective),
                           ("grid_mode", stats.requests_per_grid_mode)):
            if per:
                m = Metric(f"repro_plan_server_requests_by_{label}_total",
                           "counter", f"requests per {label}")
                for k, v in sorted(per.items(), key=lambda kv: str(kv[0])):
                    m.add(float(v), **{label: str(k)})
                out.append(m)
        if cache is not None:
            out.extend(cache_metrics(cache.stats()))
        out.extend(tracing_metrics())
        return out

    registry = MetricsRegistry()
    registry.register_source("plan_server", collect)
    return registry


def write_textfile(registry: MetricsRegistry, path: str) -> str:
    """Dump ``registry`` as a Prometheus textfile (atomic rename); the
    parsed-on-read contract lives in ``MetricsRegistry.snapshot``."""
    return registry.write_textfile(path)
