"""Session tracking and drift-triggered re-planning signals.

A PLAN is a bet on link statistics.  The planner prices a
Gilbert-Elliott link by its stationary (or exact Markov-reward) loss;
the realised channel — what :meth:`LinkModel.make_loss_process` samples,
one outcome per ARQ attempt — can drift away from that bet mid-session:
the chain gets stickier, the bad state gets worse, interference moves
in.  A session registers with the service, streams its observed
per-attempt loss outcomes in, and this module decides when the plan's
assumed loss probability and the observed loss rate have diverged far
enough that replaying the cached plan is worse than re-planning.

Drift detection: an exponentially-weighted moving average of the loss
indicators (smoothing ``ewma_alpha``), armed only after
``min_observations`` outcomes (the EWMA of three packets is noise).
Drift fires when ``|ewma - plan.p_err| > drift_threshold``.

Re-estimation: :func:`reestimate_link` maps the drifted observation back
into link-model parameters so the re-planned scenario actually reflects
the observed channel —

  * ``GilbertElliottLink``: the observed loss rate pins a new stationary
    bad-state occupancy ``pi_bad`` (inverting ``p = p_g + pi (p_b -
    p_g)`` at the session's rate); the chain's mixing speed ``p_gb +
    p_bg`` is preserved and re-split to hit the new ``pi_bad`` — the
    burst STRUCTURE is kept, its occupancy re-fit;
  * ``ErasureLink``: ``p_base`` is re-fit so ``p_err(rate)`` equals the
    observation;
  * any link exposing ``reestimate(rate, observed_loss)`` (plugin hook)
    is deferred to;
  * otherwise ``None`` — the service counts the drift but keeps the plan
    (re-planning the identical scenario would return the identical
    answer).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.links import P_ERR_MAX
from repro.core.scenario import ErasureLink, GilbertElliottLink, Scenario
from repro.fleet.planner import PlanRecord


def reestimate_link(link, rate: float, observed_loss: float):
    """A new link instance consistent with ``observed_loss`` at ``rate``,
    or ``None`` when the model offers nothing to re-fit."""
    observed_loss = float(np.clip(observed_loss, 0.0, P_ERR_MAX))
    hook = getattr(link, "reestimate", None)
    if callable(hook):
        return hook(rate, observed_loss)
    if isinstance(link, GilbertElliottLink):
        p_g, p_b = (float(min(p, P_ERR_MAX))
                    for p in link._state_p_err(rate))
        if p_b == p_g:
            return None  # degenerate chain: occupancy is unobservable
        mix = link.p_gb + link.p_bg
        pi = float(np.clip((observed_loss - p_g) / (p_b - p_g), 0.0, 1.0))
        # keep the mixing speed, re-split it to hit the observed
        # occupancy; clamps keep both probabilities in [0, 1] and the
        # chain ergodic (mix > 0 is inherited from the valid source link)
        p_gb = float(np.clip(pi * mix, max(0.0, mix - 1.0), min(1.0, mix)))
        p_gb = min(max(p_gb, 1e-9 * mix), mix - 1e-9 * mix)
        return dataclasses.replace(link, p_gb=p_gb, p_bg=mix - p_gb)
    if isinstance(link, ErasureLink):
        decay = float(np.exp(-link.beta * max(float(rate) - 1.0, 0.0)))
        p_base = 1.0 - (1.0 - observed_loss) / decay
        return dataclasses.replace(
            link, p_base=float(np.clip(p_base, 0.0, P_ERR_MAX)))
    return None


@dataclass
class Session:
    """One device's live planning session."""

    session_id: str
    scenario: Scenario
    objective: object = None
    grid_mode: str = "dense"
    plan: Optional[PlanRecord] = None
    ewma: Optional[float] = None       # observed loss EWMA (None = no data)
    n_observations: int = 0
    generation: int = 0                # bumps every time a new plan lands
    replans: int = 0                   # drift-triggered re-plans
    replan_pending: bool = False
    opened_t: float = field(default_factory=time.perf_counter)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def observe(self, losses) -> Optional[float]:
        """Fold per-attempt loss outcomes (iterable of bools) into the
        EWMA; returns the updated EWMA (None while below the arming
        threshold is handled by the tracker, not here)."""
        with self._lock:
            for lost in losses:
                x = 1.0 if lost else 0.0
                self.ewma = x if self.ewma is None else \
                    (1.0 - self.ewma_alpha) * self.ewma + self.ewma_alpha * x
                self.n_observations += 1
            return self.ewma

    # class-level default, overridable per session by the tracker
    ewma_alpha: float = 0.05


class SessionTracker:
    """Registry of live sessions + the drift decision.

    The tracker only DECIDES; the service acts (cache invalidation and
    re-enqueue live there, where the cache context and batcher are).
    """

    def __init__(self, *, drift_threshold: float = 0.1,
                 ewma_alpha: float = 0.05, min_observations: int = 20):
        if not 0.0 < drift_threshold < 1.0:
            raise ValueError(
                f"drift_threshold must be in (0, 1), got {drift_threshold}")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {ewma_alpha}")
        self.drift_threshold = drift_threshold
        self.ewma_alpha = ewma_alpha
        self.min_observations = max(1, int(min_observations))
        self._lock = threading.Lock()
        self._sessions: dict = {}

    def open(self, session: Session) -> Session:
        session.ewma_alpha = self.ewma_alpha
        with self._lock:
            if session.session_id in self._sessions:
                raise ValueError(
                    f"session {session.session_id!r} is already open")
            self._sessions[session.session_id] = session
        return session

    def get(self, session_id: str) -> Session:
        with self._lock:
            session = self._sessions.get(session_id)
        if session is None:
            raise KeyError(f"unknown session {session_id!r}; open it first")
        return session

    def close(self, session_id: str) -> Optional[Session]:
        with self._lock:
            return self._sessions.pop(session_id, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def pending_replans(self) -> int:
        """Sessions with a plan in flight (drift re-plan or first plan)
        — the service's drift-backlog health signal."""
        with self._lock:
            return sum(1 for s in self._sessions.values()
                       if s.replan_pending)

    def drifted(self, session: Session) -> bool:
        """True when the session's observed loss EWMA has moved more than
        ``drift_threshold`` away from its CURRENT plan's priced loss."""
        if session.plan is None or session.ewma is None \
                or session.replan_pending:
            return False
        if session.n_observations < self.min_observations:
            return False
        return abs(session.ewma - session.plan.p_err) > self.drift_threshold
