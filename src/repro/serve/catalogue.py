"""Shared serving catalogue: device classes, objectives, grid modes.

Everything a planning front end needs to turn NAMES into planning
configuration, used by both the always-on service
(:mod:`repro.serve.service`) and the one-shot ``plan_server`` driver:

  * link factories for the synthetic device-class catalogue
    (:data:`LINK_FACTORIES` / :data:`ALL_MODELS`) and the heterogeneous
    request stream generator :func:`synth_requests`;
  * objective factories (:data:`OBJECTIVE_FACTORIES` /
    :data:`ALL_OBJECTIVES`) and :func:`resolve_objectives`, which
    instantiates each requested objective ONCE (instance identity keys
    the jitted Monte-Carlo kernel cache);
  * :func:`resolve_grid_modes` validating grid-mode mixes;
  * :func:`default_consts`, the paper's edge-ridge bound constants.

Unknown names raise ``ValueError`` everywhere — the CLIs map that to
exit code 2 — because a typo silently falling back to a default would
skew the stream it was meant to describe.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import numpy as np

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants
from repro.core.objectives import (BoundObjective, MarkovARQObjective,
                                   MonteCarloObjective)
from repro.core.scenario import (ErasureLink, FadingLink, GilbertElliottLink,
                                 IdealLink, MultiDevice, Scenario,
                                 SingleDevice)
from repro.fleet import GRID_MODES
from repro.fleet.objective_kernels import pow2ceil

RATE_SET = (1.0, 1.25, 1.5, 2.0, 3.0)

#: Request kind for federated round planning (the population-level
#: workload served by ``PlanningService.submit_round``); also the leading
#: element of the federated cache key, so round entries can never alias
#: per-device plan entries.
FEDERATED_KIND = "federated_round"


def default_consts() -> BoundConstants:
    """The paper's edge-ridge bound constants (Sec. 5)."""
    return BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0,
                          alpha=EP.alpha)


def _draw_ideal(rng) -> IdealLink:
    return IdealLink(rates=RATE_SET)


def _draw_erasure(rng) -> ErasureLink:
    return ErasureLink(beta=float(rng.uniform(0.05, 1.5)),
                       p_base=float(rng.uniform(0.0, 0.5)), rates=RATE_SET)


def _draw_fading(rng) -> FadingLink:
    return FadingLink(snr=float(rng.uniform(2.0, 50.0)), rates=RATE_SET)


def _draw_gilbert_elliott(rng) -> GilbertElliottLink:
    p_good = float(rng.uniform(0.0, 0.2))
    return GilbertElliottLink(
        p_gb=float(rng.uniform(0.01, 0.3)),
        p_bg=float(rng.uniform(0.2, 0.9)),
        p_good=p_good,
        p_bad=float(rng.uniform(p_good, 0.9)),
        beta=float(rng.uniform(0.05, 1.0)), rates=RATE_SET)


#: Synthetic device-class link factories, by model name (--models values).
LINK_FACTORIES = {
    "ideal": _draw_ideal,
    "erasure": _draw_erasure,
    "fading": _draw_fading,
    "gilbert_elliott": _draw_gilbert_elliott,
}

#: The full mixed-model catalogue (every built-in channel family).
ALL_MODELS = tuple(LINK_FACTORIES)


def make_montecarlo_objective(min_updates: int = 0, *, crn: bool = False,
                              seed_stream: str = "fold_in",
                              coarse_seeds=None, refine_rates=None,
                              coarse_strides=None, fine_radius=None,
                              coarse_updates=None) -> MonteCarloObjective:
    """Small deterministic ridge task (the canonical generator, scaled
    down) for Monte-Carlo objective serving.  ``min_updates`` floors the
    batched kernel's padded scan length so a service compiles ONE scan
    shape for every stream below the floor.  The keyword options expose
    the estimator/schedule knobs (common random numbers, RNG stream
    derivation, coarse seed counts, rate pruning, the multi-level stride
    schedule, the fine-window radius and the coarse-pass horizon cap) —
    they flow into the objective's ``cache_token``, so
    differently-configured services never alias cache entries."""
    from repro.data.synthetic import make_regression_dataset

    X, y, _ = make_regression_dataset(n=256, d=8, seed=0)
    return MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0,
                               min_updates=min_updates, crn=crn,
                               seed_stream=seed_stream,
                               coarse_seeds=coarse_seeds,
                               refine_rates=refine_rates,
                               coarse_strides=coarse_strides,
                               fine_radius=fine_radius,
                               coarse_updates=coarse_updates)


#: Planning-objective factories, by registry id (--objective values).
OBJECTIVE_FACTORIES = {
    "corollary1": BoundObjective,
    "markov_arq": MarkovARQObjective,
    "montecarlo": make_montecarlo_objective,
}

#: The full mixed-objective catalogue (every built-in objective).
ALL_OBJECTIVES = tuple(OBJECTIVE_FACTORIES)


def mc_update_floor(n_max: int) -> int:
    """The padded-scan-length floor covering every stream
    :func:`synth_requests` can draw under ``n_max``: update slots number
    ``floor(T / tau_p)`` with ``T < 3 N <= 3 n_max`` and
    ``tau_p >= 0.5``, rounded to the kernel's power-of-two padding."""
    return pow2ceil(max(1, int(6 * n_max)))


def resolve_objectives(spec, mc_min_updates: int = 0,
                       mc_options: Dict[str, Any] = None) -> Dict[str, Any]:
    """Instantiate the requested objectives ONCE each (instance identity
    keys the jitted Monte-Carlo kernel cache).  ``spec`` is "all", a
    comma-separated string, or a sequence of registry ids; unknown names
    raise ``ValueError`` with the available ids.  ``mc_min_updates``
    pins the Monte-Carlo scan-length floor (serving; see
    :func:`mc_update_floor`) and ``mc_options`` forwards estimator /
    schedule keywords to :func:`make_montecarlo_objective` (``crn``,
    ``seed_stream``, ``coarse_seeds``, ``refine_rates``,
    ``coarse_strides``, ``fine_radius``, ``coarse_updates``).
    """
    if spec == "all":
        names: Sequence[str] = ALL_OBJECTIVES
    elif isinstance(spec, str):
        names = tuple(s.strip() for s in spec.split(",") if s.strip())
    else:
        names = tuple(spec)
    unknown = [o for o in names if o not in OBJECTIVE_FACTORIES]
    if unknown:
        raise ValueError(
            f"unregistered planning objective(s) {unknown}; "
            f"available: {sorted(OBJECTIVE_FACTORIES)}")
    if not names:
        raise ValueError("no planning objective requested; "
                         f"available: {sorted(OBJECTIVE_FACTORIES)}")
    out: Dict[str, Any] = {}
    for name in names:
        if name == "montecarlo":
            out[name] = make_montecarlo_objective(mc_min_updates,
                                                  **(mc_options or {}))
        else:
            out[name] = OBJECTIVE_FACTORIES[name]()
    return out


def resolve_grid_modes(spec) -> Sequence[str]:
    """Validate a grid-mode mix: "all", one mode, or a comma list of
    :data:`repro.fleet.GRID_MODES`.  Unknown names raise ``ValueError``
    (the CLIs map that to exit code 2) — serving policies mix refined
    bound traffic with dense calibration traffic, and a typo silently
    falling back to one mode would skew both streams."""
    if spec == "all":
        return GRID_MODES
    names = (tuple(s.strip() for s in spec.split(",") if s.strip())
             if isinstance(spec, str) else tuple(spec))
    unknown = [m for m in names if m not in GRID_MODES]
    if unknown:
        raise ValueError(
            f"unknown grid mode(s) {unknown}; available: {list(GRID_MODES)}")
    if not names:
        raise ValueError(f"no grid mode requested; "
                         f"available: {list(GRID_MODES)}")
    return names


def parse_models(spec: str) -> Sequence[str]:
    """"all" or a comma-separated subset of :data:`ALL_MODELS` (unknown
    names are rejected downstream by :func:`synth_requests`)."""
    if spec == "all":
        return ALL_MODELS
    return tuple(m.strip() for m in spec.split(",") if m.strip())


def synth_requests(n: int, *, seed: int = 0, dup_frac: float = 0.5,
                   n_classes: int = 64,
                   models: Sequence[str] = ("erasure",),
                   n_max: int = 32768) -> List[Scenario]:
    """Heterogeneous request stream over a catalogue of device classes.

    ``dup_frac`` of the requests resample a previously seen class with
    tiny parameter jitter (below the cache's quantisation step), the rest
    draw a fresh class — so the achievable cache hit-rate is ~``dup_frac``.
    Each fresh class draws its link from one of ``models`` (keys of
    :data:`LINK_FACTORIES`) uniformly, so ``models=ALL_MODELS`` yields a
    stream mixing every channel family.  ``n_max`` caps the drawn dataset
    sizes — Monte-Carlo serving simulates the update timeline, so its
    streams use a small cap to bound the scan length.
    """
    unknown = [m for m in models if m not in LINK_FACTORIES]
    if unknown:
        raise ValueError(
            f"unknown link model name(s) {unknown}; "
            f"available: {sorted(LINK_FACTORIES)}")
    if n_max <= 256:
        raise ValueError(f"n_max must be > 256, got {n_max}")
    rng = np.random.default_rng(seed)
    classes: List[dict] = []

    def fresh_class() -> dict:
        N = int(rng.integers(256, n_max))
        return dict(
            N=N, T=float(rng.uniform(1.1, 3.0)) * N,
            n_o=float(rng.uniform(1.0, 1000.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
            link=LINK_FACTORIES[models[int(rng.integers(len(models)))]](rng),
            D=int(rng.choice([1, 1, 2, 4, 8])))

    out: List[Scenario] = []
    for _ in range(n):
        if classes and rng.random() < dup_frac:
            c = classes[int(rng.integers(len(classes)))]
        else:
            c = fresh_class()
            if len(classes) < n_classes:
                classes.append(c)
        jitter = 1.0 + rng.uniform(-1e-5, 1e-5)   # below quantisation step
        out.append(Scenario(
            N=c["N"], T=c["T"] * jitter, n_o=c["n_o"], tau_p=c["tau_p"],
            link=c["link"],
            topology=MultiDevice(c["D"]) if c["D"] > 1 else SingleDevice()))
    return out


def synth_population(n_devices: int, *, seed: int = 0,
                     models: Sequence[str] = ALL_MODELS,
                     n_max: int = 4096, deadline_frac: float = 1.6):
    """Synthetic federated-round candidate population.

    Draws ``n_devices`` heterogeneous devices (dataset size, overhead,
    update period and a link from ``models`` — Gilbert-Elliott rows are
    the natural stragglers) and one SHARED round deadline
    ``deadline_frac * median(N)``; every scenario carries the deadline as
    its own ``T``, so :meth:`RoundPlanner.resolve_deadline` (the
    population minimum) recovers it.  Returns ``(population, deadline)``.
    Unknown model names raise ``ValueError`` (CLIs map that to exit 2).
    """
    unknown = [m for m in models if m not in LINK_FACTORIES]
    if unknown:
        raise ValueError(
            f"unknown link model name(s) {unknown}; "
            f"available: {sorted(LINK_FACTORIES)}")
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if n_max <= 256:
        raise ValueError(f"n_max must be > 256, got {n_max}")
    rng = np.random.default_rng(seed)
    Ns = [int(rng.integers(256, n_max)) for _ in range(n_devices)]
    deadline = float(deadline_frac) * float(np.median(Ns))
    population = [
        Scenario(N=N, T=deadline, n_o=float(rng.uniform(1.0, 1000.0)),
                 tau_p=float(rng.choice([0.5, 1.0, 2.0])),
                 link=LINK_FACTORIES[
                     models[int(rng.integers(len(models)))]](rng))
        for N in Ns]
    return population, deadline
