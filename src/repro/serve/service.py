"""The always-on planning service: warmup, continuous batching, drift.

:class:`PlanningService` is the long-lived front end over the fleet
planning engine — the piece that turns "a fast batched solver"
(:class:`~repro.fleet.planner.FleetPlanner`) into "a service an edge
population talks to":

  1. **Ingestion + continuous batching** — :meth:`submit` enqueues from
     any thread and returns a future; the
     :class:`~repro.serve.batcher.MicroBatcher` worker flushes
     size-or-deadline micro-batches grouped by (objective, grid mode)
     and pads each group to a configured power-of-two BUCKET, so the
     whole request stream exercises a small, fixed set of kernel shapes.
  2. **Bucketed AOT warmup** — :meth:`warmup` sweeps
     ``FleetPlanner.warm`` over every configured (objective, grid mode,
     bucket), compiling the dense solve, the coarse pass and every
     reachable pow2 fine-pass width up front.  After warmup NO request
     pays a ``jax.jit`` trace — audited end to end by the
     :mod:`repro.fleet.tracing` counters, surfaced per bucket in
     :meth:`stats`, and asserted by the serving tests and CI smoke.
  3. **Admission policy** — requests that don't name an objective/mode
     are routed by a pluggable policy (:mod:`repro.serve.policy`), e.g.
     exact burst-aware ``markov_arq`` for sticky Gilbert-Elliott links
     and refined ``corollary1`` under backpressure.
  4. **Drift-triggered re-planning** — devices open sessions and stream
     observed per-attempt loss outcomes in (:meth:`observe`); when a
     session's loss EWMA drifts past the threshold, the service
     re-estimates the link (:func:`repro.serve.sessions.reestimate_link`),
     INVALIDATES the prefix-keyed cache entry the stale plan lives at,
     and re-enqueues the corrected scenario through the same batcher.
  5. **Observability** — every request leaves a
     :class:`~repro.obs.spans.RequestSpan` decomposing its
     enqueue-to-plan latency exactly into batch-wait / pad / cache-lookup
     / solve / resolve phases (with the solve's device portion fenced by
     ``block_until_ready``); latencies aggregate into mergeable
     log-histograms per (objective, grid mode, bucket); drift and
     session lifecycle events land in a JSONL-exportable audit journal;
     and ``service.metrics`` — a :class:`~repro.obs.metrics\
     .MetricsRegistry` over the stats recorder, the plan cache, the
     kernel trace counters, the span totals and the journal — renders
     the whole picture as Prometheus text exposition in one call.

Plans are bitwise-identical to direct ``FleetPlanner.plan_batch`` calls:
the service adds routing, batching and caching around the solver, never
arithmetic.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.chaos import FaultPlan, parse_chaos_spec
from repro.core.bounds import BoundConstants
from repro.core.scenario import Scenario
from repro.federated.round import (FEDERATED_TOKEN, RoundPlanner,
                                   RoundRecord, population_key)
from repro.fleet import GRID_MODES, MC_IMPLS, FleetPlanner, PlanCache
from repro.fleet.objective_kernels import pow2ceil
from repro.fleet.tracing import trace_delta
from repro.obs import (EventJournal, MetricsRegistry, RequestSpan,
                       SpanRecorder, solve_delta)
from repro.serve import export
from repro.serve.batcher import MicroBatcher, PlanRequest, QueueFull
from repro.serve.catalogue import (ALL_MODELS, FEDERATED_KIND,
                                   default_consts, mc_update_floor,
                                   resolve_objectives, synth_population,
                                   synth_requests)
from repro.serve.policy import policy_spec
from repro.serve.resilience import (DegradationExhausted, HealthReport,
                                    RequestShed, ResilienceManager,
                                    RetryPolicy)
from repro.serve.sessions import Session, SessionTracker, reestimate_link
from repro.serve.stats import FederatedRecorder, ServiceStats, StatsRecorder


@dataclass(frozen=True)
class ServiceConfig:
    """Static configuration of a :class:`PlanningService`.

    ``batch_buckets`` are the micro-batch pad shapes (ascending powers
    of two; the largest is also the flush size ``max_batch``) — the
    complete set of batch lengths the service will ever compile.
    ``objective_ids`` name the served objectives (``montecarlo`` is
    opt-in: its simulated scan makes warmup cost scale with ``n_max``).
    ``n_max`` bounds the dataset sizes the service expects — it sizes
    the Monte-Carlo scan-length floor so MC streams compile ONE scan
    shape — and ``grid_modes`` restricts which solve strategies the
    admission layer may hand out.

    The ``mc_*`` knobs configure the served Monte-Carlo objective and
    engine: ``mc_impl`` selects the simulation engine (``"auto"`` /
    ``"scan"`` / ``"pallas"``; "auto" resolves by backend), ``mc_crn``
    turns on the common-random-numbers estimator, ``mc_seed_stream``
    picks the per-run RNG derivation, and ``mc_coarse_seeds`` /
    ``mc_refine_rates`` / ``mc_coarse_strides`` / ``mc_fine_radius`` /
    ``mc_coarse_updates`` install the refine-mode seed/rate/stride/
    window/horizon schedules.  All of them flow into the objective's
    cache token (and the engine into the planner's cache-context
    prefix), so differently-configured services never alias entries.
    """

    grid_size: int = 64
    batch_buckets: Tuple[int, ...] = (64, 256)
    flush_interval: float = 0.01
    objective_ids: Tuple[str, ...] = ("corollary1", "markov_arq")
    grid_modes: Tuple[str, ...] = GRID_MODES
    mc_impl: str = "auto"
    mc_crn: bool = False
    mc_seed_stream: str = "fold_in"
    mc_coarse_seeds: Optional[int] = None
    mc_refine_rates: Optional[int] = None
    mc_coarse_strides: Optional[Tuple[int, ...]] = None
    mc_fine_radius: Optional[int] = None
    mc_coarse_updates: Optional[int] = None
    policy_id: str = "link_aware"
    cache_size: int = 8192
    sig_digits: int = 3
    n_max: int = 32768
    drift_threshold: float = 0.1
    ewma_alpha: float = 0.05
    min_observations: int = 20
    shard: bool = True
    warm_models: Tuple[str, ...] = ALL_MODELS
    #: federated-round population pad shapes (ascending powers of two).
    #: Empty (the default) leaves the round path cold: ``submit_round``
    #: still works, but the first round at each population shape pays a
    #: trace.  Non-empty buckets are AOT-warmed like batch buckets, so
    #: round requests inside the largest bucket hit compiled code only.
    population_buckets: Tuple[int, ...] = ()
    #: span ring capacity (lifetime phase TOTALS are kept regardless;
    #: the ring holds the most recent complete traces)
    span_capacity: int = 8192
    #: event-journal ring capacity (per-kind counts are lifetime)
    journal_capacity: int = 4096
    #: when set, every journal event is also appended to this JSONL file
    journal_path: Optional[str] = None
    #: journal file rotation: rotate at ``journal_max_bytes`` (0 = never),
    #: keeping ``journal_keep`` rotated files; ``journal_fsync`` makes
    #: every appended event durable (fsync per flush) — the crash-journal
    #: posture, off by default because it serialises on disk latency
    journal_max_bytes: int = 0
    journal_keep: int = 3
    journal_fsync: bool = False
    #: ingestion-queue bound (0 = unbounded): a full queue SHEDS new
    #: submits (RequestShed) instead of growing without limit
    max_pending: int = 0
    #: default enqueue-to-plan budget applied to submits that don't
    #: carry one (None = unbudgeted); the degradation ladder fires when
    #: the estimated solve would overrun what remains of the budget
    default_budget_s: Optional[float] = None
    #: transient-solve retry: total attempts per chunk, then the
    #: decorrelated-jitter backoff's base/cap (seconds)
    retry_attempts: int = 3
    retry_base_s: float = 0.02
    retry_cap_s: float = 0.5
    #: per-(objective, grid_mode) circuit breaker: consecutive failures
    #: to trip, and the open->half-open probe cooldown (seconds)
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 1.0
    #: solve-time estimate used against budgets: histogram quantile and
    #: a safety multiplier on top of it
    budget_quantile: float = 90.0
    budget_safety: float = 1.0
    #: sessions with a pending drift re-plan before health reports
    #: DEGRADED
    health_drift_backlog: int = 8
    #: deterministic fault injection (repro.chaos.parse_chaos_spec
    #: grammar); None/empty = chaos-free
    chaos_spec: Optional[str] = None

    def __post_init__(self):
        if not self.batch_buckets:
            raise ValueError("batch_buckets must name >= 1 bucket")
        for b in self.batch_buckets:
            if b < 1 or pow2ceil(int(b)) != int(b):
                raise ValueError(
                    f"batch_buckets must be powers of two, got "
                    f"{self.batch_buckets}")
        if tuple(sorted(self.batch_buckets)) != tuple(self.batch_buckets):
            raise ValueError(
                f"batch_buckets must ascend, got {self.batch_buckets}")
        for b in self.population_buckets:
            if b < 1 or pow2ceil(int(b)) != int(b):
                raise ValueError(
                    f"population_buckets must be powers of two, got "
                    f"{self.population_buckets}")
        if tuple(sorted(self.population_buckets)) != \
                tuple(self.population_buckets):
            raise ValueError(
                f"population_buckets must ascend, got "
                f"{self.population_buckets}")
        unknown = [m for m in self.grid_modes if m not in GRID_MODES]
        if unknown:
            raise ValueError(
                f"unknown grid mode(s) {unknown}; valid: {list(GRID_MODES)}")
        if not self.grid_modes:
            raise ValueError("grid_modes must name >= 1 mode")
        if self.mc_impl not in MC_IMPLS:
            raise ValueError(
                f"unknown mc_impl {self.mc_impl!r}; valid: {MC_IMPLS}")
        if self.max_pending < 0:
            raise ValueError(
                f"max_pending must be >= 0, got {self.max_pending}")
        if self.default_budget_s is not None and self.default_budget_s < 0:
            raise ValueError(
                f"default_budget_s must be >= 0, got "
                f"{self.default_budget_s}")
        if self.retry_attempts < 1:
            raise ValueError(
                f"retry_attempts must be >= 1, got {self.retry_attempts}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got "
                f"{self.breaker_threshold}")
        if self.journal_max_bytes < 0 or self.journal_keep < 1:
            raise ValueError(
                f"need journal_max_bytes >= 0 and journal_keep >= 1, got "
                f"{self.journal_max_bytes}/{self.journal_keep}")

    @property
    def max_batch(self) -> int:
        return int(self.batch_buckets[-1])


class PlanningService:
    """Long-lived planning service over the fleet engine (see module
    docstring).  Lifecycle: ``warmup()`` (optional but what the
    zero-trace SLO needs) -> ``start()`` -> ``submit``/``open_session``/
    ``observe`` from any thread -> ``stop()`` (drains by default).  Also
    a context manager: ``with PlanningService() as svc: ...`` starts and
    drains it."""

    def __init__(self, config: Optional[ServiceConfig] = None,
                 consts: Optional[BoundConstants] = None, *,
                 objectives: Optional[Dict[str, Any]] = None,
                 policy: Any = None, faults: Optional[FaultPlan] = None):
        self.config = config if config is not None else ServiceConfig()
        self.consts = consts if consts is not None else default_consts()
        self.consts.validate()
        cfg = self.config
        if faults is None and cfg.chaos_spec:
            faults = parse_chaos_spec(cfg.chaos_spec)
        self.faults = faults
        # pow2 refine widths: the width set becomes enumerable, which is
        # what lets warmup() cover EVERY shape the stream can reach
        self.planner = FleetPlanner(grid_size=cfg.grid_size,
                                    shard=cfg.shard,
                                    pow2_refine_widths=True,
                                    mc_impl=cfg.mc_impl)
        corruptor = None
        if faults is not None and faults.enabled("cache.corrupt"):
            corruptor = (
                lambda: faults.draw("cache.corrupt") is not None)
        self.cache = PlanCache(maxsize=cfg.cache_size,
                               sig_digits=cfg.sig_digits,
                               checksums=faults is not None,
                               corruptor=corruptor)
        if objectives is not None:
            self.objectives = dict(objectives)
        else:
            self.objectives = resolve_objectives(
                cfg.objective_ids,
                mc_min_updates=(mc_update_floor(cfg.n_max)
                                if "montecarlo" in cfg.objective_ids
                                else 0),
                mc_options=dict(crn=cfg.mc_crn,
                                seed_stream=cfg.mc_seed_stream,
                                coarse_seeds=cfg.mc_coarse_seeds,
                                refine_rates=cfg.mc_refine_rates,
                                coarse_strides=cfg.mc_coarse_strides,
                                fine_radius=cfg.mc_fine_radius,
                                coarse_updates=cfg.mc_coarse_updates))
        self.policy = policy if policy is not None \
            else policy_spec(cfg.policy_id).cls()
        self.round_planner = RoundPlanner(grid_size=cfg.grid_size,
                                          shard=cfg.shard)
        self.federated = FederatedRecorder()
        self.sessions = SessionTracker(
            drift_threshold=cfg.drift_threshold,
            ewma_alpha=cfg.ewma_alpha,
            min_observations=cfg.min_observations)
        self.recorder = StatsRecorder()
        self.spans = SpanRecorder(capacity=cfg.span_capacity)
        self.journal = EventJournal(capacity=cfg.journal_capacity,
                                    path=cfg.journal_path,
                                    max_bytes=cfg.journal_max_bytes,
                                    keep=cfg.journal_keep,
                                    fsync=cfg.journal_fsync)
        self.batcher = MicroBatcher(self._plan_group,
                                    max_batch=cfg.max_batch,
                                    flush_interval=cfg.flush_interval,
                                    max_pending=cfg.max_pending,
                                    faults=faults)
        self.resilience = ResilienceManager(
            retry=RetryPolicy(attempts=cfg.retry_attempts,
                              base_s=cfg.retry_base_s,
                              cap_s=cfg.retry_cap_s,
                              seed=faults.seed if faults else 0),
            breaker_threshold=cfg.breaker_threshold,
            breaker_cooldown_s=cfg.breaker_cooldown_s,
            budget_quantile=cfg.budget_quantile,
            budget_safety=cfg.budget_safety,
            journal=self.journal, faults=faults)
        # the degradation ladder's "bound" rung: the cheap dense
        # Corollary-1 solve.  Reuse the SERVED corollary1 instance when
        # there is one — objective identity keys the jitted executables,
        # so reuse is what keeps the fallback inside the warmed shapes.
        self._fallback_objective = self.objectives.get("corollary1")
        if self._fallback_objective is None:
            self._fallback_objective = \
                resolve_objectives(("corollary1",))["corollary1"]
        self.metrics = MetricsRegistry()
        export.register_service_sources(self.metrics, self)
        self._lock = threading.Lock()
        self.warmed = False
        self.warmup_traces = 0
        self.warmup_seconds = 0.0

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "PlanningService":
        self.batcher.start()
        return self

    def stop(self, drain: bool = True) -> None:
        self.batcher.stop(drain=drain)

    def __enter__(self) -> "PlanningService":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)

    def warmup(self, scenarios: Optional[Sequence[Scenario]] = None) -> int:
        """AOT-compile every (objective, grid mode, bucket) executable
        the configuration admits; returns the total trace count it cost.

        ``scenarios`` fixes the warm batch signature (rate width, update
        counts); the default draws a small synthetic mix over
        ``config.warm_models``.  Restarts the stats clock afterwards so
        reported throughput is steady-state serving, not compilation.
        """
        cfg = self.config
        if scenarios is None:
            scenarios = synth_requests(
                min(8, cfg.batch_buckets[0]), seed=0, dup_frac=0.0,
                models=cfg.warm_models, n_max=cfg.n_max)
        scenarios = list(scenarios)
        t0 = time.perf_counter()
        total = 0
        for oid, objective in self.objectives.items():
            for mode in cfg.grid_modes:
                for bucket in cfg.batch_buckets:
                    traces = self.planner.warm(
                        scenarios[:bucket], self.consts,
                        objective=objective, grid_mode=mode,
                        pad_to=bucket)
                    total += traces
                    self.recorder.record_bucket(oid, mode, bucket,
                                                compiles=traces)
        # the degradation ladder's "bound" rung solves (corollary1,
        # dense) at the same chunk shapes — warm it when the configured
        # sweep above didn't already cover that exact objective instance,
        # so a degraded request never pays a post-warmup trace
        fallback_covered = (
            self._fallback_objective is self.objectives.get("corollary1")
            and "dense" in cfg.grid_modes)
        if not fallback_covered:
            for bucket in cfg.batch_buckets:
                traces = self.planner.warm(
                    scenarios[:bucket], self.consts,
                    objective=self._fallback_objective, grid_mode="dense",
                    pad_to=bucket)
                total += traces
                self.recorder.record_bucket("corollary1", "dense", bucket,
                                            compiles=traces)
        if cfg.population_buckets:
            # federated rounds use the catalogue rate set too, but draw
            # through synth_population so the warm batch carries the
            # round-request signature (shared deadline, D = 1)
            pop, _ = synth_population(cfg.population_buckets[0], seed=0,
                                      models=cfg.warm_models,
                                      n_max=min(cfg.n_max, 4096))
            for bucket in cfg.population_buckets:
                traces = self.round_planner.warm(
                    pop[:bucket], self.consts, pad_to=bucket)
                total += traces
                self.recorder.record_bucket(FEDERATED_KIND, "dense",
                                            bucket, compiles=traces)
        self.warmup_seconds = time.perf_counter() - t0
        self.warmup_traces = total
        self.warmed = True
        self.journal.emit("warmup", traces=total,
                          seconds=round(self.warmup_seconds, 6),
                          objectives=sorted(self.objectives),
                          grid_modes=list(cfg.grid_modes),
                          buckets=list(cfg.batch_buckets),
                          population_buckets=list(cfg.population_buckets))
        self.recorder.restart_clock()
        return total

    # -- request path -------------------------------------------------------

    def _resolve_objective(self, objective) -> Tuple[str, Any]:
        """(objective_id, instance) for an instance, a registry id, or
        ``None`` (caller routes through the admission policy first)."""
        if isinstance(objective, str):
            inst = self.objectives.get(objective)
            if inst is None:
                raise KeyError(
                    f"objective {objective!r} is not served; configured: "
                    f"{sorted(self.objectives)}")
            return objective, inst
        oid = getattr(objective, "objective_id", None)
        if oid is None:
            raise TypeError(
                f"{type(objective).__name__} is not a registered planning "
                "objective (no objective_id)")
        return str(oid), objective

    def _admit(self, scenario: Scenario, objective, grid_mode):
        """Fill whichever of (objective, grid_mode) the caller left to
        the admission policy, and validate the result.  The fourth
        element is the admission ACTION ("accept"/"shed") — policies
        only decide it for requests they actually routed."""
        cfg = self.config
        action = "accept"
        if objective is None or grid_mode is None:
            load = self.batcher.depth / cfg.max_batch
            decision = self.policy.admit(scenario, load=load)
            action = getattr(decision, "action", "accept")
            if objective is None:
                objective = decision.objective_id
            if grid_mode is None:
                grid_mode = decision.grid_mode
        oid, inst = self._resolve_objective(objective)
        if grid_mode not in cfg.grid_modes:
            raise ValueError(
                f"grid mode {grid_mode!r} is not served; configured: "
                f"{list(cfg.grid_modes)}")
        return oid, inst, grid_mode, action

    def submit(self, scenario: Scenario, *, objective: Any = None,
               grid_mode: Optional[str] = None,
               session_id: Optional[str] = None,
               budget_s: Optional[float] = None) -> "Future":
        """Enqueue one planning request; returns a future resolving to
        its :class:`~repro.fleet.planner.PlanRecord`.  ``objective`` may
        be a served instance, a registry id, or ``None``/``grid_mode``
        ``None`` to let the admission policy decide.  ``budget_s`` caps
        the enqueue-to-plan latency (default from the config): requests
        the service can't solve inside the budget degrade along the
        fallback ladder instead of arriving late.

        Raises :class:`~repro.serve.resilience.RequestShed` when the
        admission policy sheds the request or the bounded ingestion
        queue is full — explicit rejection, never silent queuing past
        capacity."""
        t_admit = time.perf_counter()
        _, inst, mode, action = self._admit(scenario, objective, grid_mode)
        if action == "shed":
            self.recorder.count("shed")
            self.resilience.note_shed("policy")
            raise RequestShed("admission policy shed the request "
                              f"(queue depth {self.batcher.depth})")
        admit_s = time.perf_counter() - t_admit
        if budget_s is None:
            budget_s = self.config.default_budget_s
        request = PlanRequest(scenario=scenario, objective=inst,
                              grid_mode=mode, session_id=session_id,
                              admit_s=admit_s, budget_s=budget_s)
        try:
            self.batcher.submit(request)
        except QueueFull as exc:
            self.recorder.count("shed")
            self.resilience.note_shed("queue_full")
            raise RequestShed(str(exc)) from None
        self.recorder.count("requests")
        return request.future

    def _population_bucket(self, n: int) -> int:
        """The pad shape for an ``n``-device round: the smallest
        configured population bucket that fits, else (an unwarmed
        population size) the next power of two."""
        for b in self.config.population_buckets:
            if n <= b:
                return int(b)
        return pow2ceil(n)

    def submit_round(self, population: Sequence[Scenario], *,
                     deadline: Optional[float] = None) -> RoundRecord:
        """Plan one federated round over a candidate population —
        synchronous (a round is a population-level decision, not a
        per-device stream; there is nothing to micro-batch it with).

        The population is padded to the smallest configured
        ``population_buckets`` entry that fits (so warmed services pay
        zero traces), solved by the shared :class:`~repro.federated.
        round.RoundPlanner`, and cached under ``(round context,
        FEDERATED_TOKEN, population_key)`` in the same
        :class:`~repro.fleet.PlanCache` as per-device plans — the key
        shapes guarantee a round entry can never alias one (see
        ``PlanCache.get_by_key``).  Returns the round's
        :class:`~repro.federated.round.RoundRecord`.
        """
        t_start = time.perf_counter()
        population = list(population)
        if not population:
            raise ValueError("population must be non-empty")
        if deadline is None:
            deadline = self.round_planner.resolve_deadline(population)
        bucket = self._population_bucket(len(population))
        key = (self.round_planner.cache_context(self.consts),
               FEDERATED_TOKEN,
               population_key(population, deadline,
                              self.config.sig_digits))
        self.recorder.count("round_requests")
        record = self.cache.get_by_key(key, label=FEDERATED_KIND)
        if record is None:
            with trace_delta() as traces, solve_delta():
                plan = self.round_planner.plan_round(
                    population, self.consts, deadline=deadline,
                    pad_to=bucket)
            record = plan.record()
            self.cache.put_by_key(key, record)
            self.recorder.record_bucket(FEDERATED_KIND, "dense", bucket,
                                        requests=1, batches=1,
                                        compiles=traces.total)
            if traces.total and self.warmed:
                self.recorder.count("post_warmup_traces", traces.total)
        else:
            self.recorder.record_bucket(FEDERATED_KIND, "dense", bucket,
                                        requests=1)
        latency = time.perf_counter() - t_start
        self.recorder.count("planned")
        self.recorder.record_latency(latency,
                                     key=(FEDERATED_KIND, "dense", bucket))
        self.federated.observe(record, latency)
        self.journal.emit("federated_round", devices=len(population),
                          bucket=bucket, k=record.n_participants,
                          eligible=record.n_eligible,
                          feasible=record.feasible,
                          deadline=round(float(deadline), 6))
        return record

    def _chunk_buckets(self, n: int):
        """Greedy bucket cover of ``n`` requests: repeatedly the largest
        configured bucket that fits, then one padded smallest bucket for
        the remainder — so a 100-request group costs 64+64 solve lanes,
        not a single 256-lane solve (wasted pad lanes are bounded by the
        smallest bucket, and every chunk shape is a warmed executable)."""
        buckets = self.config.batch_buckets
        out = []
        while n > 0:
            b = next((b for b in reversed(buckets) if b <= n), buckets[0])
            out.append(int(b))
            n -= min(int(b), n)
        return out

    def _plan_group(self, requests) -> None:
        """Worker-side: solve one (objective, grid mode)-homogeneous
        micro-batch through the cache, resolve its futures, and record
        one :class:`RequestSpan` per request.

        Phase attribution: every phase is a contiguous interval cut from
        the same ``perf_counter`` timeline — ``batch_wait`` (enqueue ->
        chunk start, per request), then the chunk-shared ``pad`` /
        ``cache_lookup`` / ``solve`` (``plan_many`` reports the latter
        two; ``pad`` is its remaining interior: batch formation and pad
        lanes) and ``resolve`` (everything after ``plan_many`` returns:
        session delivery and future resolution, defined as the remainder
        so the five phases sum EXACTLY to the enqueue-to-plan latency).
        """
        objective = requests[0].objective
        mode = requests[0].grid_mode
        oid, _ = self._resolve_objective(objective)
        res = self.resilience

        # Resilience triage: budget-exhausted requests degrade instead
        # of solving late, and an open breaker routes the whole group to
        # the ladder (allow() is also what promotes open -> half-open
        # after the cooldown, making this solve the probe).  With no
        # budgets, no faults, and a closed breaker this adds nothing to
        # the path: same plan_many, bitwise-identical records.
        degraded = []  # (request, reason) pairs for the ladder
        solve_reqs, over_budget = res.split_over_budget(requests, oid, mode)
        degraded.extend((r, "budget") for r in over_budget)
        if solve_reqs and not res.breaker(oid, mode).allow():
            degraded.extend((r, "breaker_open") for r in solve_reqs)
            solve_reqs = []

        lo = 0
        for bucket in (self._chunk_buckets(len(solve_reqs))
                       if solve_reqs else ()):
            chunk = solve_reqs[lo:lo + bucket]
            lo += len(chunk)
            try:
                self._solve_chunk(oid, mode, bucket, chunk, objective)
            except Exception:  # noqa: BLE001 — retries exhausted: degrade
                degraded.extend((r, "solve_failed") for r in chunk)
        if degraded:
            self._degrade_requests(oid, mode, objective, degraded)

    def _solve_chunk(self, oid: str, mode: str, bucket: int, chunk,
                     objective) -> None:
        """Solve one padded chunk (under retry/fault injection), resolve
        its futures, and record its spans.  Raises once retries are
        exhausted — the caller sends the chunk down the ladder."""
        res = self.resilience
        t_chunk = time.perf_counter()
        timings: Dict[str, float] = {}

        def _attempt():
            timings.clear()
            return self.planner.plan_many(
                [r.scenario for r in chunk], self.consts,
                cache=self.cache, pad_to=bucket, objective=objective,
                grid_mode=mode, timings=timings)

        with trace_delta() as traces, solve_delta() as solve:
            records = res.run_attempts(oid, mode, _attempt)
        t_planned = time.perf_counter()
        self.recorder.record_bucket(oid, mode, bucket,
                                    requests=len(chunk), batches=1,
                                    compiles=traces.total)
        self.recorder.count("batches")
        self.recorder.count("planned", len(chunk))
        if traces.total and self.warmed:
            self.recorder.count("post_warmup_traces", traces.total)
        for request, record in zip(chunk, records):
            if request.session_id is not None:
                self._deliver_to_session(request.session_id, record)
            request.future.set_result(record)
        t_end = time.perf_counter()

        cache_s = timings.get("cache_lookup_s", 0.0)
        solve_s = timings.get("solve_s", 0.0)
        res.estimator.observe(oid, mode, solve_s)
        if records:
            res.note_last_good(oid, mode, records[-1])
        pad_s = max(0.0, (t_planned - t_chunk) - cache_s - solve_s)
        resolve_s = max(0.0, (t_end - t_chunk)
                        - (pad_s + cache_s + solve_s))
        device_s = min(solve.device_s, solve_s)
        key = (oid, mode, bucket)
        for request in chunk:
            latency = t_end - request.enqueue_t
            self.recorder.record_latency(latency, key=key)
            self.spans.record(RequestSpan(
                objective=oid, grid_mode=mode, bucket=bucket,
                enqueue_t=request.enqueue_t,
                admit_s=request.admit_s,
                batch_wait_s=t_chunk - request.enqueue_t,
                pad_s=pad_s, cache_lookup_s=cache_s,
                solve_s=solve_s, solve_device_s=device_s,
                resolve_s=resolve_s, latency_s=latency))

    def _finish_degraded(self, request, record, oid: str, mode: str,
                         t_start: float) -> None:
        """Resolve one degraded request: deliver, count, span (bucket 0
        marks ladder-served requests; phases still sum to latency)."""
        if request.session_id is not None:
            self._deliver_to_session(request.session_id, record)
        request.future.set_result(record)
        t_end = time.perf_counter()
        latency = t_end - request.enqueue_t
        self.recorder.count("planned")
        self.recorder.count("degraded")
        self.recorder.record_latency(latency, key=(oid, mode, 0))
        batch_wait = max(0.0, t_start - request.enqueue_t)
        self.spans.record(RequestSpan(
            objective=oid, grid_mode=mode, bucket=0,
            enqueue_t=request.enqueue_t, admit_s=request.admit_s,
            batch_wait_s=batch_wait, pad_s=0.0, cache_lookup_s=0.0,
            solve_s=0.0, solve_device_s=0.0,
            resolve_s=max(0.0, latency - batch_wait),
            latency_s=latency))

    def _degrade_requests(self, oid: str, mode: str, objective,
                          pairs) -> None:
        """Walk the fallback ladder for requests that can't take (or
        survived retries of) the real solve: cached -> bound ->
        last_good, stamping and counting the level that answered.  A
        request only errors (DegradationExhausted) when every rung comes
        up empty — the 100%-completion guarantee under chaos."""
        res = self.resilience
        t_start = time.perf_counter()
        context = self.planner.cache_context(self.consts, mode)
        remaining = []
        for request, reason in pairs:
            cached = self.cache.peek(request.scenario, context=context,
                                     objective=objective)
            if cached is not None:
                res.count_fallback("cached", reason)
                self._finish_degraded(
                    request,
                    dataclasses.replace(cached, fallback="cached"),
                    oid, mode, t_start)
            else:
                remaining.append((request, reason))
        if not remaining:
            return
        # bound rung: batched dense Corollary-1 at warmed chunk shapes
        try:
            lo = 0
            for bucket in self._chunk_buckets(len(remaining)):
                chunk = remaining[lo:lo + bucket]
                lo += len(chunk)
                with trace_delta() as traces:
                    records = self.planner.plan_many(
                        [r.scenario for r, _ in chunk], self.consts,
                        cache=self.cache, pad_to=bucket,
                        objective=self._fallback_objective,
                        grid_mode="dense")
                self.recorder.record_bucket(
                    "corollary1", "dense", bucket,
                    requests=len(chunk), batches=1, compiles=traces.total)
                if traces.total and self.warmed:
                    self.recorder.count("post_warmup_traces", traces.total)
                for (request, reason), record in zip(chunk, records):
                    res.count_fallback("bound", reason)
                    self._finish_degraded(
                        request,
                        dataclasses.replace(record, fallback="bound"),
                        oid, mode, t_start)
            return
        except Exception:  # noqa: BLE001 — bound rung failed: last rung
            pass
        last = res.last_good(oid, mode)
        for request, reason in remaining:
            if request.future.done():
                continue
            if last is not None:
                res.count_fallback("last_good", reason)
                self._finish_degraded(
                    request,
                    dataclasses.replace(last, fallback="last_good"),
                    oid, mode, t_start)
            else:
                res.note_exhausted()
                request.future.set_exception(DegradationExhausted(
                    f"no fallback available for ({oid}, {mode}): "
                    f"reason={reason}"))

    # -- sessions and drift -------------------------------------------------

    def open_session(self, session_id: str, scenario: Scenario, *,
                     objective: Any = None,
                     grid_mode: Optional[str] = None) -> "Future":
        """Register a live session and enqueue its first plan.  The
        returned future resolves to the initial plan; the session keeps
        tracking the latest one (``service.session(id).plan``)."""
        _, inst, mode, _ = self._admit(scenario, objective, grid_mode)
        session = Session(session_id=session_id, scenario=scenario,
                          objective=inst, grid_mode=mode)
        self.sessions.open(session)
        session.replan_pending = True
        self.journal.emit("session_open", session_id=session_id,
                          objective=getattr(inst, "objective_id", None),
                          grid_mode=mode)
        return self.submit(scenario, objective=inst, grid_mode=mode,
                           session_id=session_id)

    def session(self, session_id: str) -> Session:
        return self.sessions.get(session_id)

    def close_session(self, session_id: str) -> Optional[Session]:
        session = self.sessions.close(session_id)
        if session is not None:
            self.journal.emit("session_close", session_id=session_id,
                              generation=session.generation,
                              replans=session.replans,
                              observations=session.n_observations)
        return session

    def _deliver_to_session(self, session_id: str, record) -> None:
        try:
            session = self.sessions.get(session_id)
        except KeyError:
            return  # closed while its plan was in flight
        with self._lock:
            session.plan = record
            session.generation += 1
            session.replan_pending = False

    def observe(self, session_id: str, losses) -> Optional["Future"]:
        """Stream a session's observed per-attempt loss outcomes
        (iterable of bools, e.g. sampled from
        ``link.make_loss_process``).  When the observed EWMA drifts past
        the threshold, re-estimates the link, invalidates the stale
        prefix-keyed cache entry and re-enqueues the corrected scenario
        — returning the re-plan future (else ``None``)."""
        session = self.sessions.get(session_id)
        session.observe(losses)
        if not self.sessions.drifted(session):
            return None
        self.recorder.count("drift_detected")
        self.journal.emit("drift_detected", session_id=session_id,
                          ewma=round(session.ewma, 6),
                          planned_p_err=round(session.plan.p_err, 6))
        new_link = reestimate_link(session.scenario.link,
                                   session.plan.rate, session.ewma)
        if new_link is None:
            self.recorder.count("drift_unactionable")
            self.journal.emit("drift_unactionable", session_id=session_id,
                              ewma=round(session.ewma, 6))
            return None
        with self._lock:
            if session.replan_pending:
                return None  # a racing observe already re-enqueued
            session.replan_pending = True
            session.replans += 1
            stale = session.scenario
            session.scenario = dataclasses.replace(stale, link=new_link)
        # drop the stale plan for EVERY session collapsing onto this
        # quantised key — the whole device class drifted, not one radio
        context = self.planner.cache_context(self.consts, session.grid_mode)
        self.cache.invalidate(stale, context=context,
                              objective=session.objective)
        self.recorder.count("drift_replans")
        self.journal.emit("drift_replan", session_id=session_id,
                          replans=session.replans,
                          ewma=round(session.ewma, 6))
        return self.submit(session.scenario, objective=session.objective,
                           grid_mode=session.grid_mode,
                           session_id=session_id)

    # -- observability ------------------------------------------------------

    def health(self) -> HealthReport:
        """STARTING/READY/DEGRADED/SHEDDING readiness, derived from
        warmup state, queue depth vs the bound, breaker states, and the
        drift re-plan backlog.  State changes land in the journal."""
        return self.resilience.health(
            warmed=self.warmed,
            queue_depth=self.batcher.depth,
            max_pending=self.config.max_pending,
            drift_backlog=self.sessions.pending_replans(),
            drift_backlog_limit=self.config.health_drift_backlog)

    def stats(self) -> ServiceStats:
        self.recorder.count("sessions_open", 0)  # ensure key exists
        snapshot = self.recorder.snapshot(queue_depth=self.batcher.depth,
                                          cache_stats=self.cache.stats())
        snapshot.counters["sessions_open"] = len(self.sessions)
        snapshot.counters["idle_ticks"] = self.batcher.idle_ticks
        snapshot.counters.setdefault("post_warmup_traces", 0)
        snapshot.counters.setdefault("shed", 0)
        snapshot.counters.setdefault("degraded", 0)
        snapshot.counters["warmup_traces"] = self.warmup_traces
        for cause, n in self.batcher.flush_causes.items():
            snapshot.counters[f"flushes_{cause}"] = n
        return dataclasses.replace(
            snapshot, phases=self.spans.totals(),
            solve_fraction=self.spans.solve_fraction,
            resilience=self.resilience.snapshot())

    def prometheus_text(self) -> str:
        """The full Prometheus text exposition across every source."""
        return self.metrics.prometheus_text()

    def metrics_snapshot(self) -> Dict[str, Dict[tuple, float]]:
        """Every exported series as ``{name: {label_tuple: value}}`` —
        the render/parse round-trip, so reading it also validates the
        export (see :meth:`MetricsRegistry.snapshot`)."""
        return self.metrics.snapshot()
