"""Thread-safe ingestion queue + continuous size-or-deadline micro-batcher.

The service's front end.  Producers call :meth:`MicroBatcher.submit`
from any thread and get a ``concurrent.futures.Future`` back; a
background worker drains the queue into micro-batches and hands each
(objective, grid-mode)-homogeneous group to the plan function.

Flush policy — CONTINUOUS batching, not fixed windows: the worker
sleeps only while the queue is empty.  Once a request arrives it
collects arrivals until either ``max_batch`` requests are pending
(flush on size) or the OLDEST pending request has waited
``flush_interval`` seconds (flush on deadline), whichever comes first —
so a full queue streams back-to-back batches with no artificial delay,
while a trickle pays at most one flush interval of latency.  A deadline
that fires on an empty queue (the wake raced a consumer) is a no-op
tick, not an error.

Groups preserve per-request order: within one flush, requests are
grouped by ``group_key`` in first-seen order and each group keeps its
arrival order, so results (delivered through per-request futures) can
never cross between interleaved objective streams.

``stop(drain=True)`` — clean shutdown — flushes everything still queued
(in ``max_batch``-sized batches, deadline waived) before the worker
exits; ``drain=False`` cancels the remaining futures instead.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Hashable, List, Optional

from repro.core.scenario import Scenario


class QueueFull(RuntimeError):
    """submit() refused: the ingestion queue is at ``max_pending``.
    Raised to the PRODUCER immediately (shed, don't block) — the
    service maps it to its admission-level RequestShed outcome."""


@dataclass
class PlanRequest:
    """One in-flight planning request.

    ``objective`` is an objective INSTANCE (or ``None`` for the
    planner's default) — identity groups micro-batches and keys the
    jitted Monte-Carlo kernel cache, exactly as in ``plan_many``.
    """

    scenario: Scenario
    objective: Any = None
    grid_mode: str = "dense"
    session_id: Optional[str] = None
    enqueue_t: float = field(default_factory=time.perf_counter)
    future: "Future" = field(default_factory=Future)
    #: admission-policy routing time spent BEFORE enqueue (seconds);
    #: reported on the request's span, outside the enqueue-to-plan SLO
    admit_s: float = 0.0
    #: enqueue-to-plan latency budget in seconds (``None`` = no budget).
    #: When the estimated solve time exceeds what remains of the budget,
    #: the resilience layer degrades the request instead of solving it
    #: late — see ``repro.serve.resilience``.
    budget_s: Optional[float] = None

    def remaining_budget(self, now: Optional[float] = None) \
            -> Optional[float]:
        """Seconds of budget left (negative = already blown), or
        ``None`` for unbudgeted requests."""
        if self.budget_s is None:
            return None
        if now is None:
            now = time.perf_counter()
        return self.budget_s - (now - self.enqueue_t)

    def group_key(self) -> Hashable:
        """Micro-batch grouping key: one jitted solve serves one
        (objective identity, grid mode) pair."""
        return (id(self.objective), self.grid_mode)


def group_requests(items: List, key: Callable[[Any], Hashable]) -> List[List]:
    """Group ``items`` by ``key`` in first-seen order, preserving each
    group's internal order — the canonical micro-batch grouping used by
    both the always-on batcher and the one-shot ``plan_server`` driver."""
    groups: "OrderedDict[Hashable, List]" = OrderedDict()
    for it in items:
        groups.setdefault(key(it), []).append(it)
    return list(groups.values())


class MicroBatcher:
    """Size-or-deadline continuous micro-batcher over a FIFO queue.

    ``plan_group(requests)`` is called on the worker thread with a
    non-empty, (objective, grid-mode)-homogeneous, arrival-ordered list;
    it must resolve every request's future (the batcher resolves them
    with the exception instead if it raises).
    """

    def __init__(self, plan_group: Callable[[List[PlanRequest]], None], *,
                 max_batch: int = 256, flush_interval: float = 0.01,
                 max_pending: int = 0, faults=None,
                 name: str = "plan-batcher"):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if flush_interval < 0:
            raise ValueError(
                f"flush_interval must be >= 0, got {flush_interval}")
        if max_pending < 0:
            raise ValueError(
                f"max_pending must be >= 0, got {max_pending}")
        self._plan_group = plan_group
        self.max_batch = max_batch
        self.flush_interval = flush_interval
        #: ingestion-queue bound; 0 = unbounded.  A full queue REJECTS
        #: (QueueFull from submit, immediately) rather than blocking the
        #: producer or growing memory without limit.
        self.max_pending = max_pending
        #: optional repro.chaos.FaultPlan; the worker draws the
        #: "queue.stall" point before planning each taken batch
        self.faults = faults
        self.rejections = 0       # submits refused by the queue bound
        self._name = name
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: Deque[PlanRequest] = deque()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._drain = True
        self.flushes = 0          # micro-batches handed to plan_group
        self.idle_ticks = 0       # deadline wakes that found nothing to do
        #: per-cause flush counts: "size" (max_batch pending), "deadline"
        #: (oldest request waited out flush_interval), "drain" (shutdown
        #: flush) — the signal separating a saturated service (size) from
        #: a trickle paying the deadline on every batch
        self.flush_causes = {"size": 0, "deadline": 0, "drain": 0}

    # -- producer side ------------------------------------------------------

    def submit(self, request: PlanRequest) -> "Future":
        """Enqueue; returns the request's future.  Raises once stopped —
        a draining queue must not grow behind the worker's back."""
        with self._cv:
            if self._stopping or self._thread is None:
                raise RuntimeError(
                    f"{self._name} is not running; start() it first")
            if (self.max_pending > 0
                    and len(self._queue) >= self.max_pending):
                self.rejections += 1
                raise QueueFull(
                    f"{self._name}: queue at capacity "
                    f"({len(self._queue)}/{self.max_pending})")
            self._queue.append(request)
            self._cv.notify()
        return request.future

    @property
    def depth(self) -> int:
        """Requests currently waiting (the service's load signal)."""
        with self._lock:
            return len(self._queue)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MicroBatcher":
        with self._cv:
            if self._thread is not None:
                raise RuntimeError(f"{self._name} already started")
            self._stopping = False
            self._thread = threading.Thread(
                target=self._run, name=self._name, daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None) \
            -> None:
        """Stop the worker.  ``drain=True`` plans everything still queued
        first; ``drain=False`` cancels the queued futures."""
        with self._cv:
            if self._thread is None:
                return
            self._stopping = True
            self._drain = drain
            self._cv.notify_all()
            thread = self._thread
        thread.join(timeout)
        with self._cv:
            self._thread = None

    # -- worker -------------------------------------------------------------

    def _take_batch(self) -> Optional[List[PlanRequest]]:
        """Block until a flush is due; return its requests, or ``None``
        when stopped and (post-drain) empty."""
        with self._cv:
            while True:
                cause = "drain"
                while not self._queue and not self._stopping:
                    self._cv.wait()
                if not self._queue:
                    return None  # stopping on an empty queue
                if self._stopping:
                    if not self._drain:
                        while self._queue:
                            self._queue.popleft().future.cancel()
                        return None
                else:
                    # deadline of the OLDEST pending request; new arrivals
                    # notify, size max_batch flushes immediately
                    deadline = self._queue[0].enqueue_t + self.flush_interval
                    while (len(self._queue) < self.max_batch
                           and not self._stopping):
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0:
                            break
                        self._cv.wait(timeout=remaining)
                    if not self._queue:
                        # the deadline wake found nothing to flush (e.g.
                        # a cancel drained the queue mid-wait): count the
                        # no-op tick and go back to sleep
                        self.idle_ticks += 1
                        continue
                    cause = ("size" if len(self._queue) >= self.max_batch
                             else "deadline")
                n = min(self.max_batch, len(self._queue))
                self.flush_causes[cause] += 1
                return [self._queue.popleft() for _ in range(n)]

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            if self.faults is not None:
                action = self.faults.draw("queue.stall")
                if action is not None:
                    time.sleep(action.duration_s)
            for group in group_requests(batch,
                                        key=PlanRequest.group_key):
                self.flushes += 1
                try:
                    self._plan_group(group)
                except BaseException as e:  # noqa: BLE001 — futures carry it
                    for req in group:
                        if not req.future.done():
                            req.future.set_exception(e)
