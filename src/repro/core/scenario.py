"""Unified Scenario / Planner / Simulator API.

The paper's planning problem — pick the packet payload ``n_c`` minimising
the Corollary-1 bound under a deadline — and both Sec.-6 extensions
(noisy channel with rate selection, multiple devices) compose through
three objects:

  * :class:`Scenario` — a frozen bundle of the protocol parameters
    ``(N, T, n_o, tau_p)`` plus a pluggable :class:`LinkModel` from the
    registry in :mod:`repro.core.links` (:class:`IdealLink` |
    :class:`ErasureLink` | :class:`FadingLink` |
    :class:`GilbertElliottLink` | any registered plugin) and
    :class:`Topology` (:class:`SingleDevice` | :class:`MultiDevice`).
    Every combination is expressible, including previously inexpressible
    cross products such as a bursty channel feeding a multi-device TDMA
    uplink.
  * :class:`Planner` — the protocol ``plan(scenario, consts) -> Plan``.
    :class:`ObjectivePlanner` minimises ANY objective registered in
    :mod:`repro.core.objectives` (Corollary-1 bound, empirical
    Monte-Carlo loss, exact burst-aware Markov-ARQ, plugins);
    :class:`BoundPlanner` (Corollary 1 on the full joint ``(rate, n_c)``
    grid in ONE broadcast call) and :class:`MonteCarloPlanner` (seed loop
    replaced by ``jax.vmap``) are facades over it, and
    :class:`Theorem1Planner` minimises the Monte-Carlo Theorem-1
    estimate.  All return the same enriched
    :class:`~repro.core.planner.Plan`.
  * :class:`Simulator` — ``run(scenario, plan, task) -> SimReport``:
    dispatches a :class:`RidgeTask` to the jitted ridge scan and a
    :class:`StreamingTask` to the generic ``run_streaming_training``
    loop, applying the scenario's topology reduction and link-induced
    effective overhead, and attaching a sampled ARQ delivery timeline
    for lossy links.

Both reductions are exact analytical maps into the paper's noiseless
single-device model (Sec. 6): round-robin TDMA over ``D`` devices is a
single stream with block ``D n_c`` / overhead ``D n_o``; stop-and-wait
ARQ at loss probability ``p`` inflates the expected block duration by
``1/(1-p)``, absorbed into an effective per-block overhead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.bounds import BoundConstants, corollary1_bound
from repro.core.links import (MAX_LINK_PARAMS, P_ERR_MAX, ErasureLink,
                              FadingLink, GilbertElliottLink, IdealLink,
                              LinkModel, LinkModelSpec, link_spec,
                              link_spec_for, register_link_model,
                              registered_link_models, unregister_link_model)
from repro.core.objectives import (BoundObjective, MarkovARQObjective,
                                   MonteCarloObjective, Objective,
                                   ObjectiveSpec, mc_default_grid,
                                   objective_spec, objective_spec_for,
                                   register_objective,
                                   registered_objectives,
                                   unregister_objective)
from repro.core.planner import Plan, default_grid
from repro.core.protocol import BlockSchedule, boundary_n_c

# Link models live in :mod:`repro.core.links` and planning objectives in
# :mod:`repro.core.objectives` (the pluggable registries); re-exported here
# because this module is the planners' home.
__all__ = [
    "MAX_LINK_PARAMS", "P_ERR_MAX", "LinkModel", "LinkModelSpec",
    "IdealLink", "ErasureLink", "FadingLink", "GilbertElliottLink",
    "register_link_model", "registered_link_models", "unregister_link_model",
    "link_spec", "link_spec_for",
    "Objective", "ObjectiveSpec", "BoundObjective", "MonteCarloObjective",
    "MarkovARQObjective", "register_objective", "registered_objectives",
    "unregister_objective", "objective_spec", "objective_spec_for",
    "Topology", "SingleDevice", "MultiDevice", "Scenario",
    "Planner", "ObjectivePlanner", "BoundPlanner", "MonteCarloPlanner",
    "Theorem1Planner",
    "RidgeTask", "StreamingTask", "SimReport", "Simulator",
]


# ---------------------------------------------------------------------------
# Topologies
# ---------------------------------------------------------------------------


@runtime_checkable
class Topology(Protocol):
    n_devices: int


@dataclass(frozen=True)
class SingleDevice:
    n_devices: int = 1


@dataclass(frozen=True)
class MultiDevice:
    """D devices sharing the uplink by round-robin TDMA (Sec. 6, ext. 2).

    The union prefix grows exactly like a single device with block size
    ``D * n_c`` and overhead ``D * n_o`` — so all planning happens in
    union coordinates and per-device block sizes come out as ``n_c / D``.
    """

    n_devices: int

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """Everything the planner and simulator need to know about the system.

    ``N`` is the TOTAL number of samples (across all devices), ``T`` the
    deadline and ``n_o`` the per-device per-block overhead, all in the
    paper's normalised sample-transmission time units; ``tau_p`` is the
    time per SGD update.
    """

    N: int
    T: float
    n_o: float
    tau_p: float = 1.0
    link: Any = field(default_factory=IdealLink)
    topology: Any = field(default_factory=SingleDevice)

    def __post_init__(self):
        if self.N < 1:
            raise ValueError(f"N must be >= 1, got {self.N}")
        if not self.T > 0.0:
            raise ValueError(f"T must be > 0, got {self.T}")
        if self.n_o < 0.0:
            raise ValueError(f"n_o must be >= 0, got {self.n_o}")
        if not self.tau_p > 0.0:
            raise ValueError(f"tau_p must be > 0, got {self.tau_p}")

    @property
    def n_devices(self) -> int:
        return self.topology.n_devices

    @property
    def union_overhead(self) -> float:
        """Per-union-block overhead after the TDMA reduction (D * n_o)."""
        return self.n_devices * self.n_o

    def effective_overhead(self, n_c, rate=1.0):
        """Link+topology-induced overhead ``n_o_eff(n_c, rate)``.

        Chosen so that ``n_c + n_o_eff`` equals the expected union-block
        delivery time — mapping any scenario into the paper's noiseless
        model where Corollary 1 applies unchanged.  Vectorised over
        broadcastable ``n_c`` / ``rate`` arrays.  May legitimately be
        NEGATIVE (rate > 1 outruns the ARQ inflation): the effective block
        duration ``n_c + n_o_eff`` stays positive, which is all the bound
        math needs.
        """
        if np.any(np.asarray(rate, np.float64) <= 0.0):
            raise ValueError(f"rate must be > 0, got {rate}")
        n_c = np.asarray(n_c, np.float64)
        dur = self.link.expected_block_time(n_c, self.union_overhead, rate)
        return dur - n_c

    def schedule(self, n_c: int, rate: float = 1.0) -> BlockSchedule:
        """Effective single-device :class:`BlockSchedule` at a block size."""
        return BlockSchedule(N=self.N, n_c=int(n_c),
                             n_o=float(self.effective_overhead(n_c, rate)),
                             T=self.T, tau_p=self.tau_p)


# ---------------------------------------------------------------------------
# Planners
# ---------------------------------------------------------------------------


@runtime_checkable
class Planner(Protocol):
    def plan(self, scenario: Scenario, consts: BoundConstants) -> Plan: ...


def _finish_plan(scenario: Scenario, grid: np.ndarray, rates: np.ndarray,
                 vals: np.ndarray, *, objective: str,
                 n_o_eff_fn=None) -> Plan:
    """Shared argmin + Plan assembly over a (rates, grid) objective array.

    ``np.argmin`` over the flattened rate-major array reproduces the
    legacy loop's tie-breaking (first rate, then first grid point).
    ``n_o_eff_fn(scenario, n_c, rate)`` lets an objective report the
    schedule/boundary under its OWN effective overhead (e.g. the exact
    burst-aware ARQ time); default is the scenario's link reduction.
    """
    flat = int(np.argmin(vals))
    ri, gi = divmod(flat, grid.size)
    rate = float(rates[ri])
    n_c = int(grid[gi])
    if n_o_eff_fn is None:
        n_o_eff = float(scenario.effective_overhead(n_c, rate))
    else:
        n_o_eff = float(n_o_eff_fn(scenario, n_c, rate))
    sched = BlockSchedule(N=scenario.N, n_c=n_c, n_o=n_o_eff,
                          T=scenario.T, tau_p=scenario.tau_p)
    D = scenario.n_devices
    return Plan(
        n_c=n_c,
        bound_value=float(vals[ri, gi]),
        full_transfer=sched.full_transfer,
        boundary=boundary_n_c(scenario.N, scenario.T, n_o_eff),
        grid=grid,
        bound_grid=vals[ri],
        schedule=sched,
        rate=rate,
        p_err=float(scenario.link.p_err(rate)),
        n_c_per_device=max(1, n_c // D),
        objective=objective,
    )


@dataclass(frozen=True)
class ObjectivePlanner:
    """Plan any registered :class:`~repro.core.objectives.Objective`.

    The generic scalar planner behind the objective registry: evaluate the
    objective's ``(rate, n_c)`` reference array, reduce it with the
    canonical rate-major argmin tie-breaking, and report the schedule under
    the objective's own effective overhead.  ``BoundPlanner`` and
    ``MonteCarloPlanner`` are thin facades over this with their historical
    constructor surfaces.
    """

    objective: Any = field(default_factory=BoundObjective)
    grid: Optional[Sequence[int]] = None

    def plan(self, scenario: Scenario,
             consts: Optional[BoundConstants] = None) -> Plan:
        obj = self.objective
        if self.grid is not None:
            grid = np.asarray(self.grid)
        else:
            own = getattr(obj, "default_grid", None)
            grid = (np.asarray(own(scenario.N)) if callable(own)
                    else default_grid(scenario.N))
        rates = np.asarray(scenario.link.rates, np.float64)
        vals = np.asarray(obj.evaluate(scenario, consts, grid, rates))
        return _finish_plan(scenario, grid, rates, vals,
                            objective=obj.objective_id,
                            n_o_eff_fn=obj.effective_overhead)


@dataclass(frozen=True)
class BoundPlanner:
    """Corollary-1 planner (the paper's recipe), joint over (n_c, rate).

    The whole ``(rate, n_c)`` grid is evaluated in ONE broadcast call to
    :func:`corollary1_bound` — no Python loop over grid points.  The
    evaluation itself lives in
    :class:`~repro.core.objectives.BoundObjective` (extracted verbatim, so
    plans are bitwise-identical to the pre-registry planner).
    """

    grid: Optional[Sequence[int]] = None

    def plan(self, scenario: Scenario, consts: BoundConstants) -> Plan:
        return ObjectivePlanner(objective=BoundObjective(),
                                grid=self.grid).plan(scenario, consts)


@dataclass(frozen=True)
class MonteCarloPlanner:
    """Experimental-optimum planner: minimise the Monte-Carlo average of
    the realised final training loss on the ridge task (the paper's
    ``n_c*`` search, Sec. 5).  The per-seed loop is a single ``jax.vmap``
    over seeds inside :func:`repro.core.pipeline.average_final_loss`; the
    grid evaluation is the reference semantics of
    :class:`~repro.core.objectives.MonteCarloObjective`.
    """

    X: Any
    y: Any
    lam: float = 0.05
    alpha: float = 1e-4
    n_runs: int = 3
    seed: int = 0
    seed_stream: str = "fold_in"
    grid: Optional[Sequence[int]] = None
    grid_points: int = 12  # MC is expensive: default to a coarse grid

    def plan(self, scenario: Scenario,
             consts: Optional[BoundConstants] = None) -> Plan:
        objective = MonteCarloObjective(
            X=self.X, y=self.y, lam=self.lam, alpha=self.alpha,
            n_runs=self.n_runs, seed=self.seed,
            seed_stream=self.seed_stream,
            grid_points=self.grid_points)
        return ObjectivePlanner(objective=objective,
                                grid=self.grid).plan(scenario, consts)


@dataclass(frozen=True)
class Theorem1Planner:
    """Tighter (but Monte-Carlo) planner: minimise the Theorem-1 estimate
    from :func:`repro.core.montecarlo.estimate_theorem1` instead of the
    closed-form Corollary-1 relaxation."""

    X: Any
    y: Any
    lam: float = 0.05
    alpha: float = 1e-4
    n_runs: int = 2
    seed: int = 0
    grid: Optional[Sequence[int]] = None
    grid_points: int = 8

    def plan(self, scenario: Scenario, consts: BoundConstants) -> Plan:
        from repro.core.montecarlo import estimate_theorem1

        grid = np.asarray(self.grid if self.grid is not None
                          else mc_default_grid(scenario.N, self.grid_points))
        rates = np.asarray(scenario.link.rates, np.float64)
        vals = np.empty((rates.size, grid.size))
        for ri, rate in enumerate(rates):
            for gi, n_c in enumerate(grid):
                n_o_eff = float(scenario.effective_overhead(int(n_c), rate))
                out = estimate_theorem1(
                    self.X, self.y, n_c=int(n_c), n_o=n_o_eff, T=scenario.T,
                    consts=consts, lam=self.lam, alpha=self.alpha,
                    tau_p=scenario.tau_p, n_runs=self.n_runs, seed=self.seed)
                vals[ri, gi] = out["theorem1"]
        return _finish_plan(scenario, grid, rates, vals,
                            objective="theorem1")


# ---------------------------------------------------------------------------
# Simulator facade
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RidgeTask:
    """The paper's Sec.-5 ridge-regression workload (jitted lax.scan)."""

    X: Any
    y: Any
    lam: float = 0.05
    alpha: float = 1e-4
    seed: int = 0
    record_every: int = 256


@dataclass
class StreamingTask:
    """Any-architecture workload for the generic streaming trainer."""

    train_step: Callable
    params: Any
    opt_state: Any
    dataset: Any            # (N, seq) host array of samples
    batch_size: int
    make_batch: Optional[Callable] = None
    seed: int = 0
    log_every: int = 10


@dataclass(frozen=True)
class SimReport:
    """Unified simulation output for every task type."""

    final_loss: float
    delivered: int
    schedule: BlockSchedule
    plan: Plan
    w_final: Optional[np.ndarray] = None
    loss_trace: Optional[np.ndarray] = None
    trace_times: Optional[np.ndarray] = None
    history: Optional[list] = None       # StreamingTask update log
    state: Any = None                    # StreamingTrainState
    arq_times: Optional[np.ndarray] = None    # sampled ARQ delivery ...
    arq_counts: Optional[np.ndarray] = None   # ... timeline (lossy links)


class Simulator:
    """``run(scenario, plan, task) -> SimReport``.

    Applies the scenario's topology reduction and link-induced effective
    overhead to the plan's block size, then dispatches on the task type:
    :class:`RidgeTask` runs the fully-jitted ridge scan,
    :class:`StreamingTask` runs the generic ``run_streaming_training``
    loop.  For any lossy link (every registered model except
    :class:`IdealLink`) a realised ARQ delivery timeline is sampled from
    the link's own loss process — i.i.d. for memoryless channels, the
    actual two-state chain for :class:`GilbertElliottLink` — and attached
    to the report.
    """

    def run(self, scenario: Scenario, plan: Plan, task) -> SimReport:
        sched = scenario.schedule(plan.n_c, plan.rate)
        if isinstance(task, RidgeTask):
            report = self._run_ridge(scenario, plan, task, sched)
        elif isinstance(task, StreamingTask):
            report = self._run_streaming(scenario, plan, task, sched)
        else:
            raise TypeError(
                f"unknown task type {type(task).__name__}; expected "
                "RidgeTask or StreamingTask")
        return report

    def _run_ridge(self, scenario, plan, task, sched) -> SimReport:
        from repro.core.pipeline import run_pipelined_sgd

        res = run_pipelined_sgd(
            task.X, task.y, n_c=sched.n_c, n_o=sched.n_o, T=sched.T,
            tau_p=sched.tau_p, alpha=task.alpha, lam=task.lam,
            seed=task.seed, record_every=task.record_every)
        arq_t, arq_c = self._maybe_sample_arq(scenario, plan, task.seed)
        return SimReport(
            final_loss=res.final_loss, delivered=res.delivered,
            schedule=sched, plan=plan, w_final=res.w_final,
            loss_trace=res.loss_trace, trace_times=res.trace_times,
            arq_times=arq_t, arq_counts=arq_c)

    def _run_streaming(self, scenario, plan, task, sched) -> SimReport:
        from repro.core.stream_trainer import run_streaming_training

        state = run_streaming_training(
            train_step=task.train_step, params=task.params,
            opt_state=task.opt_state, dataset=task.dataset, plan=sched,
            batch_size=task.batch_size, make_batch=task.make_batch,
            seed=task.seed, log_every=task.log_every)
        final = state.history[-1]["loss"] if state.history else float("nan")
        arq_t, arq_c = self._maybe_sample_arq(scenario, plan, task.seed)
        return SimReport(
            final_loss=final, delivered=state.delivered, schedule=sched,
            plan=plan, history=state.history, state=state,
            arq_times=arq_t, arq_counts=arq_c)

    def _maybe_sample_arq(self, scenario, plan, seed):
        link = scenario.link
        if isinstance(link, IdealLink) \
                or not callable(getattr(link, "make_loss_process", None)):
            return None, None
        from repro.core.channel import simulate_link_stream

        return simulate_link_stream(
            n_samples=scenario.N, n_c=plan.n_c,
            n_o=scenario.union_overhead, rate=plan.rate, link=link,
            T=scenario.T, seed=seed)
