"""Pluggable planner-objective registry: one planning API for every objective.

The paper's planner minimises the closed-form Corollary-1 bound, but the
paper itself validates that bound against empirical Monte-Carlo SGD runs,
and burst-loss channels admit an exact Markov-reward evaluation the
stationary-loss bound cannot see.  Mirroring the link-model registry in
:mod:`repro.core.links`, this module turns "which scalar does the planner
minimise over the joint ``(rate, n_c)`` grid" into an extension point.

Every objective is a frozen dataclass registered in an
:class:`ObjectiveSpec` table under a stable string ``objective_id`` and
declares

  * a numpy reference evaluation — ``evaluate(scenario, consts, grid,
    rates) -> (R, G)`` objective values over the joint grid (what the
    scalar :class:`~repro.core.scenario.ObjectivePlanner` minimises with
    the canonical rate-major argmin tie-breaking);
  * an effective-overhead map — ``effective_overhead(scenario, n_c,
    rate)``, the link+topology reduction the plan's schedule/boundary are
    reported under (objectives that re-model the channel, e.g. the exact
    burst-aware ARQ solve, override it so the reported schedule matches
    the objective's own physics);
  * a cache signature — ``cache_token()``, a hashable tuple of the id and
    every hyperparameter the optimum depends on (Monte-Carlo seed count,
    data digest, ...); :class:`~repro.fleet.cache.PlanCache` folds it into
    the quantised key so two objectives can never alias one entry;
  * optionally a ``default_grid(N)`` — objectives with expensive
    evaluations (Monte Carlo) declare a coarser default search grid.

The jitted batched counterparts live in
:mod:`repro.fleet.objective_kernels`: registering a batched kernel under
the same ``objective_id`` lets ``FleetPlanner.plan_batch`` solve thousands
of scenarios against the objective in one compiled call (see README
"Planning objectives" for a worked custom-objective plugin).

Built-in objectives (ids are part of the cache contract — never reuse):

  ============  =========================  ================================
  id            class                      minimises
  ============  =========================  ================================
  corollary1    :class:`BoundObjective`    the paper's Corollary-1 bound
  montecarlo    :class:`MonteCarloObjective`  empirical mean final ridge loss
  markov_arq    :class:`MarkovARQObjective`   Corollary 1 under the EXACT
                                             burst-aware ARQ block time
  ============  =========================  ================================
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from functools import cached_property
from typing import (Any, ClassVar, Dict, Optional, Protocol, Tuple, Type,
                    runtime_checkable)

import numpy as np

from repro.core.bounds import BoundConstants, corollary1_bound


@runtime_checkable
class Objective(Protocol):
    """What the planners minimise over the joint ``(rate, n_c)`` grid.

    ``evaluate`` must be the REFERENCE semantics: the scalar planner
    minimises exactly this array, and any batched kernel registered in
    :mod:`repro.fleet.objective_kernels` must reproduce its argmin.
    """

    objective_id: ClassVar[str]

    def evaluate(self, scenario, consts, grid, rates) -> np.ndarray: ...

    def effective_overhead(self, scenario, n_c, rate): ...

    def cache_token(self) -> Tuple: ...


@dataclass(frozen=True)
class RefineHints:
    """Per-objective hints for the coarse->fine two-pass fleet solve.

    Objectives may expose an instance as a ``refine_hints`` attribute;
    :class:`~repro.fleet.planner.FleetPlanner` consults it in
    ``grid_mode="refine"``.

      * ``min_grid`` — dense fallback below this dense grid width: a grid
        too narrow to subsample leaves no work for refinement to cut (the
        ISSUE's "bracket would clip at grid edges" degenerate case).
      * ``stride`` — coarse subsampling stride; ``None`` picks the
        work-minimising ``round(sqrt(G / 2))`` (coarse pass ``G/k`` plus
        bracket ``2k + 1`` is minimal at ``k = sqrt(G/2)``).
      * ``tail_blocks`` — densely evaluate the grid suffix where
        ``N / n_c <= tail_blocks``: with few delivery blocks the bound's
        ``ceil(B_d)/B_d`` floor arithmetic is a sawtooth whose teeth a
        coarse bracket cannot follow.  ``None`` disables the guard —
        the Monte-Carlo objective does that (every tail lane would be a
        simulated training run, and its empirical landscape has no
        ``ceil(B_d)`` algebra), trading a small documented parity residue
        for the full lane cut.
      * ``coarse_seeds`` — SEED-COUNT SCHEDULE for simulated objectives:
        run the coarse pass with only this many Monte-Carlo seeds (the
        coarse pass only has to locate basins; the full ``n_runs`` seeds
        are spent where they matter, on the fine windows).  ``None``
        keeps the full seed count on both passes (the pinned reference
        behaviour).  ``0`` is the schedule's limit — a BOUND-GUIDED
        coarse pass: skip the Monte-Carlo coarse solve entirely and take
        the per-rate window centers from a full-grid Corollary-1 solve
        (the closed-form bound as a zeroth-order estimate of the
        empirical landscape; it is ~4 orders of magnitude cheaper than
        one simulated grid point, so the fine pass becomes the whole
        cost).  Ignored by objectives whose kernels don't accept a seed
        override.
      * ``refine_rates`` — keep only the best ``refine_rates`` rates per
        scenario (ranked by the coarse pass's per-rate minima) in the
        fine pass.  ``None`` refines every rate (the pinned reference
        behaviour).  For simulated objectives every pruned rate removes
        a full row of training simulations from the fine pass.
      * ``coarse_strides`` — MULTI-LEVEL STRIDE SCHEDULE (overrides
        ``stride``): a descending tuple such as ``(32, 6)``.  Stage 0
        sweeps the full grid at stride ``coarse_strides[0]`` over all
        rates; each later stage ``i`` re-centres at step
        ``coarse_strides[i]`` within ``±coarse_strides[i - 1]`` of the
        previous stage's per-rate winners; the fine pass then evaluates
        the dense ``±coarse_strides[-1]`` window.  ``coarse_seeds``
        applies to EVERY coarse stage and ``refine_rates`` prunes after
        stage 0, so with ``(32, 6)``/1 seed/1 rate a 128-point grid
        costs ~62 simulated lane-runs instead of 1280.  Ignored by
        objectives whose kernels don't accept a seed override.
      * ``fine_radius`` — widen the dense fine window to ``±fine_radius``
        grid steps, decoupled from the last coarse stride.  A window
        wider than ``±coarse_strides[-1]`` buys back the center drift a
        throttled (few-seed / short-horizon) coarse schedule introduces:
        the full-seed fine pass re-ranks everything inside the bracket,
        so a mildly mis-centred window still recovers the dense argmin.
      * ``coarse_updates`` — HORIZON SCHEDULE for simulated objectives:
        cap every coarse stage's simulated update timeline at this many
        update slots (the fine pass always trains the full horizon).
        Basin ranking stabilises long before training converges, so a
        quarter-horizon coarse pass costs ~1/4 the scan work at nearly
        unchanged fine-pass outcomes; far below that the truncated
        landscape no longer resembles the converged one, so pair a
        small cap with a generous ``fine_radius``.  Ignored by
        objectives whose kernels don't accept a horizon override.
    """

    min_grid: int = 32
    stride: Optional[int] = None
    tail_blocks: Optional[int] = 32
    coarse_seeds: Optional[int] = None
    refine_rates: Optional[int] = None
    coarse_strides: Optional[Tuple[int, ...]] = None
    fine_radius: Optional[int] = None
    coarse_updates: Optional[int] = None


def refine_hints_for(objective) -> RefineHints:
    """The objective's declared refinement hints (registry default if none)."""
    hints = getattr(objective, "refine_hints", None)
    return hints if isinstance(hints, RefineHints) else RefineHints()


@dataclass(frozen=True)
class ObjectiveSpec:
    """Registry entry: the stable id and the objective class."""

    objective_id: str
    name: str
    cls: type


_SPECS_BY_ID: Dict[str, ObjectiveSpec] = {}
_SPECS_BY_CLS: Dict[type, ObjectiveSpec] = {}


def register_objective(cls: Type) -> Type:
    """Class decorator: add an objective class to the registry.

    The class must carry a non-empty string class attribute
    ``objective_id`` (unique) and implement the :class:`Objective` surface
    (``evaluate``, ``effective_overhead``, ``cache_token``).
    """
    objective_id = getattr(cls, "objective_id", None)
    if not isinstance(objective_id, str) or not objective_id:
        raise ValueError(
            f"{cls.__name__}.objective_id must be a non-empty str, got "
            f"{objective_id!r}")
    missing = [m for m in ("evaluate", "effective_overhead", "cache_token")
               if not callable(getattr(cls, m, None))]
    if missing:
        raise TypeError(
            f"{cls.__name__} is missing Objective methods {missing}")
    prior = _SPECS_BY_ID.get(objective_id)
    if prior is not None and prior.cls is not cls:
        raise ValueError(
            f"objective_id {objective_id!r} already registered by "
            f"{prior.name}")
    spec = ObjectiveSpec(objective_id=objective_id, name=cls.__name__,
                         cls=cls)
    _SPECS_BY_ID[objective_id] = spec
    _SPECS_BY_CLS[cls] = spec
    return cls


def unregister_objective(objective_id: str) -> None:
    """Remove a registry entry (plugin teardown / tests).  No-op if absent."""
    spec = _SPECS_BY_ID.pop(objective_id, None)
    if spec is not None:
        _SPECS_BY_CLS.pop(spec.cls, None)


def objective_spec(objective_id: str) -> ObjectiveSpec:
    """Spec for a registered id (KeyError with guidance if not)."""
    try:
        return _SPECS_BY_ID[objective_id]
    except KeyError:
        raise KeyError(
            f"no objective registered under objective_id {objective_id!r}; "
            f"known ids: {sorted(_SPECS_BY_ID)}") from None


def objective_spec_for(objective_or_cls) -> ObjectiveSpec:
    """Spec for an objective instance or class (KeyError if unregistered)."""
    cls = (objective_or_cls if isinstance(objective_or_cls, type)
           else type(objective_or_cls))
    try:
        return _SPECS_BY_CLS[cls]
    except KeyError:
        raise KeyError(
            f"{cls.__name__} is not a registered objective; decorate it "
            "with repro.core.objectives.register_objective") from None


def registered_objectives() -> Tuple[ObjectiveSpec, ...]:
    """All registered specs, sorted by ``objective_id``."""
    return tuple(_SPECS_BY_ID[k] for k in sorted(_SPECS_BY_ID))


def mc_default_grid(N: int, n_points: int = 12) -> np.ndarray:
    """Coarse log grid for Monte-Carlo objectives (MC is expensive)."""
    g = np.unique(np.round(
        np.logspace(0, np.log10(N), n_points)).astype(np.int64))
    return g[g >= 1]


def _corollary1_grid(objective, scenario, consts: BoundConstants, grid,
                     rates) -> np.ndarray:
    """Corollary 1 over the joint grid at the OBJECTIVE's effective
    overhead — one broadcast call, shared by every bound-shaped objective
    so the ``p_good == p_bad`` bitwise-reduction contract between
    :class:`BoundObjective` and :class:`MarkovARQObjective` can never
    drift (they differ ONLY through ``effective_overhead``)."""
    consts.validate()
    grid = np.asarray(grid)
    rates = np.asarray(rates, np.float64)
    n_o_eff = objective.effective_overhead(scenario, grid[None, :],
                                           rates[:, None])
    return corollary1_bound(
        np.broadcast_to(grid[None, :].astype(np.float64), n_o_eff.shape),
        N=scenario.N, T=scenario.T, n_o=n_o_eff, tau_p=scenario.tau_p,
        consts=consts)


# ---------------------------------------------------------------------------
# built-in objectives
# ---------------------------------------------------------------------------


@register_objective
@dataclass(frozen=True)
class BoundObjective:
    """The paper's recipe: Corollary 1 on the joint ``(rate, n_c)`` grid.

    This is the objective extracted verbatim from the pre-registry
    ``BoundPlanner.plan`` — one broadcast :func:`corollary1_bound` call,
    no Python loop — so plans are bitwise-identical to the old path.
    """

    objective_id: ClassVar[str] = "corollary1"
    #: bound-shaped objectives keep the guarded sawtooth tail (see
    #: :class:`RefineHints`) so coarse->fine plans stay argmin-identical
    #: to the dense solve throughout the small-block-count suffix; the
    #: wide fixed stride trades a few extra bracket lanes for a basin
    #: window that also absorbs the bound's resolved-region micro-teeth
    refine_hints: ClassVar[RefineHints] = RefineHints(stride=16)

    def evaluate(self, scenario, consts: BoundConstants, grid, rates):
        return _corollary1_grid(self, scenario, consts, grid, rates)

    def effective_overhead(self, scenario, n_c, rate):
        return scenario.effective_overhead(n_c, rate)

    def cache_token(self) -> Tuple:
        return (self.objective_id,)


@register_objective
@dataclass(frozen=True)
class MarkovARQObjective:
    """Corollary 1 under the EXACT burst-aware expected per-block ARQ time.

    A Gilbert-Elliott link plans, by default, through its stationary loss
    probability — inflation ``1 / (1 - p_bar)`` — which ignores that a
    failed attempt is evidence of the bad state, so failures cluster and
    retransmission runs on sticky chains last longer than the memoryless
    model predicts.  This objective evaluates the same Corollary-1 bound
    but with the expected block duration taken from the link's
    ``exact_expected_block_time`` — the per-(rate, state) Markov-reward
    linear solve in
    :meth:`~repro.core.links.GilbertElliottLink.exact_arq_inflation` —
    whenever the link exposes one, falling back to the stationary
    ``expected_block_time`` otherwise.

    Contracts (tested): for memoryless links, and for a Gilbert-Elliott
    chain with ``p_good == p_bad``, the objective array is bitwise equal to
    :class:`BoundObjective`'s, so the plans coincide exactly; on sticky
    chains the burst-aware plan achieves a strictly lower exact expected
    block time than the stationary-approximation plan.
    """

    objective_id: ClassVar[str] = "markov_arq"
    refine_hints: ClassVar[RefineHints] = RefineHints(stride=16)

    def evaluate(self, scenario, consts: BoundConstants, grid, rates):
        return _corollary1_grid(self, scenario, consts, grid, rates)

    def effective_overhead(self, scenario, n_c, rate):
        if np.any(np.asarray(rate, np.float64) <= 0.0):
            raise ValueError(f"rate must be > 0, got {rate}")
        link = scenario.link
        block_time = getattr(link, "exact_expected_block_time", None)
        if not callable(block_time):
            block_time = link.expected_block_time
        n_c = np.asarray(n_c, np.float64)
        dur = block_time(n_c, scenario.union_overhead, rate)
        return dur - n_c

    def cache_token(self) -> Tuple:
        return (self.objective_id,)


@register_objective
@dataclass(frozen=True, eq=False)
class MonteCarloObjective:
    """Empirical objective: Monte-Carlo mean of the realised final ridge
    loss (the paper's experimental ``n_c*`` search, Sec. 5).

    The reference evaluation is the existing scalar Monte-Carlo path
    (:func:`repro.core.montecarlo.montecarlo_objective_grid`, one vmapped
    seed batch per grid point); the batched fleet kernel vmaps the SAME
    seed streams over scenarios x rates x grid points so fleet plans match
    the scalar planner seed-for-seed.

    ``eq=False``: instances hold the training arrays, so identity (not
    array comparison) is the right equality — the fleet kernel cache keys
    on the instance, reuse one instance per request stream.
    """

    objective_id: ClassVar[str] = "montecarlo"

    X: Any = None
    y: Any = None
    lam: float = 0.05
    alpha: float = 1e-4
    n_runs: int = 3
    seed: int = 0
    grid_points: int = 12  # MC is expensive: default to a coarse grid
    #: floor on the batched kernel's padded update-timeline length.  The
    #: fleet kernel pads its shared ``lax.scan`` to the batch's largest
    #: update count; a serving layer sets this floor so every batch below
    #: it compiles to ONE scan length (padded slots no-op, so plans are
    #: unchanged — deliberately NOT part of ``cache_token``).
    min_updates: int = 0
    #: common random numbers: share ONE uniform draw per update slot
    #: across every simulation lane (all scenarios, rates and grid
    #: points) instead of drawing a per-lane sample index.  The sampled
    #: index is the comonotone ``floor(u * a)``, so nearby grid points
    #: see maximally-correlated trajectories and their loss DIFFERENCES
    #: (what the argmin consumes) converge with far fewer seeds.  A
    #: different (documented) estimator of the same objective: plans are
    #: not bitwise-pinned to the ``crn=False`` reference stream.
    crn: bool = False
    #: per-run RNG-key derivation: ``"fold_in"`` (default) derives run
    #: ``r``'s key as ``fold_in(PRNGKey(seed), r)`` — collision-free
    #: across (seed, run) pairs; ``"legacy"`` reproduces the historical
    #: ``PRNGKey(seed + 97 r)`` streams, which ALIAS across nearby
    #: objective seeds (seed=0 run 1 == seed=97 run 0) and are kept only
    #: as a pinned compatibility mode.
    seed_stream: str = "fold_in"
    #: optional seed-count schedule / rate pruning for the coarse->fine
    #: solve (folded into :attr:`refine_hints`; see
    #: :class:`RefineHints.coarse_seeds` / ``refine_rates``).  ``None``
    #: keeps the reference two-pass behaviour.
    coarse_seeds: Optional[int] = None
    refine_rates: Optional[int] = None
    #: multi-level stride schedule for the refine solve (see
    #: :class:`RefineHints.coarse_strides`); a descending tuple of
    #: positive ints, e.g. ``(32, 6)``.  ``None`` keeps the single
    #: coarse pass at :attr:`RefineHints.stride`.
    coarse_strides: Optional[Tuple[int, ...]] = None
    #: fine-window radius / coarse-pass horizon cap for the refine solve
    #: (see :class:`RefineHints.fine_radius` / ``coarse_updates``).
    #: ``None`` keeps the fine window at the last coarse stride and the
    #: coarse stages on the full update timeline.
    fine_radius: Optional[int] = None
    coarse_updates: Optional[int] = None

    def __post_init__(self):
        if self.X is None or self.y is None:
            raise ValueError("MonteCarloObjective needs the ridge task "
                             "data: MonteCarloObjective(X=..., y=...)")
        if self.n_runs < 1:
            raise ValueError(f"n_runs must be >= 1, got {self.n_runs}")
        if self.min_updates < 0:
            raise ValueError(
                f"min_updates must be >= 0, got {self.min_updates}")
        if self.seed_stream not in ("fold_in", "legacy"):
            raise ValueError(
                f"seed_stream must be 'fold_in' or 'legacy', got "
                f"{self.seed_stream!r}")
        if self.coarse_seeds is not None and self.coarse_seeds < 0:
            raise ValueError(
                f"coarse_seeds must be >= 0 or None, got "
                f"{self.coarse_seeds}")
        if self.refine_rates is not None and self.refine_rates < 1:
            raise ValueError(
                f"refine_rates must be >= 1 or None, got "
                f"{self.refine_rates}")
        if self.coarse_strides is not None:
            strides = tuple(int(s) for s in self.coarse_strides)
            if not strides or any(s < 1 for s in strides):
                raise ValueError(
                    f"coarse_strides must be a non-empty tuple of "
                    f"positive ints, got {self.coarse_strides!r}")
            if any(a <= b for a, b in zip(strides, strides[1:])):
                raise ValueError(
                    f"coarse_strides must be strictly descending, got "
                    f"{self.coarse_strides!r}")
            object.__setattr__(self, "coarse_strides", strides)
        if self.fine_radius is not None and self.fine_radius < 1:
            raise ValueError(
                f"fine_radius must be >= 1 or None, got "
                f"{self.fine_radius}")
        if self.coarse_updates is not None and self.coarse_updates < 1:
            raise ValueError(
                f"coarse_updates must be >= 1 or None, got "
                f"{self.coarse_updates}")

    #: Monte-Carlo refinement hints: a capped engagement width (the
    #: default 12-point MC grid leaves nothing to refine — refinement
    #: engages on explicitly widened grids) and NO sawtooth-tail guard:
    #: every tail point would be a full simulated training run, which is
    #: exactly the work refinement exists to eliminate, and the empirical
    #: loss has no ceil(B_d)/B_d algebra driving the bound's tail teeth.
    #: stride 10 (vs the sqrt(G/2) default) widens the bracket: the
    #: empirical loss landscape is seed-noise-ragged near the optimum, and
    #: the wider window recovers most of the raggedness at a lane cut
    #: that still clears the >= 3x refinement floor in bench_fleet.
    #: The instance's seed schedule (``coarse_seeds`` / ``refine_rates``)
    #: folds in here, so the planner reads ONE hints object.
    @property
    def refine_hints(self) -> RefineHints:
        return RefineHints(min_grid=24, stride=10, tail_blocks=None,
                           coarse_seeds=self.coarse_seeds,
                           refine_rates=self.refine_rates,
                           coarse_strides=self.coarse_strides,
                           fine_radius=self.fine_radius,
                           coarse_updates=self.coarse_updates)

    def evaluate(self, scenario, consts, grid, rates):
        from repro.core.montecarlo import montecarlo_objective_grid

        return montecarlo_objective_grid(
            self.X, self.y, scenario, grid, rates, lam=self.lam,
            alpha=self.alpha, n_runs=self.n_runs, seed=self.seed,
            seed_stream=self.seed_stream)

    def effective_overhead(self, scenario, n_c, rate):
        return scenario.effective_overhead(n_c, rate)

    def default_grid(self, N: int) -> np.ndarray:
        return mc_default_grid(N, self.grid_points)

    @property
    def default_grid_size(self) -> int:
        """Cap on the DEFAULT fleet grid width: every grid point is a
        simulated training run, so a bound-sized grid would multiply the
        batched solve cost ~10x (explicit ``grid=`` overrides)."""
        return self.grid_points

    @cached_property
    def data_digest(self) -> str:
        """Content hash of (X, y): two objectives over different data must
        never share a cache entry even if every hyperparameter matches."""
        h = hashlib.sha1()
        for a in (self.X, self.y):
            a = np.ascontiguousarray(np.asarray(a))
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
        return h.hexdigest()[:16]

    def cache_token(self) -> Tuple:
        # grid_points is part of the token: it sets the DEFAULT search
        # grid (scalar default_grid and the fleet default_grid_size cap),
        # so two objectives differing only in it can plan different n_c.
        # crn / seed_stream change the estimator's sample streams and the
        # seed schedule changes which lanes even get simulated — none of
        # those variants may ever alias a reference plan in the cache.
        return (self.objective_id, int(self.n_runs), int(self.seed),
                float(self.lam), float(self.alpha), int(self.grid_points),
                self.data_digest, bool(self.crn), str(self.seed_stream),
                self.coarse_seeds, self.refine_rates, self.coarse_strides,
                self.fine_radius, self.coarse_updates)
