"""Beyond-paper extension 1 (paper Sec. 6: "inclusion of the effect of
delays due to errors in the communication channel ... the optimization
problem could be generalized to account for the selection of the data rate").

Erasure-channel model with retransmissions:

  * a packet (one block) is lost i.i.d. with probability ``p_err(rate)``;
    lost packets are retransmitted until received (stop-and-wait ARQ), so
    the EFFECTIVE block duration is (n_c / rate + n_o) / (1 - p_err) in
    expectation.
  * transmitting faster (rate > 1 samples per time unit) shortens the
    payload time but raises the error probability — the classic
    rate-reliability trade-off, modelled here with an exponential error
    profile p_err(rate) = 1 - exp(-beta (rate - 1)) for rate >= 1.

``effective_overhead``/``effective_tau_c`` convert the noisy channel into
the paper's noiseless normalised-time model, so Corollary 1 and the
block-size planner apply UNCHANGED — the generalisation the paper sketches:
jointly pick (n_c, rate) by minimising the bound over the induced
(tau_c, n_o_eff) grid.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.scenario import P_ERR_MAX


@dataclass(frozen=True)
class ErasureChannel:
    """p_err(rate) = 1 - exp(-beta * (rate - 1)); rate in samples/unit."""

    beta: float = 0.25
    p_base: float = 0.0  # residual loss probability at rate 1

    def p_err(self, rate: float) -> float:
        p = 1.0 - (1.0 - self.p_base) * math.exp(-self.beta * max(rate - 1.0, 0.0))
        return min(p, P_ERR_MAX)

    def expected_block_time(self, n_c: int, n_o: float, rate: float) -> float:
        """E[time to deliver one block] under ARQ retransmission."""
        raw = n_c / rate + n_o
        return raw / (1.0 - self.p_err(rate))


def plan_with_channel(*, N: int, T: float, n_o: float, tau_p: float,
                      consts: BoundConstants, channel: ErasureChannel,
                      rates: Sequence[float] = (1.0, 1.25, 1.5, 2.0, 3.0),
                      grid=None):
    """Joint (n_c, rate) optimisation: for each rate, rescale the block
    duration into the paper's noiseless model and minimise Corollary 1.

    With block time (n_c/rate + n_o)/(1-p) we match the paper's model
    n_c' + n_o' by scaling time units: n_o_eff(n_c, rate) chosen so that
    n_c + n_o_eff equals the expected block time in sample-transmission
    units (tau_p is unchanged — compute speed is unaffected by the link).

    Compatibility wrapper: the search now runs as ONE broadcast bound
    evaluation over the full (rate, n_c) grid inside
    :class:`repro.core.scenario.BoundPlanner` instead of a Python loop
    per grid point.
    """
    from repro.core.scenario import BoundPlanner, ErasureLink, Scenario

    scenario = Scenario(
        N=N, T=T, n_o=n_o, tau_p=tau_p,
        link=ErasureLink(beta=channel.beta, p_base=channel.p_base,
                         rates=tuple(rates)))
    plan = BoundPlanner(grid=grid).plan(scenario, consts)
    return {"n_c": plan.n_c, "rate": plan.rate, "p_err": plan.p_err,
            "bound": plan.bound_value}


def simulate_noisy_stream(*, n_samples: int, n_c: int, n_o: float,
                          rate: float, channel: ErasureChannel, T: float,
                          seed: int = 0):
    """Sample the ARQ delivery timeline: returns the (time, delivered)
    step function actually realised over one channel run."""
    rng = np.random.default_rng(seed)
    p = channel.p_err(rate)
    return _arq_timeline(lambda: rng.random() < p, n_samples=n_samples,
                         n_c=n_c, n_o=n_o, rate=rate, T=T)


def simulate_link_stream(*, n_samples: int, n_c: int, n_o: float,
                         rate: float, link, T: float, seed: int = 0):
    """Registry-generic ARQ delivery timeline for ANY link model.

    The per-attempt loss draws come from ``link.make_loss_process(rate,
    rng)`` — i.i.d. for memoryless channels (erasure, fading), the actual
    two-state chain for Gilbert-Elliott burst loss — so the realised
    timeline reflects the channel's memory, not just its stationary loss
    probability.
    """
    rng = np.random.default_rng(seed)
    return _arq_timeline(link.make_loss_process(float(rate), rng),
                         n_samples=n_samples, n_c=n_c, n_o=n_o, rate=rate,
                         T=T)


def _arq_timeline(lost, *, n_samples: int, n_c: int, n_o: float,
                  rate: float, T: float):
    """Stop-and-wait ARQ run driven by a ``() -> lost?`` sampler."""
    t, delivered = 0.0, 0
    times, counts = [0.0], [0]
    while delivered < n_samples and t < T:
        block = min(n_c, n_samples - delivered)
        t += block / rate + n_o
        while lost() and t < T:  # retransmit until received
            t += block / rate + n_o
        if t >= T:
            break
        delivered += block
        times.append(t)
        counts.append(delivered)
    return np.asarray(times), np.asarray(counts)
