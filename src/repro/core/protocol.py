"""Block/timeline arithmetic of the paper's pipelined protocol (Sec. 2, Fig. 2).

All times are normalised to the transmission time of one data sample.  One
SGD update costs ``tau_p``.  A block carries ``n_c`` samples plus an overhead
``n_o`` (pilots/meta-data), so a block lasts ``n_c + n_o``.

Two regimes (Fig. 2):
  (a) T <= B_d (n_c + n_o): only a fraction of the dataset arrives;
  (b) T  > B_d (n_c + n_o): the full set arrives, leaving a tail block B_l
      of duration tau_l = T - B_d (n_c + n_o) for training on all data.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class BlockSchedule:
    N: int          # dataset size (samples)
    n_c: int        # samples per block
    n_o: float      # per-block overhead (normalised time)
    T: float        # deadline (normalised time)
    tau_p: float    # time per SGD update

    def __post_init__(self):
        # n_o may legitimately be NEGATIVE (a fast link's effective
        # overhead after the ARQ reduction) as long as blocks keep a
        # positive duration; everything else degenerates the timeline
        # arithmetic (zero-duration blocks loop forever in available_at).
        if self.N < 1:
            raise ValueError(f"N must be >= 1, got {self.N}")
        if self.n_c < 1:
            raise ValueError(f"n_c must be >= 1, got {self.n_c}")
        if not self.T > 0.0:
            raise ValueError(f"T must be > 0, got {self.T}")
        if not self.tau_p > 0.0:
            raise ValueError(f"tau_p must be > 0, got {self.tau_p}")
        if not self.n_c + self.n_o > 0.0:
            raise ValueError(
                f"block duration n_c + n_o must be > 0, got "
                f"{self.n_c} + {self.n_o}")

    # ---- protocol quantities (paper notation) -----------------------------
    @property
    def block_duration(self) -> float:
        return self.n_c + self.n_o

    @property
    def B_d(self) -> float:
        """Blocks sufficient to deliver the entire dataset."""
        return self.N / self.n_c

    @property
    def full_transfer(self) -> bool:
        """Regime (b): whole dataset delivered before T.

        Uses the DELIVERED count (ceil-block semantics) so the flag is
        consistent with the simulation even when n_c does not divide N;
        the paper's continuous B_d = N/n_c criterion is kept in the bound
        evaluator (bounds.corollary1_bound) exactly as published."""
        return self.available_at(self.T) >= self.N

    @property
    def B(self) -> int:
        """Number of (whole) blocks that fit in T (regime (a) count)."""
        return int(self.T // self.block_duration)

    @property
    def tau_l(self) -> float:
        """Tail-block duration (regime (b) only)."""
        return max(self.T - self.B_d * self.block_duration, 0.0)

    @property
    def n_p(self) -> int:
        """SGD updates per regular block."""
        return max(int(self.block_duration // self.tau_p), 0)

    @property
    def n_l(self) -> int:
        """SGD updates in the tail block."""
        return int(self.tau_l // self.tau_p)

    @property
    def delivered_fraction(self) -> float:
        """Fraction of the dataset available to the learner at time T."""
        if self.full_transfer:
            return 1.0
        return min(max(self.B - 1, 0) / self.B_d, 1.0)

    # ---- simulation helpers -------------------------------------------------
    @property
    def total_updates(self) -> int:
        """SGD updates that fit in [0, T]."""
        return int(self.T // self.tau_p)

    def available_at(self, t: float) -> int:
        """Samples available at the edge at (normalised) time t.

        Block b (1-indexed) occupies [ (b-1)*dur, b*dur ); its samples become
        available at the END of the block, i.e. from b*dur onwards.
        """
        blocks_done = int(t // self.block_duration)
        return min(blocks_done * self.n_c, self.N)

    def updates_timeline(self):
        """Array of 'samples available' for each update step j=0..total-1
        (the j-th update runs during [j*tau_p, (j+1)*tau_p))."""
        import numpy as np

        t = np.arange(self.total_updates, dtype=np.float64) * self.tau_p
        blocks_done = np.floor(t / self.block_duration).astype(np.int64)
        return np.minimum(blocks_done * self.n_c, self.N)


def boundary_n_c(N: int, T: float, n_o: float) -> float:
    """n_c at which T == B_d (n_c + n_o) — the regime boundary (Fig. 3 dots).

    B_d (n_c + n_o) = N (1 + n_o / n_c) = T  =>  n_c = N n_o / (T - N).
    Returns +inf when T <= N (the whole set can never be delivered) and
    0.0 when n_o <= 0: a link-induced EFFECTIVE overhead can be negative
    (rate > 1 outruns the ARQ inflation), in which case every block size
    delivers the full set before T — the boundary sits below the grid.
    """
    if T <= N:
        return math.inf
    return max(N * n_o / (T - N), 0.0)
