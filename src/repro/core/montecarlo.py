"""Monte-Carlo evaluation of Theorem 1 (eqs. 12-13).

The paper motivates Corollary 1 because Theorem 1 "would require ... running
Monte Carlo experiments for every randomly selected sample of the sequence
of SGD updates, which is computationally intractable" at scale.  At the
ridge-regression scale it IS tractable, which lets us quantify exactly how
loose Corollary 1 is: we estimate the per-block quantities
E_b[L_b(w_b^{n_p}) - L_b(w_b*)] by running the pipelined trainer and
evaluating the block-local empirical losses at the block boundaries, then
plug them into Theorem 1.

This module also hosts the scalar REFERENCE evaluation of the Monte-Carlo
ridge objective (:func:`montecarlo_objective_grid`) — the per-grid-point
empirical mean final loss that :class:`~repro.core.objectives.MonteCarloObjective`
declares and the batched fleet kernel in
:mod:`repro.fleet.objective_kernels` must reproduce seed-for-seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bounds import BoundConstants, theorem1_bound
from repro.core.pipeline import ridge_loss_full
from repro.core.protocol import BlockSchedule


def montecarlo_objective_grid(X, y, scenario, grid, rates, *,
                              lam: float = 0.05, alpha: float = 1e-4,
                              n_runs: int = 3, seed: int = 0,
                              seed_stream: str = "fold_in") -> np.ndarray:
    """Scalar reference of the Monte-Carlo ridge objective: the ``(R, G)``
    empirical mean final loss over the joint ``(rate, n_c)`` grid.

    One :func:`~repro.core.pipeline.average_final_loss` call (a single
    vmapped seed batch) per grid point, at the scenario's link-induced
    effective overhead — exactly the loop the pre-registry
    ``MonteCarloPlanner`` ran, moved here so the scalar planner and the
    objective registry share one reference implementation.
    """
    from repro.core.pipeline import average_final_loss

    grid = np.asarray(grid)
    rates = np.asarray(rates, np.float64)
    vals = np.empty((rates.size, grid.size))
    for ri, rate in enumerate(rates):
        for gi, n_c in enumerate(grid):
            n_o_eff = float(scenario.effective_overhead(int(n_c), rate))
            vals[ri, gi] = average_final_loss(
                X, y, n_c=int(n_c), n_o=n_o_eff, T=scenario.T,
                tau_p=scenario.tau_p, n_runs=n_runs, alpha=alpha, lam=lam,
                seed=seed, seed_stream=seed_stream)
    return vals


def _block_local_loss(w, X_blk, y_blk, lam, n_total):
    r = X_blk @ w - y_blk
    return float(np.mean(r ** 2) + lam / n_total * np.sum(w ** 2))


def _block_local_opt(X_blk, y_blk, lam, n_total):
    d = X_blk.shape[1]
    scale = len(X_blk)
    w = np.linalg.solve(X_blk.T @ X_blk + lam * scale / n_total * np.eye(d),
                        X_blk.T @ y_blk)
    return w


def estimate_theorem1(X, y, *, n_c: int, n_o: float, T: float,
                      consts: BoundConstants, lam: float = 0.05,
                      alpha: float = 1e-4, tau_p: float = 1.0,
                      n_runs: int = 3, seed: int = 0):
    """Monte-Carlo Theorem-1 estimate + the matching Corollary-1 value.

    Returns dict with 'theorem1', 'corollary1', 'empirical_gap' (the actual
    E[L(w_T) - L(w*)] from the runs).
    """
    from repro.core.bounds import corollary1_bound
    from repro.core.pipeline import run_pipelined_sgd

    n, d = X.shape
    plan = BlockSchedule(N=n, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p)
    # global optimum for the empirical gap
    w_star = np.linalg.solve(X.T @ X + lam * np.eye(d), X.T @ y)
    loss_star = float(np.mean((X @ w_star - y) ** 2)
                      + lam / n * np.sum(w_star ** 2))

    n_blocks = plan.B if not plan.full_transfer else int(np.ceil(plan.B_d))
    rng = np.random.default_rng(seed)

    per_block_gaps = np.zeros(max(n_blocks, 1))
    emp_gap = 0.0
    for r in range(n_runs):
        res = run_pipelined_sgd(X, y, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p,
                                alpha=alpha, lam=lam, seed=seed + 31 * r,
                                record_every=1)
        # reconstruct block boundaries on the update timeline
        perm = np.asarray(jax.random.permutation(
            jax.random.PRNGKey(seed + 31 * r), n))
        # loss trace is per update (one every tau_p time units); block b
        # ends at update floor(b * dur / tau_p)
        for b in range(1, n_blocks + 1):
            t_end = min(int(b * plan.block_duration / tau_p) - 1,
                        len(res.loss_trace) - 1)
            blk_idx = perm[(b - 1) * n_c: b * n_c]
            if len(blk_idx) == 0 or t_end < 0:
                continue
            # approximate w at block end via the recorded full loss is not
            # enough — rerun? Instead we use the final w for the last block
            # and bound the others by the FULL loss at that time (the
            # block-local loss concentrates around it for random blocks)
            per_block_gaps[b - 1] += res.loss_trace[t_end] - loss_star
        emp_gap += res.final_loss - loss_star
    per_block_gaps /= n_runs
    emp_gap /= n_runs

    th1 = theorem1_bound(per_block_gaps,
                         delta_gap_B=float(per_block_gaps[-1]),
                         N=n, T=T, n_c=n_c, n_o=n_o, tau_p=tau_p,
                         consts=consts)
    c1 = float(corollary1_bound(np.asarray([n_c]), N=n, T=T, n_o=n_o,
                                tau_p=tau_p, consts=consts)[0])
    return {"theorem1": float(th1), "corollary1": c1,
            "empirical_gap": float(emp_gap),
            "looseness_c1_over_th1": float(c1 / max(th1, 1e-12))}
