"""The pipelined streaming-SGD trainer (paper Secs. 2 & 5).

``run_pipelined_sgd`` simulates the exact protocol on the ridge-regression
task: blocks of ``n_c`` samples arrive every ``n_c + n_o`` time units while
SGD updates run every ``tau_p`` units on the prefix received so far.  The
whole timeline executes as one ``jax.lax.scan`` over update slots — fully
jitted, so the Fig. 3/4 sweeps run in seconds on CPU.

``n_c = N`` recovers the sequential transmit-everything-first baseline the
paper argues against (single block, single overhead, no pipelining).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import BlockSchedule


# ---------------------------------------------------------------------------
# Ridge-regression objective (paper Sec. 5)
# ---------------------------------------------------------------------------


def ridge_loss_full(w, X, y, lam):
    """L(w) = (1/N) sum (w^T x - y)^2 + (lam/N)||w||^2   (paper's ell summed)."""
    r = X @ w - y
    n = X.shape[0]
    return jnp.mean(r ** 2) + lam / n * jnp.sum(w ** 2)


def ridge_grad_sample(w, x, yv, lam, n):
    """grad of ell(w, (x,y)) = (w^T x - y)^2 + (lam/N)||w||^2."""
    return 2.0 * (jnp.dot(w, x) - yv) * x + 2.0 * lam / n * w


# ---------------------------------------------------------------------------
# Pipelined trainer
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StreamResult:
    w_final: np.ndarray
    final_loss: float
    loss_trace: np.ndarray    # loss every `record_every` updates
    trace_times: np.ndarray   # normalised times of the trace entries
    delivered: int


def _scan_core(X, y, perm, w0, alpha, lam, key, *, n_c: int, n_o: float,
               T: float, tau_p: float, record_every: int):
    n, d = X.shape
    plan = BlockSchedule(N=n, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p)
    total = plan.total_updates
    # samples available at each update slot (host-computed static timeline)
    avail = jnp.asarray(plan.updates_timeline(), jnp.int32)

    Xs = X[perm]  # streaming order: uniform w/o replacement == random perm
    ys = y[perm]

    def step(carry, inp):
        w, k = carry
        a_t = inp
        k, sub = jax.random.split(k)
        idx = jax.random.randint(sub, (), 0, jnp.maximum(a_t, 1))
        g = ridge_grad_sample(w, Xs[idx], ys[idx], lam, n)
        w_new = w - alpha * g
        w = jnp.where(a_t > 0, w_new, w)  # no data yet -> no update
        return (w, k), ridge_loss_full(w, X, y, lam)

    (w_fin, _), losses = jax.lax.scan(step, (w0, key), avail)
    # subsample the trace
    rec = losses[record_every - 1::record_every]
    return w_fin, ridge_loss_full(w_fin, X, y, lam), rec


_run_scan = partial(jax.jit, static_argnames=(
    "n_c", "n_o", "T", "tau_p", "record_every"))(_scan_core)


def mc_run_key(seed0, r, seed_stream: str = "fold_in"):
    """Per-run PRNG key of the Monte-Carlo seed loop.

    ``"fold_in"`` (default) derives run ``r`` as ``fold_in(PRNGKey(seed0),
    r)`` — distinct (seed0, r) pairs can never share a key.  ``"legacy"``
    reproduces the historical ``PRNGKey(seed0 + 97 r)`` streams, which
    alias across nearby base seeds (seed0=0 run 1 == seed0=97 run 0); it
    exists only to pin old results, e.g. the fleet parity suite.
    """
    if seed_stream == "legacy":
        return jax.random.PRNGKey(seed0 + 97 * r)
    if seed_stream != "fold_in":
        raise ValueError(f"unknown seed_stream {seed_stream!r}")
    return jax.random.fold_in(jax.random.PRNGKey(seed0), r)


@partial(jax.jit,
         static_argnames=("n_c", "n_o", "T", "tau_p", "n_runs",
                          "seed_stream"))
def _mc_final_losses(X, y, alpha, lam, seed0, *, n_c: int, n_o: float,
                     T: float, tau_p: float, n_runs: int,
                     seed_stream: str = "fold_in"):
    """Final loss for ``n_runs`` independent seeds as ONE vmapped scan —
    the Monte-Carlo seed loop of the experimental-optimum search runs
    batched instead of one jitted call per seed."""
    n, d = X.shape

    def one(r):
        key = mc_run_key(seed0, r, seed_stream)
        kp, kw, ks = jax.random.split(key, 3)
        perm = jax.random.permutation(kp, n)
        w0 = jax.random.normal(kw, (d,))
        _, floss, _ = _scan_core(X, y, perm, w0, alpha, lam, ks, n_c=n_c,
                                 n_o=n_o, T=T, tau_p=tau_p,
                                 record_every=1_000_000_000)
        return floss

    return jax.vmap(one)(jnp.arange(n_runs))


def run_pipelined_sgd(X, y, *, n_c: int, n_o: float, T: float,
                      tau_p: float = 1.0, alpha: float = 1e-4,
                      lam: float = 0.05, seed: int = 0,
                      w0: Optional[np.ndarray] = None,
                      record_every: int = 256,
                      key=None) -> StreamResult:
    n, d = X.shape
    if key is None:
        key = jax.random.PRNGKey(seed)
    kp, kw, ks = jax.random.split(key, 3)
    perm = jax.random.permutation(kp, n)
    if w0 is None:
        w0 = jax.random.normal(kw, (d,))  # paper: i.i.d. N(0, 1) init
    plan = BlockSchedule(N=n, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p)
    w_fin, floss, rec = _run_scan(
        jnp.asarray(X), jnp.asarray(y), perm, jnp.asarray(w0),
        alpha, lam, ks, n_c=int(n_c), n_o=float(n_o), T=float(T),
        tau_p=float(tau_p), record_every=int(record_every))
    times = (np.arange(len(rec)) + 1) * record_every * tau_p
    return StreamResult(
        w_final=np.asarray(w_fin), final_loss=float(floss),
        loss_trace=np.asarray(rec), trace_times=times,
        delivered=plan.available_at(T))


def average_final_loss(X, y, *, n_c: int, n_o: float, T: float,
                       n_runs: int = 5, **kw) -> float:
    """Monte-Carlo average of the final training loss (paper's experimental
    optimum search computes this per candidate n_c).

    The seeds run as a single ``jax.vmap``-batched scan rather than a
    Python loop of jitted calls.  Per-run keys come from
    :func:`mc_run_key` — collision-free ``fold_in`` streams by default,
    ``seed_stream="legacy"`` for the historical ``seed0 + 97 r`` keys.
    Passing ``w0`` falls back to the sequential path, which the batched
    kernel does not support.
    """
    seed0 = kw.pop("seed", 0)
    seed_stream = kw.pop("seed_stream", "fold_in")
    if kw.get("w0") is not None:
        losses = [run_pipelined_sgd(
            X, y, n_c=n_c, n_o=n_o, T=T,
            key=mc_run_key(seed0, r, seed_stream), **kw).final_loss
                  for r in range(n_runs)]
        return float(np.mean(losses))
    kw.pop("w0", None)
    kw.pop("record_every", None)  # only affects the (unused) trace
    losses = _mc_final_losses(
        jnp.asarray(X), jnp.asarray(y), kw.pop("alpha", 1e-4),
        kw.pop("lam", 0.05), seed0, n_c=int(n_c), n_o=float(n_o),
        T=float(T), tau_p=float(kw.pop("tau_p", 1.0)), n_runs=int(n_runs),
        seed_stream=str(seed_stream))
    if kw:
        raise TypeError(f"unexpected keyword arguments: {sorted(kw)}")
    return float(np.mean(np.asarray(losses)))
