"""Block-size planner: minimise the Corollary-1 bound over n_c.

This is the paper's practical recipe: evaluate the Monte-Carlo-free bound
(14)-(15) on a grid of block sizes and pick the minimiser n_c-tilde.  The
planner also reports the regime boundary (the dots in Fig. 3) and supports
calibrating (L, c) from a data Gramian and (tau_p, n_o) from measured
step/transfer times of a real mesh.

The unified scenario API lives in :mod:`repro.core.scenario`: a frozen
``Scenario`` (dataset/deadline/overhead + ``LinkModel`` + ``Topology``)
is planned by any ``Planner`` (``ObjectivePlanner`` over any objective
from the registry in :mod:`repro.core.objectives`, or the
``BoundPlanner`` / ``MonteCarloPlanner`` / ``Theorem1Planner`` facades) —
all of which return the enriched :class:`Plan` below — and executed by
the ``Simulator`` facade.  ``optimize_block_size`` is kept as a thin
compatibility wrapper over ``BoundPlanner`` on the ideal-link
single-device scenario.  ``Plan.objective`` records which registered
objective the ``bound_value`` minimises.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.protocol import BlockSchedule


@dataclass(frozen=True)
class Plan:
    """The unified planner output (every Planner returns this type).

    For a ``SingleDevice``/``IdealLink`` scenario the extra fields take
    their neutral defaults (rate 1, no losses, per-device == union), so
    the type is backward-compatible with the original bound planner.
    """
    n_c: int                 # optimised UNION block size (n_c-tilde)
    bound_value: float       # planner objective at the optimum
    full_transfer: bool      # whether the optimum delivers the whole set
    boundary: float          # n_c where T = B_d (n_c + n_o)
    grid: np.ndarray         # evaluated n_c grid
    bound_grid: np.ndarray   # objective per grid point (at the chosen rate)
    schedule: BlockSchedule  # effective single-device schedule at the optimum
    rate: float = 1.0            # chosen transmission rate (samples/unit)
    p_err: float = 0.0           # packet-loss probability at that rate
    n_c_per_device: int = 0      # per-device block size; planners set n_c // D
    objective: str = "corollary1"  # which objective bound_value minimises


def default_grid(N: int) -> np.ndarray:
    """Log-spaced integer grid 1..N (dense enough for a smooth Fig. 3)."""
    g = np.unique(np.round(np.logspace(0, np.log10(N), 400)).astype(np.int64))
    return g[g >= 1]


def fleet_grid(N, size: int = 128) -> np.ndarray:
    """Fixed-width log-spaced integer grid(s) 1..N for batched planning.

    Unlike :func:`default_grid` the output is NOT deduplicated, so every
    scenario in a heterogeneous batch gets the same grid width regardless
    of its ``N`` — the shape invariance ``vmap``/``jit`` need.  Duplicate
    grid points are harmless: argmin tie-breaking picks the first.

    ``N`` may be a scalar (returns ``(size,)``) or a 1-D array of
    per-scenario dataset sizes (returns ``(len(N), size)``).
    """
    if size < 1:
        raise ValueError(f"size must be >= 1, got {size}")
    N = np.asarray(N, np.int64)
    if np.any(N < 1):
        raise ValueError("every N must be >= 1")
    expo = (np.linspace(0.0, 1.0, size)
            * np.log10(N.astype(np.float64))[..., None])
    return np.maximum(np.round(10.0 ** expo).astype(np.int64), 1)


def optimize_block_size(*, N: int, T: float, n_o: float, tau_p: float,
                        consts: BoundConstants,
                        grid: Optional[Sequence[int]] = None) -> Plan:
    """Compatibility wrapper: Corollary-1 planning of the paper's baseline
    scenario (ideal link, single device).  Equivalent to
    ``BoundPlanner(grid=grid).plan(Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p),
    consts)``."""
    from repro.core.scenario import BoundPlanner, Scenario

    scenario = Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p)
    return BoundPlanner(grid=grid).plan(scenario, consts)


def calibrate_tau_p(step_time_s: float, sample_tx_time_s: float) -> float:
    """Normalise a measured train-step time to sample-transmission units
    (how the planner binds to a real mesh: step time from the roofline
    model or a profile, transfer time from link bandwidth)."""
    return step_time_s / sample_tx_time_s


def calibrate_n_o(fixed_transfer_cost_s: float, sample_tx_time_s: float) -> float:
    """Per-transfer fixed cost (dispatch/collective setup) in sample units."""
    return fixed_transfer_cost_s / sample_tx_time_s
