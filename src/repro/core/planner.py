"""Block-size planner: minimise the Corollary-1 bound over n_c.

This is the paper's practical recipe: evaluate the Monte-Carlo-free bound
(14)-(15) on a grid of block sizes and pick the minimiser n_c-tilde.  The
planner also reports the regime boundary (the dots in Fig. 3) and supports
calibrating (L, c) from a data Gramian and (tau_p, n_o) from measured
step/transfer times of a real mesh — the TPU binding described in
DESIGN.md §2.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import BoundConstants, corollary1_bound
from repro.core.protocol import BlockSchedule, boundary_n_c


@dataclass(frozen=True)
class Plan:
    n_c: int                 # optimised block size (n_c-tilde)
    bound_value: float       # Corollary-1 bound at the optimum
    full_transfer: bool      # whether the optimum delivers the whole set
    boundary: float          # n_c where T = B_d (n_c + n_o)
    grid: np.ndarray         # evaluated n_c grid
    bound_grid: np.ndarray   # bound value per grid point
    schedule: BlockSchedule


def default_grid(N: int) -> np.ndarray:
    """Log-spaced integer grid 1..N (dense enough for a smooth Fig. 3)."""
    g = np.unique(np.round(np.logspace(0, np.log10(N), 400)).astype(np.int64))
    return g[g >= 1]


def optimize_block_size(*, N: int, T: float, n_o: float, tau_p: float,
                        consts: BoundConstants,
                        grid: Optional[Sequence[int]] = None) -> Plan:
    consts.validate()
    grid = np.asarray(grid if grid is not None else default_grid(N))
    vals = corollary1_bound(grid, N=N, T=T, n_o=n_o, tau_p=tau_p, consts=consts)
    i = int(np.argmin(vals))
    n_c = int(grid[i])
    sched = BlockSchedule(N=N, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p)
    return Plan(
        n_c=n_c,
        bound_value=float(vals[i]),
        full_transfer=sched.full_transfer,
        boundary=boundary_n_c(N, T, n_o),
        grid=grid,
        bound_grid=vals,
        schedule=sched,
    )


def calibrate_tau_p(step_time_s: float, sample_tx_time_s: float) -> float:
    """Normalise a measured train-step time to sample-transmission units
    (how the planner binds to a real mesh: step time from the roofline
    model or a profile, transfer time from link bandwidth)."""
    return step_time_s / sample_tx_time_s


def calibrate_n_o(fixed_transfer_cost_s: float, sample_tx_time_s: float) -> float:
    """Per-transfer fixed cost (dispatch/collective setup) in sample units."""
    return fixed_transfer_cost_s / sample_tx_time_s
