"""Block-size planner: minimise the Corollary-1 bound over n_c.

This is the paper's practical recipe: evaluate the Monte-Carlo-free bound
(14)-(15) on a grid of block sizes and pick the minimiser n_c-tilde.  The
planner also reports the regime boundary (the dots in Fig. 3) and supports
calibrating (L, c) from a data Gramian and (tau_p, n_o) from measured
step/transfer times of a real mesh.

The unified scenario API lives in :mod:`repro.core.scenario`: a frozen
``Scenario`` (dataset/deadline/overhead + ``LinkModel`` + ``Topology``)
is planned by any ``Planner`` (``ObjectivePlanner`` over any objective
from the registry in :mod:`repro.core.objectives`, or the
``BoundPlanner`` / ``MonteCarloPlanner`` / ``Theorem1Planner`` facades) —
all of which return the enriched :class:`Plan` below — and executed by
the ``Simulator`` facade.  ``optimize_block_size`` is kept as a thin
compatibility wrapper over ``BoundPlanner`` on the ideal-link
single-device scenario.  ``Plan.objective`` records which registered
objective the ``bound_value`` minimises.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.protocol import BlockSchedule


@dataclass(frozen=True)
class Plan:
    """The unified planner output (every Planner returns this type).

    For a ``SingleDevice``/``IdealLink`` scenario the extra fields take
    their neutral defaults (rate 1, no losses, per-device == union), so
    the type is backward-compatible with the original bound planner.
    """
    n_c: int                 # optimised UNION block size (n_c-tilde)
    bound_value: float       # planner objective at the optimum
    full_transfer: bool      # whether the optimum delivers the whole set
    boundary: float          # n_c where T = B_d (n_c + n_o)
    grid: np.ndarray         # evaluated n_c grid
    bound_grid: np.ndarray   # objective per grid point (at the chosen rate)
    schedule: BlockSchedule  # effective single-device schedule at the optimum
    rate: float = 1.0            # chosen transmission rate (samples/unit)
    p_err: float = 0.0           # packet-loss probability at that rate
    n_c_per_device: int = 0      # per-device block size; planners set n_c // D
    objective: str = "corollary1"  # which objective bound_value minimises


def default_grid(N: int) -> np.ndarray:
    """Log-spaced integer grid 1..N (dense enough for a smooth Fig. 3)."""
    g = np.unique(np.round(np.logspace(0, np.log10(N), 400)).astype(np.int64))
    return g[g >= 1]


def fleet_grid(N, size: int = 128) -> np.ndarray:
    """Fixed-width log-spaced integer grid(s) 1..N for batched planning.

    Unlike :func:`default_grid` the output is NOT deduplicated, so every
    scenario in a heterogeneous batch gets the same grid width regardless
    of its ``N`` — the shape invariance ``vmap``/``jit`` need.  Duplicate
    grid points are harmless: argmin tie-breaking picks the first.

    ``N`` may be a scalar (returns ``(size,)``) or a 1-D array of
    per-scenario dataset sizes (returns ``(len(N), size)``).
    """
    if size < 1:
        raise ValueError(f"size must be >= 1, got {size}")
    N = np.asarray(N, np.int64)
    if np.any(N < 1):
        raise ValueError("every N must be >= 1")
    expo = (np.linspace(0.0, 1.0, size)
            * np.log10(N.astype(np.float64))[..., None])
    return np.maximum(np.round(10.0 ** expo).astype(np.int64), 1)


def coarse_indices(G: int, stride: int) -> np.ndarray:
    """Dense-grid indices evaluated by the coarse pass of the two-pass
    (coarse -> fine) fleet solve: every ``stride``-th point PLUS the last
    point.  Anchoring the last index matters: the full-transfer end of the
    grid (``n_c = N``, the single-block plan) is frequently the optimum and
    a plain ``::stride`` subsample never sees it.
    """
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    idx = np.arange(0, G, stride, dtype=np.int64)
    if idx[-1] != G - 1:
        idx = np.append(idx, G - 1)
    return idx


def refine_window_bounds(centers: np.ndarray, stride: int, G: int,
                         tail_start: Optional[np.ndarray] = None):
    """Interval arithmetic shared by :func:`refine_grid` and the fused
    on-device window builder in :mod:`repro.fleet.objective_kernels`
    (which mirrors it op-for-op in ``jax.numpy``): the union of the
    bracket ``[c - stride, c + stride]`` and the tail ``[t, G)`` as one
    or two ascending index intervals.

    Returns ``(lo, hi2, t2, len1, count)``, all ``(S, R)``: the first
    interval is ``[lo, hi2]`` (``len1`` wide), the second ``[t2, G)``
    (empty when ``t2 == G``), and ``count`` the total window width.
    """
    centers = np.asarray(centers, np.int64)
    lo = np.maximum(centers - stride, 0)                       # (S, R)
    hi = np.minimum(centers + stride, G - 1)
    if tail_start is None:
        t = np.full(centers.shape[0], G, np.int64)
    else:
        t = np.clip(np.asarray(tail_start, np.int64), 0, G)
    t = np.broadcast_to(t[:, None], centers.shape)
    # union of [lo, hi] and [t, G): one interval when they touch/overlap
    single = t <= hi + 1
    lo = np.where(single, np.minimum(lo, t), lo)
    hi2 = np.where(single, G - 1, hi)
    t2 = np.where(single, G, t)
    len1 = hi2 - lo + 1
    return lo, hi2, t2, len1, len1 + (G - t2)


def refine_grid(grid: np.ndarray, centers: np.ndarray, stride: int,
                tail_start: Optional[np.ndarray] = None,
                width: Optional[int] = None, pad_multiple: int = 1):
    """Per-rate fine-grid windows bracketing the coarse argmins.

    The fine pass of the two-pass fleet solve evaluates, for every
    ``(scenario, rate)`` lane, the dense indices in the union of

      * the BRACKET ``[c - stride, c + stride]`` around that rate's coarse
        argmin ``c`` (clamped at the grid edges) — under the bound's
        unimodal-per-regime structure this contains the dense per-rate
        argmin whenever the basin is resolved by the coarse grid; and
      * the guarded TAIL ``[tail_start, G)`` — the small-block-count
        suffix where the objective's floor arithmetic (``ceil(B_d)/B_d``
        in Corollary 1) turns into a sawtooth that bracketing cannot
        follow, so it is evaluated densely.

    Both components are ascending index intervals, so the union is one or
    two intervals and the window enumerates dense indices in ASCENDING
    order — which is what keeps rate-major argmin tie-breaking identical
    to the single-pass dense solve.  Trailing padding (up to the common
    width ``W``) repeats the window's last real index; duplicates can
    never win an argmin tie against their first occurrence.

    ``grid`` is the dense ``(S, G)`` grid, ``centers`` the ``(S, R)``
    dense indices of the per-rate coarse argmins, ``tail_start`` an
    optional ``(S,)`` first tail index (``G`` disables the tail for that
    scenario).  The padded width is the widest window rounded up to
    ``width`` (if given) or to a multiple of ``pad_multiple`` — a serving
    stream with per-scenario tails then compiles ``O(G / pad_multiple)``
    fine-pass shapes instead of one per distinct tail length.  Returns
    ``(win_idx, win_grid, count)`` with shapes ``(S, R, W)``,
    ``(S, R, W)`` and ``(S, R)``.
    """
    grid = np.asarray(grid)
    S, G = grid.shape
    lo, hi2, t2, len1, count = refine_window_bounds(centers, stride, G,
                                                    tail_start)
    widest = int(count.max())
    if width is None:
        width = -(-widest // pad_multiple) * pad_multiple
    W = min(int(width), G)
    if W < widest:
        raise ValueError(f"width={W} < widest window {widest}")
    # positions j < len1 walk the bracket from lo, then jump to the tail
    # at t2, then (j >= count) repeat the last real index as padding —
    # expressed as two conditional jumps so only three (S, R, W)
    # temporaries are materialised (this runs on the serving hot path)
    j = np.arange(W, dtype=np.int32)
    pad = np.where(t2 < G, G - 1, hi2)        # last REAL index of the window
    win_idx = lo[..., None].astype(np.int32) + j
    win_idx += (t2 - lo - len1)[..., None].astype(np.int32) \
        * (j >= len1[..., None].astype(np.int32))
    np.minimum(win_idx, pad[..., None].astype(np.int32), out=win_idx)
    win_idx = win_idx.astype(np.int64)
    win_grid = grid[np.arange(S)[:, None, None], win_idx]
    return win_idx, win_grid, count


def optimize_block_size(*, N: int, T: float, n_o: float, tau_p: float,
                        consts: BoundConstants,
                        grid: Optional[Sequence[int]] = None) -> Plan:
    """Compatibility wrapper: Corollary-1 planning of the paper's baseline
    scenario (ideal link, single device).  Equivalent to
    ``BoundPlanner(grid=grid).plan(Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p),
    consts)``."""
    from repro.core.scenario import BoundPlanner, Scenario

    scenario = Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p)
    return BoundPlanner(grid=grid).plan(scenario, consts)


def calibrate_tau_p(step_time_s: float, sample_tx_time_s: float) -> float:
    """Normalise a measured train-step time to sample-transmission units
    (how the planner binds to a real mesh: step time from the roofline
    model or a profile, transfer time from link bandwidth)."""
    return step_time_s / sample_tx_time_s


def calibrate_n_o(fixed_transfer_cost_s: float, sample_tx_time_s: float) -> float:
    """Per-transfer fixed cost (dispatch/collective setup) in sample units."""
    return fixed_transfer_cost_s / sample_tx_time_s
