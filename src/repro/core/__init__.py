"""The paper's contribution: pipelined communication/computation scheduling
for latency-constrained edge learning (protocol, bounds, planner, trainers).

The unified surface is the Scenario/Planner/Simulator triple from
:mod:`repro.core.scenario`; the flat functions (``optimize_block_size``,
``plan_with_channel``, ``plan_multi_device``, ``run_pipelined_sgd``) remain
as compatibility wrappers / task kernels."""
from repro.core.bounds import BoundConstants, calibrate_from_gram, corollary1_bound, theorem1_bound
from repro.core.pipeline import (StreamResult, average_final_loss,
                                 ridge_loss_full, run_pipelined_sgd)
from repro.core.planner import Plan, default_grid, optimize_block_size
from repro.core.protocol import BlockSchedule, boundary_n_c
from repro.core.links import (MAX_LINK_PARAMS, P_ERR_MAX, LinkModel,
                              LinkModelSpec, link_spec, link_spec_for,
                              register_link_model, registered_link_models,
                              unregister_link_model)
from repro.core.objectives import (BoundObjective, MarkovARQObjective,
                                   MonteCarloObjective, Objective,
                                   ObjectiveSpec, mc_default_grid,
                                   objective_spec, objective_spec_for,
                                   register_objective, registered_objectives,
                                   unregister_objective)
from repro.core.scenario import (BoundPlanner, ErasureLink, FadingLink,
                                 GilbertElliottLink, IdealLink,
                                 MonteCarloPlanner, MultiDevice,
                                 ObjectivePlanner, Planner, RidgeTask,
                                 Scenario, SimReport, Simulator,
                                 SingleDevice, StreamingTask, Theorem1Planner)
from repro.core.streaming import StreamBuffer, make_buffer, receive_block, sample
from repro.core.stream_trainer import StreamingTrainState, run_streaming_training

__all__ = [
    "BoundConstants", "calibrate_from_gram", "corollary1_bound", "theorem1_bound",
    "StreamResult", "average_final_loss", "ridge_loss_full", "run_pipelined_sgd",
    "Plan", "default_grid", "optimize_block_size",
    "BlockSchedule", "boundary_n_c",
    "Scenario", "IdealLink", "ErasureLink", "FadingLink",
    "GilbertElliottLink", "SingleDevice", "MultiDevice",
    "LinkModel", "LinkModelSpec", "MAX_LINK_PARAMS", "P_ERR_MAX",
    "register_link_model", "registered_link_models", "unregister_link_model",
    "link_spec", "link_spec_for",
    "Objective", "ObjectiveSpec", "BoundObjective", "MonteCarloObjective",
    "MarkovARQObjective", "register_objective", "registered_objectives",
    "unregister_objective", "objective_spec", "objective_spec_for",
    "mc_default_grid",
    "Planner", "ObjectivePlanner", "BoundPlanner", "MonteCarloPlanner",
    "Theorem1Planner",
    "Simulator", "SimReport", "RidgeTask", "StreamingTask",
    "StreamBuffer", "make_buffer", "receive_block", "sample",
    "StreamingTrainState", "run_streaming_training",
]
