"""Pluggable link-model registry: one channel API for every planner path.

The paper's rate-reliability extension (Sec. 6) is the repo's only channel
physics; this module turns it into an extension point.  Every link model is
a frozen dataclass registered in a :class:`LinkModelSpec` table under a
stable integer ``model_id`` and declares

  * numpy scalar semantics — ``p_err(rate)`` and
    ``expected_block_time(n_c, n_o, rate)``, vectorised over broadcastable
    arrays (what the scalar :class:`~repro.core.scenario.BoundPlanner`,
    the Monte-Carlo planners and the Simulator consume);
  * a fixed-width parameter vector — ``pack_params()`` /
    ``from_params(params, rates)`` round-trip the model through a padded
    ``(num_scenarios, MAX_LINK_PARAMS)`` float table (what
    :class:`~repro.fleet.batch.ScenarioBatch` stacks and the jitted fleet
    kernel dispatches over via ``jax.lax.switch``);
  * an ARQ loss process — ``make_loss_process(rate, rng)`` returns a
    stateful ``() -> lost?`` sampler driving the Simulator's realised
    delivery timeline (i.i.d. for memoryless channels, a two-state Markov
    chain for burst loss).

Registering a custom channel is ~50 lines: subclass the dataclass pattern
below, decorate with :func:`register_link_model`, and register its
``p_err`` jax kernel with :func:`repro.fleet.link_kernels.register_link_kernel`
so the batched planner can solve it too (see README "Link models").

Built-in models (ids are part of the on-wire/cache contract — never reuse):

  ====  ======================  ========================================
  id    class                   parameters
  ====  ======================  ========================================
  0     :class:`IdealLink`      (none)
  1     :class:`ErasureLink`    ``beta, p_base``
  2     :class:`FadingLink`     ``snr``
  3     :class:`GilbertElliottLink`  ``beta, p_good, p_bad, p_gb, p_bg``
  ====  ======================  ========================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (Callable, ClassVar, Dict, Protocol, Tuple, Type,
                    runtime_checkable)

import numpy as np

#: Cap on the loss probability: keeps the stop-and-wait ARQ inflation
#: ``1 / (1 - p_err)`` finite however aggressive the rate.  Shared by the
#: numpy semantics here and the jax kernels in ``repro.fleet.link_kernels``
#: so both paths see identical link physics.
P_ERR_MAX = 0.999

#: Padded width of the per-scenario link-parameter table in
#: :class:`~repro.fleet.batch.ScenarioBatch`.  Fixed so the jitted fleet
#: kernel sees one shape regardless of which models a batch mixes; models
#: may declare at most this many parameters.
MAX_LINK_PARAMS = 8


@runtime_checkable
class LinkModel(Protocol):
    """Rate/reliability model of the device->edge link.

    Implementations must be vectorised: ``n_c`` and ``rate`` may be numpy
    arrays broadcastable against each other.
    """

    model_id: ClassVar[int]
    N_PARAMS: ClassVar[int]
    rates: Tuple[float, ...]

    def p_err(self, rate): ...

    def expected_block_time(self, n_c, n_o, rate): ...

    def pack_params(self) -> np.ndarray: ...

    @classmethod
    def from_params(cls, params, rates: Tuple[float, ...]) -> "LinkModel": ...

    def make_loss_process(self, rate: float, rng) -> Callable[[], bool]: ...


@dataclass(frozen=True)
class LinkModelSpec:
    """Registry entry: the stable id, the class, and its parameter width."""

    model_id: int
    name: str
    cls: type
    n_params: int


_SPECS_BY_ID: Dict[int, LinkModelSpec] = {}
_SPECS_BY_CLS: Dict[type, LinkModelSpec] = {}


def register_link_model(cls: Type) -> Type:
    """Class decorator: add a link-model class to the registry.

    The class must carry integer class attributes ``model_id`` (unique,
    >= 0) and ``N_PARAMS`` (<= :data:`MAX_LINK_PARAMS`) and implement the
    :class:`LinkModel` surface (``p_err``, ``expected_block_time``,
    ``pack_params``, ``from_params``, ``make_loss_process``).
    """
    model_id = getattr(cls, "model_id", None)
    if not isinstance(model_id, int) or isinstance(model_id, bool) \
            or model_id < 0:
        raise ValueError(
            f"{cls.__name__}.model_id must be an int >= 0, got {model_id!r}")
    n_params = getattr(cls, "N_PARAMS", None)
    if not isinstance(n_params, int) or n_params < 0:
        raise ValueError(
            f"{cls.__name__}.N_PARAMS must be an int >= 0, got {n_params!r}")
    if n_params > MAX_LINK_PARAMS:
        raise ValueError(
            f"{cls.__name__} declares {n_params} parameters; the padded "
            f"fleet table holds at most MAX_LINK_PARAMS={MAX_LINK_PARAMS}")
    missing = [m for m in ("p_err", "expected_block_time", "pack_params",
                           "from_params", "make_loss_process")
               if not callable(getattr(cls, m, None))]
    if missing:
        raise TypeError(f"{cls.__name__} is missing LinkModel methods "
                        f"{missing}")
    prior = _SPECS_BY_ID.get(model_id)
    if prior is not None and prior.cls is not cls:
        raise ValueError(
            f"model_id {model_id} already registered by {prior.name}")
    spec = LinkModelSpec(model_id=model_id, name=cls.__name__, cls=cls,
                         n_params=n_params)
    _SPECS_BY_ID[model_id] = spec
    _SPECS_BY_CLS[cls] = spec
    return cls


def unregister_link_model(model_id: int) -> None:
    """Remove a registry entry (plugin teardown / tests).  No-op if absent."""
    spec = _SPECS_BY_ID.pop(model_id, None)
    if spec is not None:
        _SPECS_BY_CLS.pop(spec.cls, None)


def link_spec(model_id: int) -> LinkModelSpec:
    """Spec for a registered ``model_id`` (KeyError with guidance if not)."""
    try:
        return _SPECS_BY_ID[model_id]
    except KeyError:
        raise KeyError(
            f"no link model registered under model_id {model_id}; known ids: "
            f"{sorted(_SPECS_BY_ID)}") from None


def link_spec_for(link_or_cls) -> LinkModelSpec:
    """Spec for a link instance or class (KeyError if unregistered)."""
    cls = link_or_cls if isinstance(link_or_cls, type) else type(link_or_cls)
    try:
        return _SPECS_BY_CLS[cls]
    except KeyError:
        raise KeyError(
            f"{cls.__name__} is not a registered link model; decorate it "
            "with repro.core.links.register_link_model") from None


def registered_link_models() -> Tuple[LinkModelSpec, ...]:
    """All registered specs, sorted by ``model_id``."""
    return tuple(_SPECS_BY_ID[i] for i in sorted(_SPECS_BY_ID))


def _validate_rates(rates) -> None:
    if len(rates) == 0:
        raise ValueError("rates must be a non-empty tuple")
    if any(not np.isfinite(r) or r <= 0.0 for r in rates):
        raise ValueError(f"rates must be finite and > 0, got {rates}")
    if any(b <= a for a, b in zip(rates, rates[1:])):
        # duplicates waste grid columns and can skew the rate-major argmin
        # tie-breaking; out-of-order sets silently reorder the tie winner
        raise ValueError(
            f"rates must be strictly ascending (no duplicates), got {rates}")


class _StopAndWaitARQ:
    """Shared semantics of every lossy stop-and-wait link: the expected
    block duration is the lossless time inflated by ``1 / (1 - p_err)``,
    and the default realised loss process draws i.i.d. per attempt."""

    def expected_block_time(self, n_c, n_o, rate):
        raw = np.asarray(n_c, np.float64) / rate + n_o
        return raw / (1.0 - self.p_err(rate))

    def make_loss_process(self, rate: float, rng) -> Callable[[], bool]:
        p = float(self.p_err(float(rate)))
        return lambda: bool(rng.random() < p)


@register_link_model
@dataclass(frozen=True)
class IdealLink:
    """The paper's noiseless unit-rate link (Secs. 2-5)."""

    model_id: ClassVar[int] = 0
    N_PARAMS: ClassVar[int] = 0

    rates: Tuple[float, ...] = (1.0,)

    def __post_init__(self):
        _validate_rates(self.rates)

    def p_err(self, rate):
        return np.zeros_like(np.asarray(rate, np.float64))

    def expected_block_time(self, n_c, n_o, rate):
        return np.asarray(n_c, np.float64) / rate + n_o

    def pack_params(self) -> np.ndarray:
        return np.empty(0, np.float64)

    @classmethod
    def from_params(cls, params, rates) -> "IdealLink":
        return cls(rates=tuple(rates))

    def make_loss_process(self, rate, rng) -> Callable[[], bool]:
        return lambda: False


@register_link_model
@dataclass(frozen=True)
class ErasureLink(_StopAndWaitARQ):
    """Erasure channel with stop-and-wait ARQ (paper Sec. 6, extension 1).

    A packet is lost i.i.d. with probability
    ``p_err(rate) = 1 - (1 - p_base) exp(-beta (rate - 1))`` and
    retransmitted until received, so the EXPECTED block duration is
    ``(n_c / rate + n_o) / (1 - p_err)`` — the classic rate-reliability
    trade-off.  ``rates`` is the candidate set the joint planner searches.

    Rates below 1 transmit slower but are never MORE reliable than the
    nominal rate (the exponent is clamped at 0, so ``p_err == p_base``);
    ``p_err`` is additionally capped at :data:`P_ERR_MAX` so the expected
    ARQ inflation ``1 / (1 - p_err)`` stays finite at any rate.
    """

    model_id: ClassVar[int] = 1
    N_PARAMS: ClassVar[int] = 2

    beta: float = 0.25
    p_base: float = 0.0  # residual loss probability at rate 1
    rates: Tuple[float, ...] = (1.0, 1.25, 1.5, 2.0, 3.0)

    def __post_init__(self):
        _validate_rates(self.rates)
        if not np.isfinite(self.beta) or self.beta < 0.0:
            raise ValueError(f"beta must be finite and >= 0, got {self.beta}")
        if not 0.0 <= self.p_base < 1.0:
            # p_base >= 1 used to be silently masked by the p_err cap,
            # turning an impossible channel into a merely terrible one
            raise ValueError(
                f"p_base must be in [0, 1), got {self.p_base}")

    def p_err(self, rate):
        rate = np.asarray(rate, np.float64)
        p = 1.0 - (1.0 - self.p_base) * np.exp(
            -self.beta * np.maximum(rate - 1.0, 0.0))
        return np.minimum(p, P_ERR_MAX)

    def pack_params(self) -> np.ndarray:
        return np.asarray([self.beta, self.p_base], np.float64)

    @classmethod
    def from_params(cls, params, rates) -> "ErasureLink":
        return cls(beta=float(params[0]), p_base=float(params[1]),
                   rates=tuple(rates))


@register_link_model
@dataclass(frozen=True)
class FadingLink(_StopAndWaitARQ):
    """Block-fading channel with rate-dependent outage.

    Each block sees an independent Rayleigh fade; transmitting at ``rate``
    (samples per unit time, i.e. spectral efficiency in the normalised
    model) fails whenever the instantaneous capacity falls short, giving
    the classic outage probability

        ``p_err(rate) = 1 - exp(-(2**rate - 1) / snr)``

    capped at :data:`P_ERR_MAX`.  ``snr`` is the mean received SNR
    (linear).  Unlike :class:`ErasureLink` the outage already bites at the
    nominal rate 1, and grows doubly-exponentially with the rate — the
    planner's rate selection matters much more on a fading link.
    """

    model_id: ClassVar[int] = 2
    N_PARAMS: ClassVar[int] = 1

    snr: float = 10.0
    rates: Tuple[float, ...] = (1.0, 1.25, 1.5, 2.0, 3.0)

    def __post_init__(self):
        _validate_rates(self.rates)
        if not np.isfinite(self.snr) or self.snr <= 0.0:
            raise ValueError(f"snr must be finite and > 0, got {self.snr}")

    def p_err(self, rate):
        rate = np.asarray(rate, np.float64)
        p = 1.0 - np.exp(-(np.exp2(rate) - 1.0) / self.snr)
        return np.minimum(p, P_ERR_MAX)

    def pack_params(self) -> np.ndarray:
        return np.asarray([self.snr], np.float64)

    @classmethod
    def from_params(cls, params, rates) -> "FadingLink":
        return cls(snr=float(params[0]), rates=tuple(rates))


@register_link_model
@dataclass(frozen=True)
class GilbertElliottLink(_StopAndWaitARQ):
    """Two-state Markov (Gilbert-Elliott) burst-loss channel.

    The link alternates between a good and a bad state with transition
    probabilities ``p_gb`` (good->bad) and ``p_bg`` (bad->good) per
    transmission attempt.  In each state a packet is lost with the
    rate-dependent probability of an :class:`ErasureLink` whose residual
    loss is that state's ``p_good`` / ``p_bad``:

        ``p_state(rate) = 1 - (1 - p_state) exp(-beta (rate - 1))``

    PLANNING uses the stationary loss probability

        ``p_err = p_g + pi_bad (p_b - p_g)``,  ``pi_bad = p_gb / (p_gb + p_bg)``

    (exact for the long-run expected ARQ inflation of an ergodic chain;
    burst structure only shows up in the realised delivery timeline, which
    ``make_loss_process`` samples from the actual chain).  Exact-reduction
    contract: when ``p_good == p_bad`` the convex combination is written
    so ``p_err`` equals ``ErasureLink(beta, p_base=p_good).p_err``
    BITWISE, whatever the transition probabilities.
    """

    model_id: ClassVar[int] = 3
    N_PARAMS: ClassVar[int] = 5

    p_gb: float = 0.05    # P(good -> bad) per transmission attempt
    p_bg: float = 0.5     # P(bad -> good) per transmission attempt
    p_good: float = 0.0   # loss probability in the good state at rate 1
    p_bad: float = 0.5    # loss probability in the bad state at rate 1
    beta: float = 0.25    # rate-sensitivity shared by both states
    rates: Tuple[float, ...] = (1.0, 1.25, 1.5, 2.0, 3.0)

    def __post_init__(self):
        _validate_rates(self.rates)
        if not np.isfinite(self.beta) or self.beta < 0.0:
            raise ValueError(f"beta must be finite and >= 0, got {self.beta}")
        for name in ("p_gb", "p_bg"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.p_gb + self.p_bg <= 0.0:
            raise ValueError(
                "p_gb + p_bg must be > 0 (a frozen chain has no stationary "
                "distribution)")
        for name in ("p_good", "p_bad"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")

    @property
    def stationary_bad(self) -> float:
        """Long-run probability of finding the chain in the bad state."""
        return self.p_gb / (self.p_gb + self.p_bg)

    def _state_p_err(self, rate):
        """Per-state loss probabilities at ``rate`` (uncapped)."""
        decay = np.exp(-self.beta * np.maximum(
            np.asarray(rate, np.float64) - 1.0, 0.0))
        p_g = 1.0 - (1.0 - self.p_good) * decay
        p_b = 1.0 - (1.0 - self.p_bad) * decay
        return p_g, p_b

    def p_err(self, rate):
        p_g, p_b = self._state_p_err(rate)
        # p_g + pi (p_b - p_g), NOT (1-pi) p_g + pi p_b: the difference form
        # is bitwise-exact at p_b == p_g (the ErasureLink reduction)
        p = p_g + self.stationary_bad * (p_b - p_g)
        return np.minimum(p, P_ERR_MAX)

    def exact_arq_inflation(self, rate):
        """EXACT expected transmission attempts per delivered block.

        Markov-reward evaluation of the stop-and-wait ARQ run: every
        attempt costs one slot, the chain steps once per attempt (the same
        semantics ``make_loss_process`` samples), and the run starts from
        the stationary state distribution.  With per-state loss
        probabilities ``p_g, p_b`` (capped at :data:`P_ERR_MAX`) the
        expected attempts-to-success from each state solve the 2x2 linear
        system

            ``T_g = 1 + p_g [(1 - p_gb) T_g + p_gb T_b]``
            ``T_b = 1 + p_b [p_bg T_g + (1 - p_bg) T_b]``

        and the inflation is ``pi_g T_g + pi_b T_b``.  Unlike the
        stationary approximation ``1 / (1 - p_bar)`` this sees that a
        failure is evidence of the bad state — on sticky chains failures
        cluster and the exact inflation is strictly larger.  The
        degenerate chain ``p_good == p_bad`` takes the stationary branch
        explicitly, so the reduction to :class:`ErasureLink` stays
        BITWISE (immune to solver rounding).  Vectorised over ``rate``.
        """
        if self.p_good == self.p_bad:
            return 1.0 / (1.0 - self.p_err(rate))
        p_g, p_b = (np.minimum(p, P_ERR_MAX)
                    for p in self._state_p_err(rate))
        den_g = 1.0 - p_g * (1.0 - self.p_gb)
        den_b = 1.0 - p_b * (1.0 - self.p_bg)
        det = den_g * den_b - p_g * self.p_gb * p_b * self.p_bg
        t_g = (den_b + p_g * self.p_gb) / det
        t_b = (den_g + p_b * self.p_bg) / det
        pi_b = self.stationary_bad
        return t_g + pi_b * (t_b - t_g)

    def exact_expected_block_time(self, n_c, n_o, rate):
        """Expected block duration under the EXACT burst-aware inflation
        (see :meth:`exact_arq_inflation`); what
        :class:`~repro.core.objectives.MarkovARQObjective` plans with.
        The ``p_good == p_bad`` branch reuses the stationary division form
        so it is bitwise-equal to :meth:`expected_block_time`.
        """
        raw = np.asarray(n_c, np.float64) / rate + n_o
        if self.p_good == self.p_bad:
            return raw / (1.0 - self.p_err(rate))
        return raw * self.exact_arq_inflation(rate)

    def pack_params(self) -> np.ndarray:
        return np.asarray([self.beta, self.p_good, self.p_bad,
                           self.p_gb, self.p_bg], np.float64)

    @classmethod
    def from_params(cls, params, rates) -> "GilbertElliottLink":
        return cls(beta=float(params[0]), p_good=float(params[1]),
                   p_bad=float(params[2]), p_gb=float(params[3]),
                   p_bg=float(params[4]), rates=tuple(rates))

    def make_loss_process(self, rate, rng) -> Callable[[], bool]:
        """Sample the actual two-state chain (bursts and all), one step per
        transmission attempt, starting from the stationary distribution."""
        p_g, p_b = (min(float(p), P_ERR_MAX)
                    for p in self._state_p_err(float(rate)))
        state = {"bad": bool(rng.random() < self.stationary_bad)}

        def step() -> bool:
            lost = rng.random() < (p_b if state["bad"] else p_g)
            flip = rng.random() < (self.p_bg if state["bad"] else self.p_gb)
            if flip:
                state["bad"] = not state["bad"]
            return bool(lost)

        return step
