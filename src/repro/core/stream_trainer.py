"""Generic streaming trainer: the paper's schedule wrapped around ANY
``train_step`` (all 10 assigned architectures train under it).

The sample unit is one packed sequence.  The host-side ``BlockStreamer``
delivers blocks of sequences on the paper's timeline; every ``tau_p`` time
units the edge (the TPU mesh) takes one SGD step on a mini-batch drawn
uniformly from the delivered prefix.  Block transfer for block b+1 proceeds
while block b is being trained on — the device feed and the train step are
issued back-to-back and XLA overlaps the host transfer with compute
(dispatch is async), which is the TPU-native realisation of Fig. 2.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import BlockSchedule


@dataclass
class StreamingTrainState:
    params: object
    opt_state: object
    step: int = 0
    delivered: int = 0
    history: list = field(default_factory=list)


def run_streaming_training(
    *,
    train_step: Callable,          # (params, opt_state, step, batch) -> (p, o, metrics)
    params,
    opt_state,
    dataset: np.ndarray,           # (N, seq) token sequences on host
    plan: BlockSchedule,
    batch_size: int,
    make_batch: Optional[Callable] = None,  # tokens -> batch dict
    seed: int = 0,
    log_every: int = 10,
) -> StreamingTrainState:
    """Run the pipelined schedule for plan.total_updates steps."""
    rng = np.random.default_rng(seed)
    n = len(dataset)
    perm = rng.permutation(n)
    state = StreamingTrainState(params=params, opt_state=opt_state)
    avail_timeline = plan.updates_timeline()
    make_batch = make_batch or (lambda tok: {"tokens": jnp.asarray(tok)})

    step_j = jnp.zeros((), jnp.int32)
    for j, avail in enumerate(avail_timeline):
        if avail == 0:
            continue  # block 1 still in flight: nothing to train on yet
        state.delivered = int(avail)
        idx = perm[rng.integers(0, avail, size=batch_size)]
        batch = make_batch(dataset[idx])
        state.params, state.opt_state, metrics = train_step(
            state.params, state.opt_state, step_j, batch)
        step_j = step_j + 1
        state.step = j
        if (j % log_every) == 0:
            state.history.append(
                {"update": j, "available": int(avail),
                 "loss": float(metrics["loss"])})
    return state
