"""Optimality-gap bounds: Theorem 1 (eqs. 12-13) and Corollary 1 (eqs. 14-15).

The Corollary-1 evaluator is closed-form (geometric sums) and vectorised
over ``n_c`` grids — this is what the planner minimises, exactly as the
paper proposes (Sec. 4: "a generally looser bound that can be directly
evaluated numerically without running any Monte Carlo simulations").

This numpy implementation is the REFERENCE semantics; the batched fleet
planner (:mod:`repro.fleet`) carries a line-for-line ``jax.numpy`` port in
:mod:`repro.fleet.bounds_jax` that must stay in lockstep with
:func:`corollary1_bound` (the fleet property tests enforce agreement).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BoundConstants:
    """Assumption constants (A1)-(A4) + stepsize."""
    L: float        # smoothness (A2)
    c: float        # P-L constant (A3)
    M: float        # gradient-variance floor (A4)
    M_G: float      # M_V + 1 in the notation of [9]; paper uses M_G
    D: float        # iterate-set diameter (A1)
    alpha: float    # SGD stepsize, must satisfy 0 < alpha <= 2/(L*M_G)

    @property
    def gamma(self) -> float:
        """gamma = alpha (1 - alpha L M_G / 2)   (eq. 11)."""
        return self.alpha * (1.0 - 0.5 * self.alpha * self.L * self.M_G)

    @property
    def variance_floor(self) -> float:
        """alpha^2 L M / (2 gamma c) — the asymptotic bias of SGD."""
        return self.alpha ** 2 * self.L * self.M / (2.0 * self.gamma * self.c)

    @property
    def init_gap(self) -> float:
        """L D^2 / 2 — the Corollary-1 bound on any per-block initial error."""
        return self.L * self.D ** 2 / 2.0

    @property
    def contraction(self) -> float:
        """Per-update contraction factor r = clip(1 - gamma c, 0, 1).

        Shared by the numpy evaluator below and the ``jax.numpy`` port in
        :mod:`repro.fleet.bounds_jax` so both paths derive the bound from
        the same three scalars (contraction, variance_floor, init_gap).
        """
        return float(np.clip(1.0 - self.gamma * self.c, 0.0, 1.0))

    def validate(self):
        assert 0 < self.alpha <= 2.0 / (self.L * self.M_G), (
            f"stepsize violates (10): alpha={self.alpha}, "
            f"2/(L M_G)={2.0 / (self.L * self.M_G)}")
        assert self.gamma > 0


def _geom_sum(r: np.ndarray, k: np.ndarray) -> np.ndarray:
    """sum_{l=1}^{k} r^l, elementwise, k may be 0 (-> 0). Stable closed form."""
    r = np.asarray(r, np.float64)
    k = np.asarray(k, np.float64)
    out = np.where(np.abs(1.0 - r) < 1e-15, k, r * (1.0 - r ** k) / (1.0 - r))
    return np.where(k <= 0, 0.0, out)


def corollary1_bound(n_c, *, N: int, T: float, n_o, tau_p: float,
                     consts: BoundConstants) -> np.ndarray:
    """Eq. (14) / (15), vectorised over ``n_c`` AND ``n_o``.

    ``n_o`` may be a scalar or an array broadcastable against ``n_c`` —
    link models (e.g. ARQ retransmission) induce an effective overhead
    that varies with the block size, and the joint ``(n_c, rate)`` planner
    evaluates the whole 2-D grid in one broadcast call.

    Returns the upper bound on E[L(w_T) - L(w*)] for each grid point.
    """
    n_c = np.asarray(n_c, np.float64)
    n_o = np.asarray(n_o, np.float64)
    dur = n_c + n_o
    B_d = N / n_c
    B = np.floor(T / dur)                 # whole blocks that fit
    n_p = np.floor(dur / tau_p)           # SGD updates per block
    full = T > B_d * dur                  # regime (b)

    sigma = consts.variance_floor         # alpha^2 L M / (2 gamma c)
    e0 = consts.init_gap                  # L D^2 / 2
    r = consts.contraction
    rp = r ** n_p                         # per-block contraction

    # ---- regime (a): T <= B_d (n_c + n_o)   (eq. 14) -----------------------
    frac = np.clip((B - 1.0) / B_d, 0.0, 1.0)
    # sum_{l=1}^{B-1} rp^l  (closed form)
    s_a = _geom_sum(rp, np.maximum(B - 1.0, 0.0))
    bound_a = sigma * frac + (1.0 - frac) * e0 + (e0 - sigma) * s_a / B_d

    # ---- regime (b): T > B_d (n_c + n_o)    (eq. 15) -----------------------
    tau_l = np.maximum(T - B_d * dur, 0.0)
    n_l = np.floor(tau_l / tau_p)
    # sum_{l=0}^{B_d - 1} rp^l = 1 + sum_{l=1}^{B_d-1} rp^l
    s_b = 1.0 + _geom_sum(rp, np.maximum(np.ceil(B_d) - 1.0, 0.0))
    bound_b = sigma + (r ** n_l) * (e0 - sigma) * s_b / B_d

    return np.where(full, bound_b, bound_a)


def theorem1_bound(per_block_gap: np.ndarray, delta_gap_B: float, *,
                   N: int, T: float, n_c: int, n_o: float, tau_p: float,
                   consts: BoundConstants) -> float:
    """Eq. (12)/(13) given *empirical* per-block quantities.

    per_block_gap[b] = E_b[ L_b(w_b^{n_p}) - L_b(w*) ] for blocks b=1..B-1
    (or 1..B_d in regime (b)); delta_gap_B = E[ dL_B(w) - dL_B(w*) ]
    for the not-yet-received remainder (regime (a) only).
    """
    from repro.core.protocol import BlockSchedule

    plan = BlockSchedule(N=N, n_c=n_c, n_o=n_o, T=T, tau_p=tau_p)
    sigma = consts.variance_floor
    r = 1.0 - consts.gamma * consts.c
    n_p = plan.n_p
    B_d = plan.B_d

    if not plan.full_transfer:  # eq. (12)
        B = plan.B
        frac = (B - 1.0) / B_d
        tail = sum((r ** (l * n_p)) * (per_block_gap[B - 1 - l] - sigma)
                   for l in range(1, B))
        return sigma * frac + (1.0 - frac) * delta_gap_B + tail / B_d
    # eq. (13)
    n_l = plan.n_l
    Bd_i = int(np.ceil(B_d))
    tail = sum((r ** (l * n_p)) * (per_block_gap[Bd_i - 1 - l] - sigma)
               for l in range(0, Bd_i))
    return sigma + (r ** n_l) * tail / B_d


def calibrate_from_gram(X: np.ndarray, lam: float = 0.0):
    """(L, c) from the data Gramian — the paper (Sec. 4) sets L and c to the
    largest/smallest eigenvalues of the Gramian of the training features."""
    n = X.shape[0]
    gram = (X.T @ X) / n
    eigs = np.linalg.eigvalsh(gram)
    L = float(eigs[-1]) + lam / n
    c = float(eigs[0]) + lam / n
    return L, c
