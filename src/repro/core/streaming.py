"""On-device streaming buffer: the edge node's growing dataset prefix.

A fixed-size device tensor plus an ``available`` counter.  ``receive_block``
appends a block (dynamic_update_slice — in the distributed runtime this is
the host-feed/pod-axis transfer XLA overlaps with compute); ``sample`` draws
i.i.d. uniform indices from the available prefix, exactly the paper's
sampling model (Sec. 2: xi_b^j ~ Uniform(X_tilde_b)).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class StreamBuffer(NamedTuple):
    x: jnp.ndarray          # (N, ...) sample payloads (zeros beyond prefix)
    y: jnp.ndarray          # (N, ...) labels
    available: jnp.ndarray  # () int32 — prefix length visible to the sampler


def make_buffer(n: int, x_shape: Tuple[int, ...], y_shape: Tuple[int, ...] = (),
                dtype=jnp.float32) -> StreamBuffer:
    return StreamBuffer(
        x=jnp.zeros((n,) + tuple(x_shape), dtype),
        y=jnp.zeros((n,) + tuple(y_shape), dtype),
        available=jnp.zeros((), jnp.int32),
    )


def receive_block(buf: StreamBuffer, block_x, block_y) -> StreamBuffer:
    """Append a block at the current prefix end."""
    start = buf.available
    x = jax.lax.dynamic_update_slice(buf.x, block_x.astype(buf.x.dtype),
                                     (start,) + (0,) * (buf.x.ndim - 1))
    y = jax.lax.dynamic_update_slice(buf.y, block_y.astype(buf.y.dtype),
                                     (start,) + (0,) * (buf.y.ndim - 1))
    return StreamBuffer(x=x, y=y, available=start + block_x.shape[0])


def sample(buf: StreamBuffer, key, batch: int):
    """i.i.d. uniform draws from the available prefix (with replacement)."""
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.available, 1))
    return jnp.take(buf.x, idx, axis=0), jnp.take(buf.y, idx, axis=0)
