"""Beyond-paper extension 2 (paper Sec. 6: "investigate a scenario with
multiple devices").

D devices each hold a disjoint shard of the dataset and share the uplink by
round-robin TDMA: device d transmits block b_d in slot (b*D + d).  Each
block still carries overhead n_o, so the edge receives D interleaved block
streams; the learner's available set is the union of delivered blocks.

Key analytical observation (captured in ``equivalent_single_device``): under
round-robin TDMA the union prefix grows exactly like a SINGLE device with
block size D*n_c and overhead D*n_o — so the paper's Corollary-1 planner
applies to the multi-device system after this reduction, and per-device
block sizes come out as n_c_tilde / D.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.protocol import BlockSchedule


@dataclass(frozen=True)
class MultiDeviceSchedule:
    n_devices: int
    samples_per_device: int
    n_c: int          # per-device block size
    n_o: float
    T: float
    tau_p: float

    @property
    def N_total(self) -> int:
        return self.n_devices * self.samples_per_device

    def equivalent_single_device(self) -> BlockSchedule:
        """Round-robin TDMA union == one device with (D n_c, D n_o)."""
        return BlockSchedule(N=self.N_total, n_c=self.n_devices * self.n_c,
                             n_o=self.n_devices * self.n_o, T=self.T,
                             tau_p=self.tau_p)

    def available_at(self, t: float) -> int:
        """Union of samples delivered across devices at time t (exact
        slot-level accounting, for validating the reduction)."""
        slot = self.n_c + self.n_o
        slots_done = int(t // slot)
        per_dev_blocks = [slots_done // self.n_devices
                          + (1 if d < slots_done % self.n_devices else 0)
                          for d in range(self.n_devices)]
        return sum(min(b * self.n_c, self.samples_per_device)
                   for b in per_dev_blocks)


def plan_multi_device(*, n_devices: int, samples_per_device: int, T: float,
                      n_o: float, tau_p: float, consts: BoundConstants) -> dict:
    """Plan per-device block size via the single-device reduction.

    Compatibility wrapper over ``BoundPlanner`` on a ``MultiDevice``
    scenario (the TDMA reduction now lives in
    :class:`repro.core.scenario.Scenario`)."""
    from repro.core.scenario import BoundPlanner, MultiDevice, Scenario

    scenario = Scenario(N=n_devices * samples_per_device, T=T, n_o=n_o,
                        tau_p=tau_p, topology=MultiDevice(n_devices))
    plan = BoundPlanner().plan(scenario, consts)
    return {"n_c_union": plan.n_c, "n_c_per_device": plan.n_c_per_device,
            "bound": plan.bound_value,
            "schedule": MultiDeviceSchedule(
                n_devices=n_devices, samples_per_device=samples_per_device,
                n_c=plan.n_c_per_device, n_o=n_o, T=T, tau_p=tau_p)}
