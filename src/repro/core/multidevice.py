"""Beyond-paper extension 2 (paper Sec. 6: "investigate a scenario with
multiple devices").

D devices each hold a disjoint shard of the dataset and share the uplink by
round-robin TDMA: device d transmits block b_d in slot (b*D + d).  Each
block still carries overhead n_o, so the edge receives D interleaved block
streams; the learner's available set is the union of delivered blocks.

Key analytical observation (captured in ``equivalent_single_device``): under
round-robin TDMA the union prefix grows exactly like a SINGLE device with
block size D*n_c and overhead D*n_o — so the paper's Corollary-1 planner
applies to the multi-device system after this reduction, and per-device
block sizes come out as n_c_tilde / D.

Shards need not be equal: ``split_samples`` hands out a remainder-exact
split (first ``N % D`` devices carry one extra sample), and
:class:`MultiDeviceSchedule` accepts explicit per-device ``shard_sizes``
— the union accounting caps each device at ITS shard, so uneven fleets
(including the federated round simulator's data split) are modelled
exactly instead of silently rounded to an even split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core.bounds import BoundConstants
from repro.core.protocol import BlockSchedule


def split_samples(N: int, n_devices: int) -> Tuple[int, ...]:
    """Remainder-exact split of ``N`` samples over ``n_devices`` disjoint
    shards: sizes differ by at most one, sum exactly to ``N``, and the
    first ``N % n_devices`` devices take the extra sample."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if N < n_devices:
        raise ValueError(
            f"cannot split N={N} samples over {n_devices} devices "
            "(every shard needs at least one sample)")
    base, extra = divmod(int(N), int(n_devices))
    return tuple(base + (1 if d < extra else 0)
                 for d in range(int(n_devices)))


@dataclass(frozen=True)
class MultiDeviceSchedule:
    n_devices: int
    samples_per_device: int   # the LARGEST shard (uniform when even split)
    n_c: int                  # per-device block size
    n_o: float
    T: float
    tau_p: float
    #: per-device shard sizes; ``None`` normalises to the uniform split
    #: ``(samples_per_device,) * n_devices``
    shard_sizes: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(
                f"n_devices must be >= 1, got {self.n_devices}")
        if self.samples_per_device < 1:
            raise ValueError(f"samples_per_device must be >= 1, got "
                             f"{self.samples_per_device}")
        if self.n_c < 1:
            raise ValueError(f"n_c must be >= 1, got {self.n_c}")
        if self.n_o < 0:
            raise ValueError(f"n_o must be >= 0, got {self.n_o}")
        if self.T <= 0:
            raise ValueError(f"T must be > 0, got {self.T}")
        if self.tau_p <= 0:
            raise ValueError(f"tau_p must be > 0, got {self.tau_p}")
        if self.shard_sizes is None:
            object.__setattr__(
                self, "shard_sizes",
                (self.samples_per_device,) * self.n_devices)
        else:
            object.__setattr__(self, "shard_sizes",
                               tuple(int(s) for s in self.shard_sizes))
        if len(self.shard_sizes) != self.n_devices:
            raise ValueError(
                f"{len(self.shard_sizes)} shard sizes for "
                f"{self.n_devices} devices")
        if any(s < 1 for s in self.shard_sizes):
            raise ValueError(f"every shard needs at least one sample, "
                             f"got {self.shard_sizes}")
        if max(self.shard_sizes) != self.samples_per_device:
            raise ValueError(
                f"samples_per_device={self.samples_per_device} must be "
                f"the largest shard, got shards {self.shard_sizes}")

    @property
    def N_total(self) -> int:
        return sum(self.shard_sizes)

    def equivalent_single_device(self) -> BlockSchedule:
        """Round-robin TDMA union == one device with (D n_c, D n_o)."""
        return BlockSchedule(N=self.N_total, n_c=self.n_devices * self.n_c,
                             n_o=self.n_devices * self.n_o, T=self.T,
                             tau_p=self.tau_p)

    def available_at(self, t: float) -> int:
        """Union of samples delivered across devices at time t (exact
        slot-level accounting, for validating the reduction).  Each
        device is capped at its OWN shard size — with uneven shards the
        union saturates at ``N_total``, not at ``D * max_shard``."""
        slot = self.n_c + self.n_o
        slots_done = int(t // slot)
        per_dev_blocks = [slots_done // self.n_devices
                          + (1 if d < slots_done % self.n_devices else 0)
                          for d in range(self.n_devices)]
        return sum(min(b * self.n_c, s)
                   for b, s in zip(per_dev_blocks, self.shard_sizes))


def plan_multi_device(*, n_devices: int, samples_per_device: int = None,
                      N: int = None, T: float, n_o: float, tau_p: float,
                      consts: BoundConstants) -> dict:
    """Plan per-device block size via the single-device reduction.

    Compatibility wrapper over ``BoundPlanner`` on a ``MultiDevice``
    scenario (the TDMA reduction now lives in
    :class:`repro.core.scenario.Scenario`).  Give either
    ``samples_per_device`` (the historical uniform-split form) or a total
    ``N``: the latter plans the EXACT total and splits it
    remainder-exactly over the devices (``split_samples``) instead of
    silently rounding the population to an even multiple of the device
    count."""
    from repro.core.scenario import BoundPlanner, MultiDevice, Scenario

    if (samples_per_device is None) == (N is None):
        raise ValueError(
            "give exactly one of samples_per_device= or N=")
    if N is None:
        shards = (int(samples_per_device),) * int(n_devices)
        N = n_devices * samples_per_device
    else:
        shards = split_samples(int(N), int(n_devices))

    scenario = Scenario(N=int(N), T=T, n_o=n_o, tau_p=tau_p,
                        topology=MultiDevice(n_devices))
    plan = BoundPlanner().plan(scenario, consts)
    return {"n_c_union": plan.n_c, "n_c_per_device": plan.n_c_per_device,
            "bound": plan.bound_value, "shard_sizes": shards,
            "schedule": MultiDeviceSchedule(
                n_devices=n_devices, samples_per_device=max(shards),
                n_c=plan.n_c_per_device, n_o=n_o, T=T, tau_p=tau_p,
                shard_sizes=shards)}
