"""Pure-jax ``p_err`` kernels, one per registered link model.

The jax side of the pluggable link registry (:mod:`repro.core.links`): each
kernel is a pure function ``p_err(params, rate) -> p`` where ``params`` is
the model's row of the padded ``(S, MAX_LINK_PARAMS)`` parameter table and
``rate`` the scenario's candidate-rate row — both jnp arrays, broadcast
semantics, NO Python branching on values.  The fleet solve kernel vmaps a
``jax.lax.switch`` over :func:`kernel_table` so one jitted ``plan_batch``
call plans a batch mixing every channel family.

Every kernel must mirror its model's numpy ``p_err`` bitwise (same op
order, same :data:`~repro.core.links.P_ERR_MAX` clamp) — the batched ==
scalar equivalence tests enforce it to argmin exactness.

Registering a custom channel's kernel::

    from repro.fleet.link_kernels import register_link_kernel

    def _my_p_err(params, rate):          # params: (MAX_LINK_PARAMS,)
        return jnp.minimum(params[..., 0] * rate, P_ERR_MAX)

    register_link_kernel(MyLink.model_id, _my_p_err)

Registration bumps :func:`kernel_table_version`; the fleet planner keys its
jitted dispatch on that version, so plugins registered after import still
get compiled in (at the cost of one retrace).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.core.links import (P_ERR_MAX, ErasureLink, FadingLink,
                              GilbertElliottLink, IdealLink, link_spec,
                              registered_link_models)

_KERNELS: Dict[int, Callable] = {}
_VERSION = 0


def register_link_kernel(model_id: int, p_err_fn: Callable) -> None:
    """Register the jax ``p_err(params, rate)`` kernel for ``model_id``.

    The model must already be registered with
    :func:`repro.core.links.register_link_model`.
    """
    global _VERSION
    link_spec(model_id)  # raises KeyError with guidance if no spec exists
    prior = _KERNELS.get(model_id)
    if prior is p_err_fn:
        return  # idempotent re-registration: no version bump, no retrace
    if prior is not None:
        raise ValueError(
            f"model_id {model_id} already has a registered kernel")
    _KERNELS[model_id] = p_err_fn
    _VERSION += 1


def unregister_link_kernel(model_id: int) -> None:
    """Remove a kernel (plugin teardown / tests).  No-op if absent."""
    global _VERSION
    if _KERNELS.pop(model_id, None) is not None:
        _VERSION += 1


def kernel_table() -> tuple:
    """Branch table for ``jax.lax.switch``, indexed by ``model_id``.

    Requires a DENSE id space: every id in ``0..max`` must carry both a
    spec and a kernel, because ``lax.switch(i, branches)`` selects
    ``branches[i]`` positionally.
    """
    specs = registered_link_models()
    missing = [s.model_id for s in specs if s.model_id not in _KERNELS]
    if missing:
        raise ValueError(
            f"link models {missing} have no registered jax kernel; call "
            "repro.fleet.link_kernels.register_link_kernel for each")
    top = max(_KERNELS)
    holes = [i for i in range(top + 1) if i not in _KERNELS]
    if holes:
        raise ValueError(
            f"model_id space has holes {holes}: lax.switch dispatch needs "
            f"dense ids 0..{top}")
    return tuple(_KERNELS[i] for i in range(top + 1))


def kernel_table_version() -> int:
    """Monotone counter bumped on (un)registration — cache key for any
    jitted function closing over :func:`kernel_table`."""
    return _VERSION


# ---------------------------------------------------------------------------
# built-in kernels — each mirrors the numpy semantics in repro.core.links
# ---------------------------------------------------------------------------


def _ideal_p_err(params, rate):
    return jnp.zeros_like(rate)


def _erasure_p_err(params, rate):
    beta, p_base = params[..., 0], params[..., 1]
    p = 1.0 - (1.0 - p_base) * jnp.exp(-beta * jnp.maximum(rate - 1.0, 0.0))
    return jnp.minimum(p, P_ERR_MAX)


def _fading_p_err(params, rate):
    snr = params[..., 0]
    p = 1.0 - jnp.exp(-(jnp.exp2(rate) - 1.0) / snr)
    return jnp.minimum(p, P_ERR_MAX)


def _gilbert_elliott_p_err(params, rate):
    beta, p_good, p_bad, p_gb, p_bg = (params[..., k] for k in range(5))
    decay = jnp.exp(-beta * jnp.maximum(rate - 1.0, 0.0))
    p_g = 1.0 - (1.0 - p_good) * decay
    p_b = 1.0 - (1.0 - p_bad) * decay
    pi_bad = p_gb / (p_gb + p_bg)
    # difference form: bitwise-equal to ErasureLink when p_b == p_g
    return jnp.minimum(p_g + pi_bad * (p_b - p_g), P_ERR_MAX)


register_link_kernel(IdealLink.model_id, _ideal_p_err)
register_link_kernel(ErasureLink.model_id, _erasure_p_err)
register_link_kernel(FadingLink.model_id, _fading_p_err)
register_link_kernel(GilbertElliottLink.model_id, _gilbert_elliott_p_err)
