"""Batched planning over a :class:`ScenarioBatch`, for any registered objective.

One jitted call evaluates the joint ``(rate, n_c)`` objective for EVERY
scenario in the batch — shape ``(S, R, G)`` — and reduces it with the same
rate-major argmin tie-breaking as the scalar
:class:`~repro.core.scenario.ObjectivePlanner`, so the batched and scalar
paths pick identical plans (enforced by the fleet property tests).

Both pluggable registries meet here: the channel physics comes from the
link registry (a vmapped ``jax.lax.switch`` over the
:mod:`~repro.fleet.link_kernels` branch table turns each scenario's
``(link_model_id, link_params)`` row into its loss probability) and the
quantity being minimised comes from the OBJECTIVE registry
(:mod:`repro.core.objectives` + :mod:`~repro.fleet.objective_kernels`):
the closed-form Corollary-1 bound, the exact burst-aware Markov-ARQ
variant, the empirical Monte-Carlo ridge objective, or any plugin.  A
single compilation per objective plans a fleet mixing every registered
channel family; jitted solves are cached per kernel-table version, so
registering a new model after import just triggers one retrace.

The whole computation runs under ``jax.experimental.enable_x64()`` to match
the numpy reference bit-for-bit where the backend's libm allows, and the
grid objectives are sharded across local devices via
``jax.sharding.NamedSharding`` over the scenario axis whenever more than
one device is visible and ``S`` divides evenly.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Union

import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.objectives import BoundObjective
from repro.core.planner import Plan, fleet_grid
from repro.core.protocol import BlockSchedule
from repro.core.scenario import Scenario

from repro.fleet.batch import ScenarioBatch
from repro.fleet.cache import PlanCache
from repro.fleet.objective_kernels import fleet_solve, pow2ceil


@dataclass(frozen=True)
class PlanRecord:
    """Lightweight per-scenario plan — what the cache stores and the
    server streams back.  ``FleetPlan.record(i)`` extracts one."""

    n_c: int
    rate: float
    bound_value: float
    p_err: float
    n_o_eff: float
    full_transfer: bool
    boundary: float
    n_c_per_device: int
    objective: str = "corollary1"


@dataclass(frozen=True)
class FleetPlan:
    """Struct-of-arrays planner output; all arrays share leading dim S."""

    n_c: np.ndarray             # (S,) int64   chosen union block size
    rate: np.ndarray            # (S,) float64 chosen transmission rate
    bound_value: np.ndarray     # (S,) float64 objective at the optimum
    p_err: np.ndarray           # (S,) float64 loss probability at that rate
    n_o_eff: np.ndarray         # (S,) float64 effective overhead at optimum
    full_transfer: np.ndarray   # (S,) bool    regime flag (delivered >= N)
    boundary: np.ndarray        # (S,) float64 regime-boundary block size
    n_c_per_device: np.ndarray  # (S,) int64   per-device block size
    grid: np.ndarray            # (S, G) evaluated n_c grid
    bound_grid: np.ndarray      # (S, G) objective at the chosen rate
    objective: str = "corollary1"

    def __len__(self) -> int:
        return int(self.n_c.shape[0])

    def record(self, i: int) -> PlanRecord:
        return PlanRecord(
            n_c=int(self.n_c[i]), rate=float(self.rate[i]),
            bound_value=float(self.bound_value[i]),
            p_err=float(self.p_err[i]), n_o_eff=float(self.n_o_eff[i]),
            full_transfer=bool(self.full_transfer[i]),
            boundary=float(self.boundary[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)

    def to_plan(self, batch: ScenarioBatch, i: int) -> Plan:
        """Materialise the i-th result as a full PR-1 :class:`Plan`."""
        sched = BlockSchedule(N=int(batch.N[i]), n_c=int(self.n_c[i]),
                              n_o=float(self.n_o_eff[i]),
                              T=float(batch.T[i]),
                              tau_p=float(batch.tau_p[i]))
        return Plan(
            n_c=int(self.n_c[i]), bound_value=float(self.bound_value[i]),
            full_transfer=sched.full_transfer,
            boundary=float(self.boundary[i]),
            grid=np.asarray(self.grid[i]),
            bound_grid=np.asarray(self.bound_grid[i]),
            schedule=sched, rate=float(self.rate[i]),
            p_err=float(self.p_err[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)


def _pad_batch(scenarios: List[Scenario],
               pad_to: Optional[int] = None) -> List[Scenario]:
    """Pad (repeating the last scenario) to a fixed length ``pad_to``, or
    to the next power of two — shape invariance bounds how many kernel
    shapes a request stream can ever compile (one per pad length)."""
    n = len(scenarios)
    if pad_to is None:
        pad_to = pow2ceil(n)
    elif pad_to < n:
        raise ValueError(f"pad_to={pad_to} < batch of {n}")
    return scenarios + [scenarios[-1]] * (pad_to - n)


@dataclass(frozen=True)
class FleetPlanner:
    """Batched planner: thousands of scenarios per call, any objective.

    ``grid_size`` is the per-scenario grid width G (every scenario gets its
    own log-spaced 1..N grid of that width via
    :func:`repro.core.planner.fleet_grid`); ``shard`` toggles the
    NamedSharding layout across local devices; ``objective`` is the
    default registered objective instance solved by ``plan_batch`` /
    ``plan_many`` (``None`` means the Corollary-1
    :class:`~repro.core.objectives.BoundObjective`), overridable per call.
    """

    grid_size: int = 128
    shard: bool = True
    objective: Any = None

    def _resolve_objective(self, override):
        obj = override if override is not None else self.objective
        return obj if obj is not None else BoundObjective()

    def plan_batch(self,
                   batch: Union[ScenarioBatch, Sequence[Scenario]],
                   consts: BoundConstants,
                   grid: Optional[np.ndarray] = None,
                   objective: Any = None) -> FleetPlan:
        """Solve every scenario in the batch in one jitted call.

        ``grid`` may be ``None`` (per-scenario default grids), a shared
        ``(G,)`` vector, or a per-scenario ``(S, G)`` matrix;
        ``objective`` overrides the planner's default objective.  With
        ``grid=None``, an objective declaring ``default_grid_size`` (the
        Monte-Carlo objective: simulating training per grid point is
        expensive) caps the default grid width below ``grid_size``.
        """
        consts.validate()
        objective = self._resolve_objective(objective)
        if not isinstance(batch, ScenarioBatch):
            batch = ScenarioBatch.from_scenarios(list(batch))
        S = len(batch)
        if grid is None:
            size = self.grid_size
            own = getattr(objective, "default_grid_size", None)
            if own is not None:
                size = min(size, int(own))
            grid = fleet_grid(batch.N, size)
        else:
            grid = np.asarray(grid, np.int64)
            if grid.ndim == 1:
                grid = np.broadcast_to(grid, (S, grid.shape[0]))
            if grid.shape[0] != S:
                raise ValueError(
                    f"grid has leading dim {grid.shape[0]}, want {S}")

        arrays = {  # np.asarray: no copy when the dtype already matches
            "N": np.asarray(batch.N, np.int64),
            "T": np.asarray(batch.T, np.float64),
            "union_no": batch.union_overhead,
            "tau_p": np.asarray(batch.tau_p, np.float64),
            "rates": np.asarray(batch.rates, np.float64),
            "rate_mask": batch.rate_mask,
            "grid": np.ascontiguousarray(grid),
            "link_model_id": np.asarray(batch.link_model_id, np.int32),
            "link_params": np.asarray(batch.link_params, np.float64),
        }
        solve = fleet_solve(objective)
        out = solve(arrays, consts, self.shard, batch)

        D = batch.n_devices
        with np.errstate(divide="ignore"):  # T == N -> inf boundary
            boundary = np.where(
                batch.T <= batch.N, np.inf,
                np.maximum(batch.N * out["n_o_eff"], 0.0)
                / np.where(batch.T > batch.N, batch.T - batch.N, 1.0))
        return FleetPlan(
            n_c=out["n_c"], rate=out["rate"],
            bound_value=out["bound_value"], p_err=out["p_err"],
            n_o_eff=out["n_o_eff"], full_transfer=out["full_transfer"],
            boundary=boundary,
            n_c_per_device=np.maximum(1, out["n_c"] // D),
            grid=np.asarray(grid), bound_grid=out["bound_grid"],
            objective=objective.objective_id)

    def plan_many(self, scenarios: Sequence[Scenario],
                  consts: BoundConstants,
                  cache: Optional[PlanCache] = None,
                  pad_to: Optional[int] = None,
                  objective: Any = None) -> List[PlanRecord]:
        """Plan a request list, deduplicating through the cache.

        Cache hits (and in-batch duplicates, up to key quantisation) skip
        the solve; the remaining unique misses are padded — to ``pad_to``
        when given (a serving loop passes its micro-batch size so ONE
        kernel shape covers every batch), else to the next power of two —
        and solved in ONE ``plan_batch`` call.  Results come back in
        request order.  Cache entries are scoped to ``(consts,
        grid_size)`` AND the objective's ``cache_token()`` so one cache
        can serve several configurations and objectives without
        cross-talk.
        """
        scenarios = list(scenarios)
        if not scenarios:
            return []
        objective = self._resolve_objective(objective)
        records: List[Optional[PlanRecord]] = [None] * len(scenarios)
        if cache is None:
            fp = self.plan_batch(_pad_batch(scenarios, pad_to), consts,
                                 objective=objective)
            return [fp.record(i) for i in range(len(scenarios))]

        ctx = (consts, self.grid_size)
        miss: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, sc in enumerate(scenarios):
            rec = cache.get(sc, context=ctx, objective=objective)
            if rec is not None:
                records[i] = rec
            else:
                miss.setdefault(
                    cache.key(sc, context=ctx, objective=objective),
                    []).append(i)
        if miss:
            reps = [scenarios[idxs[0]] for idxs in miss.values()]
            fp = self.plan_batch(_pad_batch(reps, pad_to), consts,
                                 objective=objective)
            for j, idxs in enumerate(miss.values()):
                rec = fp.record(j)
                cache.put(scenarios[idxs[0]], rec, context=ctx,
                          objective=objective)
                for i in idxs:
                    records[i] = rec
        return records  # type: ignore[return-value]
