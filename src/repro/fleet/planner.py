"""Batched Corollary-1 planning over a :class:`ScenarioBatch`.

One jitted call evaluates the joint ``(rate, n_c)`` objective for EVERY
scenario in the batch — shape ``(S, R, G)`` — and reduces it with the same
rate-major argmin tie-breaking as the scalar
:class:`~repro.core.scenario.BoundPlanner`, so the batched and scalar paths
pick identical plans (enforced by the fleet property tests).

The channel physics comes from the pluggable link registry: a vmapped
``jax.lax.switch`` over the :mod:`~repro.fleet.link_kernels` branch table
turns each scenario's ``(link_model_id, link_params)`` row into its loss
probability, so a single compilation plans a fleet mixing every registered
channel family (ideal / erasure / fading / Gilbert-Elliott / plugins).
The jitted solve is cached per kernel-table version — registering a new
model after import just triggers one retrace.

The whole computation runs under ``jax.experimental.enable_x64()`` to match
the numpy reference bit-for-bit where the backend's libm allows, and is
sharded across local devices via ``jax.sharding.NamedSharding`` over the
scenario axis whenever more than one device is visible and ``S`` divides
evenly.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bounds import BoundConstants
from repro.core.planner import Plan, fleet_grid
from repro.core.protocol import BlockSchedule
from repro.core.scenario import Scenario

from repro.fleet.batch import ScenarioBatch
from repro.fleet.bounds_jax import corollary1_bound_jax
from repro.fleet.cache import PlanCache
from repro.fleet.link_kernels import kernel_table, kernel_table_version


@dataclass(frozen=True)
class PlanRecord:
    """Lightweight per-scenario plan — what the cache stores and the
    server streams back.  ``FleetPlan.record(i)`` extracts one."""

    n_c: int
    rate: float
    bound_value: float
    p_err: float
    n_o_eff: float
    full_transfer: bool
    boundary: float
    n_c_per_device: int
    objective: str = "corollary1"


@dataclass(frozen=True)
class FleetPlan:
    """Struct-of-arrays planner output; all arrays share leading dim S."""

    n_c: np.ndarray             # (S,) int64   chosen union block size
    rate: np.ndarray            # (S,) float64 chosen transmission rate
    bound_value: np.ndarray     # (S,) float64 objective at the optimum
    p_err: np.ndarray           # (S,) float64 loss probability at that rate
    n_o_eff: np.ndarray         # (S,) float64 effective overhead at optimum
    full_transfer: np.ndarray   # (S,) bool    regime flag (delivered >= N)
    boundary: np.ndarray        # (S,) float64 regime-boundary block size
    n_c_per_device: np.ndarray  # (S,) int64   per-device block size
    grid: np.ndarray            # (S, G) evaluated n_c grid
    bound_grid: np.ndarray      # (S, G) objective at the chosen rate
    objective: str = "corollary1"

    def __len__(self) -> int:
        return int(self.n_c.shape[0])

    def record(self, i: int) -> PlanRecord:
        return PlanRecord(
            n_c=int(self.n_c[i]), rate=float(self.rate[i]),
            bound_value=float(self.bound_value[i]),
            p_err=float(self.p_err[i]), n_o_eff=float(self.n_o_eff[i]),
            full_transfer=bool(self.full_transfer[i]),
            boundary=float(self.boundary[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)

    def to_plan(self, batch: ScenarioBatch, i: int) -> Plan:
        """Materialise the i-th result as a full PR-1 :class:`Plan`."""
        sched = BlockSchedule(N=int(batch.N[i]), n_c=int(self.n_c[i]),
                              n_o=float(self.n_o_eff[i]),
                              T=float(batch.T[i]),
                              tau_p=float(batch.tau_p[i]))
        return Plan(
            n_c=int(self.n_c[i]), bound_value=float(self.bound_value[i]),
            full_transfer=sched.full_transfer,
            boundary=float(self.boundary[i]),
            grid=np.asarray(self.grid[i]),
            bound_grid=np.asarray(self.bound_grid[i]),
            schedule=sched, rate=float(self.rate[i]),
            p_err=float(self.p_err[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)


def _build_solve_kernel(branches):
    """Jit the fleet solve closed over a link-kernel branch table.

    Shapes: per-scenario vectors (S,), rate matrix (S, R), grid (S, G);
    output per-scenario reductions.  Equivalent to vmapping the scalar
    planner over scenarios with the grid axes broadcast — written directly
    in batch form so the argmin layout (rate-major, then grid) matches
    ``repro.core.scenario._finish_plan`` exactly.
    """

    @jax.jit
    def _solve_kernel(N, T, union_no, tau_p, rates, rate_mask, grid,
                      link_model_id, link_params, sigma, e0, contraction):
        S = rates.shape[0]
        rate = rates[:, :, None]                                   # (S, R, 1)
        g = grid[:, None, :].astype(T.dtype)                       # (S, 1, G)

        # per-scenario link dispatch: lax.switch over the registered p_err
        # kernels, vmapped over the batch (under vmap every branch runs and
        # the result is selected — fine: p_err is O(R), the bound is O(R G))
        def p_err_one(mid, params, rate_row):
            return jax.lax.switch(mid, branches, params, rate_row)

        p = jax.vmap(p_err_one)(link_model_id, link_params, rates)  # (S, R)

        # expected_block_time under stop-and-wait ARQ, batched
        p3 = p[:, :, None]
        dur = (g / rate + union_no[:, None, None]) / (1.0 - p3)    # (S, R, G)
        n_o_eff = dur - g

        vals = corollary1_bound_jax(
            g, N=N[:, None, None].astype(T.dtype), T=T[:, None, None],
            n_o=n_o_eff, tau_p=tau_p[:, None, None],
            sigma=sigma, e0=e0, contraction=contraction)           # (S, R, G)

        # Two-stage argmin == flat rate-major argmin (ties: first grid point
        # within a rate, then first rate), matching _finish_plan exactly.
        masked = jnp.where(rate_mask[:, :, None], vals, jnp.inf)
        gi_per_rate = jnp.argmin(masked, axis=2)                   # (S, R)
        ri = jnp.argmin(jnp.min(masked, axis=2), axis=1)           # (S,)
        s = jnp.arange(S)
        gi = gi_per_rate[s, ri]

        n_c = grid[s, gi]
        best_no = n_o_eff[s, ri, gi]
        best_dur = n_c.astype(T.dtype) + best_no
        delivered = jnp.minimum(jnp.floor(T / best_dur) * n_c, N)
        return {
            "n_c": n_c,
            "rate": rates[s, ri],
            "bound_value": vals[s, ri, gi],
            "p_err": p[s, ri],
            "n_o_eff": best_no,
            "full_transfer": delivered >= N,
            "bound_grid": vals[s, ri],
        }

    return _solve_kernel


@lru_cache(maxsize=4)
def _solve_kernel_for(version: int):
    """Jitted solve for the CURRENT link-kernel table; keyed on the
    registry version so later plugin registrations get their own trace.
    Bounded: stale versions' compiled programs are evicted rather than
    retained for the life of a long-running server."""
    del version  # cache key only
    return _build_solve_kernel(kernel_table())


def _maybe_shard(arrays: dict, S: int) -> dict:
    """Lay the batch out across local devices over the scenario axis."""
    devices = jax.local_devices()
    if len(devices) <= 1 or S % len(devices) != 0:
        return arrays
    mesh = Mesh(np.asarray(devices), ("fleet",))
    sharding = NamedSharding(mesh, P("fleet"))
    return {k: jax.device_put(v, sharding) for k, v in arrays.items()}


def _pad_batch(scenarios: List[Scenario],
               pad_to: Optional[int] = None) -> List[Scenario]:
    """Pad (repeating the last scenario) to a fixed length ``pad_to``, or
    to the next power of two — shape invariance bounds how many kernel
    shapes a request stream can ever compile (one per pad length)."""
    n = len(scenarios)
    if pad_to is None:
        pad_to = 1
        while pad_to < n:
            pad_to *= 2
    elif pad_to < n:
        raise ValueError(f"pad_to={pad_to} < batch of {n}")
    return scenarios + [scenarios[-1]] * (pad_to - n)


@dataclass(frozen=True)
class FleetPlanner:
    """Batched Corollary-1 planner: thousands of scenarios per call.

    ``grid_size`` is the per-scenario grid width G (every scenario gets its
    own log-spaced 1..N grid of that width via
    :func:`repro.core.planner.fleet_grid`); ``shard`` toggles the
    NamedSharding layout across local devices.
    """

    grid_size: int = 128
    shard: bool = True

    def plan_batch(self,
                   batch: Union[ScenarioBatch, Sequence[Scenario]],
                   consts: BoundConstants,
                   grid: Optional[np.ndarray] = None) -> FleetPlan:
        """Solve every scenario in the batch in one jitted call.

        ``grid`` may be ``None`` (per-scenario default grids), a shared
        ``(G,)`` vector, or a per-scenario ``(S, G)`` matrix.
        """
        consts.validate()
        if not isinstance(batch, ScenarioBatch):
            batch = ScenarioBatch.from_scenarios(list(batch))
        S = len(batch)
        if grid is None:
            grid = fleet_grid(batch.N, self.grid_size)
        else:
            grid = np.asarray(grid, np.int64)
            if grid.ndim == 1:
                grid = np.broadcast_to(grid, (S, grid.shape[0]))
            if grid.shape[0] != S:
                raise ValueError(
                    f"grid has leading dim {grid.shape[0]}, want {S}")

        arrays = {  # np.asarray: no copy when the dtype already matches
            "N": np.asarray(batch.N, np.int64),
            "T": np.asarray(batch.T, np.float64),
            "union_no": batch.union_overhead,
            "tau_p": np.asarray(batch.tau_p, np.float64),
            "rates": np.asarray(batch.rates, np.float64),
            "rate_mask": batch.rate_mask,
            "grid": np.ascontiguousarray(grid),
            "link_model_id": np.asarray(batch.link_model_id, np.int32),
            "link_params": np.asarray(batch.link_params, np.float64),
        }
        solve = _solve_kernel_for(kernel_table_version())
        with enable_x64():
            if self.shard:
                arrays = _maybe_shard(arrays, S)
            out = solve(
                sigma=consts.variance_floor, e0=consts.init_gap,
                contraction=consts.contraction, **arrays)
            out = {k: np.asarray(v) for k, v in out.items()}

        D = batch.n_devices
        with np.errstate(divide="ignore"):  # T == N -> inf boundary
            boundary = np.where(
                batch.T <= batch.N, np.inf,
                np.maximum(batch.N * out["n_o_eff"], 0.0)
                / np.where(batch.T > batch.N, batch.T - batch.N, 1.0))
        return FleetPlan(
            n_c=out["n_c"], rate=out["rate"],
            bound_value=out["bound_value"], p_err=out["p_err"],
            n_o_eff=out["n_o_eff"], full_transfer=out["full_transfer"],
            boundary=boundary,
            n_c_per_device=np.maximum(1, out["n_c"] // D),
            grid=np.asarray(grid), bound_grid=out["bound_grid"])

    def plan_many(self, scenarios: Sequence[Scenario],
                  consts: BoundConstants,
                  cache: Optional[PlanCache] = None,
                  pad_to: Optional[int] = None) -> List[PlanRecord]:
        """Plan a request list, deduplicating through the cache.

        Cache hits (and in-batch duplicates, up to key quantisation) skip
        the solve; the remaining unique misses are padded — to ``pad_to``
        when given (a serving loop passes its micro-batch size so ONE
        kernel shape covers every batch), else to the next power of two —
        and solved in ONE ``plan_batch`` call.  Results come back in
        request order.  Cache entries are scoped to ``(consts,
        grid_size)`` so one cache can serve several configurations
        without cross-talk.
        """
        scenarios = list(scenarios)
        if not scenarios:
            return []
        records: List[Optional[PlanRecord]] = [None] * len(scenarios)
        if cache is None:
            fp = self.plan_batch(_pad_batch(scenarios, pad_to), consts)
            return [fp.record(i) for i in range(len(scenarios))]

        ctx = (consts, self.grid_size)
        miss: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, sc in enumerate(scenarios):
            rec = cache.get(sc, context=ctx)
            if rec is not None:
                records[i] = rec
            else:
                miss.setdefault(cache.key(sc, context=ctx), []).append(i)
        if miss:
            reps = [scenarios[idxs[0]] for idxs in miss.values()]
            fp = self.plan_batch(_pad_batch(reps, pad_to), consts)
            for j, idxs in enumerate(miss.values()):
                rec = fp.record(j)
                cache.put(scenarios[idxs[0]], rec, context=ctx)
                for i in idxs:
                    records[i] = rec
        return records  # type: ignore[return-value]
