"""Batched planning over a :class:`ScenarioBatch`, for any registered objective.

One jitted call evaluates the joint ``(rate, n_c)`` objective for EVERY
scenario in the batch — shape ``(S, R, G)`` — and reduces it with the same
rate-major argmin tie-breaking as the scalar
:class:`~repro.core.scenario.ObjectivePlanner`, so the batched and scalar
paths pick identical plans (enforced by the fleet property tests).

Both pluggable registries meet here: the channel physics comes from the
link registry (a vmapped ``jax.lax.switch`` over the
:mod:`~repro.fleet.link_kernels` branch table turns each scenario's
``(link_model_id, link_params)`` row into its loss probability) and the
quantity being minimised comes from the OBJECTIVE registry
(:mod:`repro.core.objectives` + :mod:`~repro.fleet.objective_kernels`):
the closed-form Corollary-1 bound, the exact burst-aware Markov-ARQ
variant, the empirical Monte-Carlo ridge objective, or any plugin.  A
single compilation per objective plans a fleet mixing every registered
channel family; jitted solves are cached per kernel-table version, so
registering a new model after import just triggers one retrace.

The whole computation runs under ``jax.experimental.enable_x64()`` to match
the numpy reference bit-for-bit where the backend's libm allows, and the
grid objectives are sharded across local devices via
``jax.sharding.NamedSharding`` over the scenario axis whenever more than
one device is visible and ``S`` divides evenly.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.bounds import BoundConstants
from repro.core.objectives import BoundObjective, refine_hints_for
from repro.core.planner import (Plan, coarse_indices, fleet_grid,
                                refine_grid, refine_window_bounds)
from repro.core.protocol import BlockSchedule
from repro.core.scenario import Scenario

from repro.fleet.batch import ScenarioBatch
from repro.fleet.cache import PlanCache
from repro.fleet.objective_kernels import fleet_solve, pow2ceil
from repro.fleet.tracing import trace_delta

#: Valid ``FleetPlanner.grid_mode`` values: ``"dense"`` (single-pass, the
#: reference semantics and the documented escape hatch) and ``"refine"``
#: (two-pass coarse -> fine; see ``FleetPlanner``).
GRID_MODES = ("dense", "refine")

#: Valid ``FleetPlanner.mc_impl`` values: ``"auto"`` resolves by backend
#: (the pallas slab kernel on TPU, the ``lax.scan`` engines elsewhere);
#: ``"scan"`` / ``"pallas"`` pin the Monte-Carlo simulation engine.
MC_IMPLS = ("auto", "scan", "pallas")


@dataclass(frozen=True)
class PlanRecord:
    """Lightweight per-scenario plan — what the cache stores and the
    server streams back.  ``FleetPlan.record(i)`` extracts one."""

    n_c: int
    rate: float
    bound_value: float
    p_err: float
    n_o_eff: float
    full_transfer: bool
    boundary: float
    n_c_per_device: int
    objective: str = "corollary1"
    #: degradation-ladder level that produced this record ("full" =
    #: the real solve; see repro.serve.resilience.FALLBACK_LEVELS).
    #: Defaults keep full-fidelity records bitwise comparable across
    #: the service and direct plan_many paths.
    fallback: str = "full"


@dataclass(frozen=True)
class FleetPlan:
    """Struct-of-arrays planner output; all arrays share leading dim S."""

    n_c: np.ndarray             # (S,) int64   chosen union block size
    rate: np.ndarray            # (S,) float64 chosen transmission rate
    bound_value: np.ndarray     # (S,) float64 objective at the optimum
    p_err: np.ndarray           # (S,) float64 loss probability at that rate
    n_o_eff: np.ndarray         # (S,) float64 effective overhead at optimum
    full_transfer: np.ndarray   # (S,) bool    regime flag (delivered >= N)
    boundary: np.ndarray        # (S,) float64 regime-boundary block size
    n_c_per_device: np.ndarray  # (S,) int64   per-device block size
    grid: np.ndarray            # (S, G) evaluated n_c grid
    bound_grid: np.ndarray      # (S, G) objective at the chosen rate
    objective: str = "corollary1"

    def __len__(self) -> int:
        return int(self.n_c.shape[0])

    def record(self, i: int) -> PlanRecord:
        return PlanRecord(
            n_c=int(self.n_c[i]), rate=float(self.rate[i]),
            bound_value=float(self.bound_value[i]),
            p_err=float(self.p_err[i]), n_o_eff=float(self.n_o_eff[i]),
            full_transfer=bool(self.full_transfer[i]),
            boundary=float(self.boundary[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)

    def to_plan(self, batch: ScenarioBatch, i: int) -> Plan:
        """Materialise the i-th result as a full PR-1 :class:`Plan`."""
        sched = BlockSchedule(N=int(batch.N[i]), n_c=int(self.n_c[i]),
                              n_o=float(self.n_o_eff[i]),
                              T=float(batch.T[i]),
                              tau_p=float(batch.tau_p[i]))
        return Plan(
            n_c=int(self.n_c[i]), bound_value=float(self.bound_value[i]),
            full_transfer=sched.full_transfer,
            boundary=float(self.boundary[i]),
            grid=np.asarray(self.grid[i]),
            bound_grid=np.asarray(self.bound_grid[i]),
            schedule=sched, rate=float(self.rate[i]),
            p_err=float(self.p_err[i]),
            n_c_per_device=int(self.n_c_per_device[i]),
            objective=self.objective)


def _pad_batch(scenarios: List[Scenario],
               pad_to: Optional[int] = None) -> List[Scenario]:
    """Pad to a fixed length ``pad_to``, or to the next power of two —
    shape invariance bounds how many kernel shapes a request stream can
    ever compile (one per pad length).  Pad lanes repeat the batch's
    smallest-``N`` scenario: their results are discarded either way, and
    for simulated objectives (Monte Carlo scales with the update count,
    which grows with ``N``) repeating an arbitrary scenario could fill
    the padding with copies of the batch's most expensive simulation."""
    n = len(scenarios)
    if pad_to is None:
        pad_to = pow2ceil(n)
    elif pad_to < n:
        raise ValueError(f"pad_to={pad_to} < batch of {n}")
    pad = min(scenarios, key=lambda sc: sc.N)
    return scenarios + [pad] * (pad_to - n)


@dataclass(frozen=True)
class FleetPlanner:
    """Batched planner: thousands of scenarios per call, any objective.

    ``grid_size`` is the per-scenario grid width G (every scenario gets its
    own log-spaced 1..N grid of that width via
    :func:`repro.core.planner.fleet_grid`); ``shard`` toggles the
    NamedSharding layout across local devices; ``objective`` is the
    default registered objective instance solved by ``plan_batch`` /
    ``plan_many`` (``None`` means the Corollary-1
    :class:`~repro.core.objectives.BoundObjective`), overridable per call.

    ``grid_mode`` selects the solve strategy over the grid:

      * ``"dense"`` (default, and the documented escape hatch): one pass
        over the full grid — the reference semantics every equivalence
        test is stated against.
      * ``"refine"``: hierarchical coarse -> fine.  Pass 1 solves on the
        coarse subsample ``grid[::k]`` + the anchored last point
        (``k ~ sqrt(G/2)``); pass 2 re-solves per-rate bracket windows
        around each rate's coarse argmin, extended by the objective's
        guarded sawtooth tail (see
        :class:`~repro.core.objectives.RefineHints`), cutting the
        evaluated lanes roughly 2-4x.  Both passes run through the same
        jitted ``fleet_solve`` kernels, so every registered objective —
        including plugins built on ``grid_objective_builder`` — gets the
        cut for free.  The refined argmin equals the dense argmin
        (rate-major tie-breaking included) whenever the dense argmin lies
        in the evaluated windows — guaranteed by the bracket for
        coarse-resolved basins and by the dense tail guard for the
        small-block-count sawtooth, and enforced by the refinement
        parity tests; when a grid is too narrow to subsample
        (``G < hints.min_grid``), windows would cover the grid anyway, or
        a kernel does not expose per-rate argmins, the solve silently
        falls back to the dense pass.
    """

    grid_size: int = 128
    shard: bool = True
    objective: Any = None
    grid_mode: str = "dense"
    #: Round refined fine-pass widths up to the next POWER OF TWO instead
    #: of the default data-tight rule (multiples of 8 with a tail guard,
    #: exact otherwise).  Padding only repeats already-evaluated window
    #: points, so plans are unchanged — but the set of fine-pass widths a
    #: request stream can compile becomes enumerable from ``(G, hints)``
    #: alone, which is what lets :meth:`warm` precompile EVERY shape a
    #: serving configuration admits (the "zero traces after warmup" SLO).
    pow2_refine_widths: bool = False
    #: Monte-Carlo simulation engine: ``"auto"`` (default) picks the
    #: pallas slab kernel (:mod:`repro.kernels.mc_ridge`) on TPU and the
    #: ``lax.scan`` engines elsewhere; ``"scan"`` / ``"pallas"`` pin it.
    #: The choice never changes WHICH plan is selected — the engines are
    #: bitwise-matched per :class:`~repro.core.objectives.MonteCarloObjective`
    #: configuration — so only non-default engines are tagged into
    #: :meth:`cache_context`.  Ignored by non-Monte-Carlo objectives.
    mc_impl: str = "auto"

    def __post_init__(self):
        if self.grid_mode not in GRID_MODES:
            raise ValueError(
                f"unknown grid_mode {self.grid_mode!r}; valid: {GRID_MODES}")
        if self.mc_impl not in MC_IMPLS:
            raise ValueError(
                f"unknown mc_impl {self.mc_impl!r}; valid: {MC_IMPLS}")

    def _resolve_mc_impl(self) -> str:
        if self.mc_impl == "auto":
            return "pallas" if jax.default_backend() == "tpu" else "scan"
        return self.mc_impl

    def _resolve_objective(self, override):
        obj = override if override is not None else self.objective
        return obj if obj is not None else BoundObjective()

    def _resolve_grid_mode(self, override: Optional[str]) -> str:
        mode = override if override is not None else self.grid_mode
        if mode not in GRID_MODES:
            raise ValueError(
                f"unknown grid_mode {mode!r}; valid: {GRID_MODES}")
        return mode

    def _default_grid(self, batch: ScenarioBatch, objective) -> np.ndarray:
        """The per-scenario default grid for this objective: ``grid_size``
        wide, capped by the objective's own ``default_grid_size``."""
        size = self.grid_size
        own = getattr(objective, "default_grid_size", None)
        if own is not None:
            size = min(size, int(own))
        return fleet_grid(batch.N, size)

    @staticmethod
    def _solve_arrays(batch: ScenarioBatch, grid: np.ndarray) -> dict:
        """The kernel input dict (np.asarray: no copy when dtypes match)."""
        return {
            "N": np.asarray(batch.N, np.int64),
            "T": np.asarray(batch.T, np.float64),
            "union_no": batch.union_overhead,
            "tau_p": np.asarray(batch.tau_p, np.float64),
            "rates": np.asarray(batch.rates, np.float64),
            "rate_mask": batch.rate_mask,
            "grid": np.ascontiguousarray(grid),
            "link_model_id": np.asarray(batch.link_model_id, np.int32),
            "link_params": np.asarray(batch.link_params, np.float64),
        }

    def _pad_width(self, x: int, pad_multiple: int) -> int:
        """Fine-pass width padding: next power of two under
        ``pow2_refine_widths`` (enumerable shapes, for serving warmup),
        else the data-tight multiple-of-``pad_multiple`` rule."""
        if self.pow2_refine_widths:
            return pow2ceil(int(x))
        return -(-int(x) // pad_multiple) * pad_multiple

    def plan_batch(self,
                   batch: Union[ScenarioBatch, Sequence[Scenario]],
                   consts: BoundConstants,
                   grid: Optional[np.ndarray] = None,
                   objective: Any = None,
                   grid_mode: Optional[str] = None) -> FleetPlan:
        """Solve every scenario in the batch against the objective.

        ``grid`` may be ``None`` (per-scenario default grids), a shared
        ``(G,)`` vector, or a per-scenario ``(S, G)`` matrix;
        ``objective`` and ``grid_mode`` override the planner's defaults
        per call.  With ``grid=None``, an objective declaring
        ``default_grid_size`` (the Monte-Carlo objective: simulating
        training per grid point is expensive) caps the default grid width
        below ``grid_size``.  In ``"refine"`` mode the returned
        ``grid`` / ``bound_grid`` hold the evaluated fine window at each
        scenario's chosen rate (ascending in ``n_c``) rather than the
        full dense grid.
        """
        consts.validate()
        objective = self._resolve_objective(objective)
        mode = self._resolve_grid_mode(grid_mode)
        if not isinstance(batch, ScenarioBatch):
            batch = ScenarioBatch.from_scenarios(list(batch))
        S = len(batch)
        if grid is None:
            grid = self._default_grid(batch, objective)
        else:
            grid = np.asarray(grid, np.int64)
            if grid.ndim == 1:
                grid = np.broadcast_to(grid, (S, grid.shape[0]))
            if grid.shape[0] != S:
                raise ValueError(
                    f"grid has leading dim {grid.shape[0]}, want {S}")

        arrays = self._solve_arrays(batch, grid)
        solve = fleet_solve(objective)
        impl = self._resolve_mc_impl()
        if impl != "scan" and getattr(solve, "supports_mc_impl", False):
            arrays["mc_impl"] = impl  # popped host-side by the MC builder
        out = None
        if mode == "refine":
            out, fine_grid = self._refine_solve(solve, arrays, consts,
                                                batch, objective, grid)
        if out is None:  # dense mode, or refinement fell back
            out = solve(arrays, consts, self.shard, batch)
            fine_grid = np.asarray(grid)

        D = batch.n_devices
        num = np.maximum(batch.N * out["n_o_eff"], 0.0)
        den = batch.T - batch.N
        # regime boundary N * n_o_eff / (T - N); T <= N means the full set
        # can never arrive — clamp to +inf explicitly (matching the scalar
        # boundary_n_c) so no inf/NaN arithmetic can leak into records
        ratio = num / np.where(den > 0.0, den, 1.0)
        boundary = np.where(den > 0.0, ratio, np.inf)
        return FleetPlan(
            n_c=out["n_c"], rate=out["rate"],
            bound_value=out["bound_value"], p_err=out["p_err"],
            n_o_eff=out["n_o_eff"], full_transfer=out["full_transfer"],
            boundary=boundary,
            n_c_per_device=np.maximum(1, out["n_c"] // D),
            grid=fine_grid, bound_grid=out["bound_grid"],
            objective=objective.objective_id)

    def _refine_solve(self, solve, arrays, consts, batch, objective, grid):
        """The two-pass coarse -> fine solve; ``(None, None)`` signals a
        dense fallback (grid too narrow, windows as wide as the grid, or
        a custom kernel without per-rate argmins).

        Two OPT-IN hints reshape the passes for simulated objectives (see
        :class:`~repro.core.objectives.RefineHints`): ``coarse_seeds``
        schedules the seed count of the coarse pass — ``k >= 1`` runs it
        with only ``k`` Monte-Carlo seeds, ``0`` skips the simulated
        coarse pass entirely and takes the per-rate centers from a
        full-grid Corollary-1 solve (the bound is a few-microsecond
        closed form, and its per-rate argmin lands in the same basin the
        simulated coarse pass brackets) — and ``refine_rates=K`` prunes
        the fine pass to each scenario's top-``K`` rates as ranked by the
        coarse per-rate minima.  With either hint active the solve never
        falls back to dense on width grounds: the caller opted into an
        approximate (but far cheaper) search, so a wide window at pruned
        rates still beats the dense all-rates pass it would fall back to.

        ``coarse_strides`` stacks extra coarse stages between the first
        pass and the fine windows (the MULTI-LEVEL schedule): stage 0
        sweeps the grid at ``coarse_strides[0]``, each later stage
        re-centres at step ``coarse_strides[i]`` inside the previous
        stage's ``±coarse_strides[i-1]`` bracket, and the fine pass runs
        the dense ``±coarse_strides[-1]`` window.  Rate pruning applies
        after stage 0 and ``coarse_seeds`` throttles every coarse stage,
        so the full ``n_runs`` seed budget is only ever spent on the
        final narrow window.

        Two further schedule hints tune that budget split:
        ``fine_radius`` widens (or narrows) the dense fine window to
        ``±fine_radius`` independently of the last coarse stride, and
        ``coarse_updates`` caps the simulated update horizon of every
        coarse stage (the fine pass always trains the full timeline) —
        a truncated-horizon coarse pass ranks basins almost as well at
        a fraction of the scan cost, and the wide full-horizon fine
        window absorbs the residual center drift.
        """
        S, G = grid.shape
        hints = refine_hints_for(objective)
        if G < max(2, hints.min_grid):
            return None, None
        schedulable = getattr(solve, "supports_mc_impl", False)
        ml = hints.coarse_strides if schedulable else None
        if ml is not None:
            ml = tuple(max(2, min(int(s), G - 1)) for s in ml)
        hz = hints.coarse_updates if schedulable else None
        # an objective's explicit stride hint is honoured as-is (clamped
        # to the grid); only the automatic work-minimising default applies
        stride = ((hints.fine_radius if schedulable else None)
                  or (ml[-1] if ml else
                      hints.stride or int(round(np.sqrt(G / 2.0)))))
        stride = max(2, min(int(stride), G - 1))
        cpos = coarse_indices(G, ml[0] if ml else stride)
        if cpos.size < 4:
            return None, None
        guided = schedulable and hints.coarse_seeds == 0
        K = hints.refine_rates if schedulable else None
        R = int(np.asarray(arrays["rates"]).shape[1])
        prune = K is not None and K < R
        scheduled = (guided or prune or ml is not None or hz is not None
                     or (schedulable and bool(hints.coarse_seeds
                                              or hints.fine_radius)))

        if hints.tail_blocks:
            # first dense index inside the guarded sawtooth tail
            # (N / n_c <= tail_blocks); rows of `grid` are ascending
            tail = np.sum(
                grid.astype(np.int64) * int(hints.tail_blocks)
                < batch.N[:, None], axis=1)
        else:
            tail = None
        # tail windows vary per scenario: round the padded width up to a
        # multiple of 8 so a request stream compiles O(G / 8) fine-pass
        # shapes, not one per distinct tail length
        pad_multiple = 8 if tail is not None else 1
        # upper-bound the fine width BEFORE the coarse solve: bracket +
        # longest tail suffix (centers can only merge the two, never
        # widen them), so an unprofitable batch — e.g. one small-N
        # scenario whose guarded tail spans most of the log grid — costs
        # nothing instead of a wasted coarse pass on top of the dense one
        w_ub = 2 * stride + 1 + (G - int(tail.min()) if tail is not None
                                 else 0)
        if not scheduled and \
                cpos.size + min(G, self._pad_width(w_ub, pad_multiple)) >= G:
            return None, None  # two passes would outwork the dense solve

        if guided:
            # bound-guided coarse: the closed-form Corollary-1 solve on
            # the FULL grid supplies per-rate centers (already dense
            # indices) and the per-rate ranking, for ~zero simulation
            bound_arrays = {k: v for k, v in arrays.items()
                            if k not in ("mc_impl", "mc_seeds")}
            out1 = fleet_solve(BoundObjective())(bound_arrays, consts,
                                                 self.shard, batch)
            centers = np.asarray(out1["gi_per_rate"], np.int64)
        else:
            arrays1 = dict(arrays,
                           grid=np.ascontiguousarray(grid[:, cpos]))
            if schedulable and hints.coarse_seeds:
                arrays1["mc_seeds"] = int(hints.coarse_seeds)
            if hz:
                arrays1["mc_updates"] = int(hz)
            out1 = solve(arrays1, consts, self.shard, batch)
            centers1 = out1.get("gi_per_rate")
            if centers1 is None:  # pre-refinement custom kernel
                return None, None
            centers = cpos[np.asarray(centers1, np.int64)]     # (S, R)

        sel = None
        if prune and "val_per_rate" in out1:
            # keep each scenario's top-K rates by the coarse per-rate
            # minima; ascending index order preserves the reduction's
            # rate-major tie-breaking among the kept rates
            vpr = np.asarray(out1["val_per_rate"])
            sel = np.sort(np.argsort(vpr, axis=1, kind="stable")[:, :K],
                          axis=1)                              # (S, K)
            centers = np.take_along_axis(centers, sel, axis=1)

        if ml is not None:
            # mid coarse stages: re-centre at each finer step inside the
            # previous stage's bracket.  Windows are host-built per-rate
            # index sets — clipping at the grid edges keeps the width
            # (hence the compiled shape) data-independent.
            for prev, step in zip(ml, ml[1:]):
                offs = np.arange(-(prev // step),
                                 prev // step + 1) * step      # (O,)
                win = np.clip(centers[:, :, None] + offs, 0, G - 1)
                arrays_i = dict(arrays, grid=np.ascontiguousarray(
                    np.take_along_axis(grid[:, None, :], win, axis=2)))
                if sel is not None:
                    arrays_i["rates"] = np.ascontiguousarray(
                        np.take_along_axis(
                            np.asarray(arrays["rates"]), sel, 1))
                    arrays_i["rate_mask"] = np.ascontiguousarray(
                        np.take_along_axis(
                            np.asarray(arrays["rate_mask"]), sel, 1))
                if hints.coarse_seeds:
                    arrays_i["mc_seeds"] = int(hints.coarse_seeds)
                if hz:
                    arrays_i["mc_updates"] = int(hz)
                out_i = solve(arrays_i, consts, self.shard, batch)
                gi = np.asarray(out_i["gi_per_rate"], np.int64)
                centers = np.take_along_axis(
                    win, gi[:, :, None], axis=2)[..., 0]

        count = refine_window_bounds(centers, stride, G, tail)[-1]
        W = min(G, self._pad_width(int(count.max()), pad_multiple))
        if not scheduled and cpos.size + W >= G:
            return None, None  # the merged windows still cover the grid

        if getattr(solve, "supports_refine_windows", False):
            # fused fine pass: windows are built and gathered on device
            # from (centers, tail_start); the host only sizes W
            arrays2 = dict(
                arrays,
                centers=np.ascontiguousarray(centers),
                tail_start=(np.zeros(S, np.int64) + G if tail is None
                            else np.asarray(tail, np.int64)),
                refine_stride=stride, refine_width=W)
        else:  # e.g. the Monte-Carlo kernel: host-built (S, R, W) windows
            _, win_grid, _ = refine_grid(grid, centers, stride,
                                         tail_start=tail, width=W)
            arrays2 = dict(arrays, grid=np.ascontiguousarray(win_grid))
        if sel is not None:
            arrays2["rates"] = np.ascontiguousarray(
                np.take_along_axis(np.asarray(arrays["rates"]), sel, 1))
            arrays2["rate_mask"] = np.ascontiguousarray(
                np.take_along_axis(np.asarray(arrays["rate_mask"]), sel, 1))
        out2 = solve(arrays2, consts, self.shard, batch)
        return out2, np.asarray(out2["sel_grid"])

    def cache_context(self, consts: BoundConstants,
                      grid_mode: Optional[str] = None) -> tuple:
        """The cache-key PREFIX ``plan_many`` scopes its entries under —
        ``(consts, grid width, grid mode[, width rule])``.  Exposed so a
        serving layer can address the exact entry a drifted session's
        plan lives at (``PlanCache.invalidate``) without re-deriving the
        planner's keying scheme."""
        mode = self._resolve_grid_mode(grid_mode)
        impl = self._resolve_mc_impl()
        # pow2-padded refine widths can evaluate (strictly more) window
        # points than the data-tight rule, so the two never share entries.
        # A non-default Monte-Carlo engine is tagged in too — the engines
        # are bitwise-matched per objective configuration, but scoping by
        # engine keeps a mis-matched build from ever aliasing plans (the
        # default "scan" resolution stays token-free so existing cache
        # layouts are unchanged).
        return (consts, self.grid_size, mode) + \
            (("pow2w",) if self.pow2_refine_widths else ()) + \
            (("mc_impl", impl) if impl != "scan" else ())

    def _warm_widths(self, G: int, stride: int, n_coarse: int) -> List[int]:
        """Every fine-pass width a stream of ``plan_batch`` calls over a
        ``G``-wide grid can reach under pow2 width padding: powers of two
        from the narrowest possible window (``stride + 1``, a fully
        edge-clamped bracket) up to the dense-fallback threshold."""
        widths: List[int] = []
        w = pow2ceil(stride + 1)
        while n_coarse + w < G:
            widths.append(w)
            w *= 2
        return widths

    def warm(self, scenarios: Sequence[Scenario], consts: BoundConstants,
             objective: Any = None, grid_mode: Optional[str] = None,
             pad_to: Optional[int] = None) -> int:
        """AOT warmup: compile every kernel shape that ``plan_batch`` /
        ``plan_many`` calls with this batch signature can hit, and return
        the number of fresh traces it cost.

        ``scenarios`` fixes the signature — the padded batch length ``S``
        (via ``pad_to``, e.g. a serving bucket), the rate width ``R`` and,
        for the Monte-Carlo objective, the padded scan length (pin it with
        the objective's ``min_updates`` floor).  The sweep compiles the
        dense solve (also the refine fallback) and, in ``"refine"`` mode,
        the coarse pass plus the fine pass at every reachable width.  The
        width sweep is exhaustive only under ``pow2_refine_widths`` (the
        data-tight default admits data-dependent widths no sweep can
        enumerate); a planning service therefore runs with pow2 widths,
        warms each configured ``(objective, grid_mode, bucket)`` and gets
        the zero-traces-after-warmup guarantee the serving tests assert.
        Results are discarded; the cache is never touched.
        """
        consts.validate()
        objective = self._resolve_objective(objective)
        mode = self._resolve_grid_mode(grid_mode)
        batch = ScenarioBatch.from_scenarios(
            _pad_batch(list(scenarios), pad_to))
        grid = self._default_grid(batch, objective)
        arrays = self._solve_arrays(batch, grid)
        solve = fleet_solve(objective)
        with trace_delta() as traces:
            self._warm_sweep(solve, arrays, consts, batch, grid, mode,
                             objective)
        return traces.total

    def _warm_sweep(self, solve, arrays, consts, batch, grid, mode,
                    objective) -> None:
        # dense pass — the "dense" mode solve AND the refine fallback
        solve(arrays, consts, self.shard, batch)
        if mode == "refine":
            S, G = grid.shape
            hints = refine_hints_for(objective)
            schedulable = getattr(solve, "supports_mc_impl", False)
            ml = hints.coarse_strides if schedulable else None
            if ml is not None:
                ml = tuple(max(2, min(int(s), G - 1)) for s in ml)
            hz = hints.coarse_updates if schedulable else None
            stride = ((hints.fine_radius if schedulable else None)
                      or (ml[-1] if ml else
                          hints.stride or int(round(np.sqrt(G / 2.0)))))
            stride = max(2, min(int(stride), G - 1))
            guided = schedulable and hints.coarse_seeds == 0
            K = hints.refine_rates if schedulable else None
            prune = K is not None and K < batch.n_rates
            scheduled = (guided or prune or ml is not None
                         or hz is not None
                         or (schedulable and bool(hints.coarse_seeds
                                                  or hints.fine_radius)))
            if G >= max(2, hints.min_grid):
                cpos = coarse_indices(G, ml[0] if ml else stride)
                if scheduled:
                    # a scheduled solve never falls back on width grounds
                    # (see _refine_solve), so the reachable fine widths
                    # run all the way to the bracket's pow2 ceiling
                    # (tail_blocks is None for simulated objectives, so
                    # the data-independent 2*stride+1 bound is exact)
                    if self.pow2_refine_widths:
                        widths, w = [], pow2ceil(stride + 1)
                        while w < min(G, pow2ceil(2 * stride + 1)):
                            widths.append(w)
                            w *= 2
                        widths.append(min(G, w))
                    else:
                        # data-tight rule: a fixed schedule reaches ONE
                        # width — the full bracket
                        widths = [min(G, 2 * stride + 1)]
                else:
                    widths = self._warm_widths(G, stride, cpos.size)
                if cpos.size >= 4 and widths:
                    if guided:
                        bound_arrays = {
                            k: v for k, v in arrays.items()
                            if k not in ("mc_impl", "mc_seeds")}
                        fleet_solve(BoundObjective())(
                            bound_arrays, consts, self.shard, batch)
                    else:
                        arrays1 = dict(
                            arrays,
                            grid=np.ascontiguousarray(grid[:, cpos]))
                        if schedulable and hints.coarse_seeds:
                            arrays1["mc_seeds"] = int(hints.coarse_seeds)
                        if hz:
                            arrays1["mc_updates"] = int(hz)
                        solve(arrays1, consts, self.shard, batch)  # coarse
                    n_rates = K if prune else batch.n_rates
                    centers = np.zeros((S, n_rates), np.int64)
                    tail_start = np.full(S, G, np.int64)
                    fine = dict(arrays)
                    if prune:
                        fine["rates"] = np.ascontiguousarray(
                            np.asarray(arrays["rates"])[:, :K])
                        fine["rate_mask"] = np.ascontiguousarray(
                            np.asarray(arrays["rate_mask"])[:, :K])
                    if ml is not None:
                        # mid coarse stages: one data-independent window
                        # shape per (prev, step) pair — clip keeps the
                        # width fixed, so dummy zero centers compile the
                        # exact shapes plan_batch will hit
                        for prev, step in zip(ml, ml[1:]):
                            offs = np.arange(-(prev // step),
                                             prev // step + 1) * step
                            win = np.clip(
                                centers[:, :, None] + offs, 0, G - 1)
                            arrays_i = dict(fine, grid=np.ascontiguousarray(
                                np.take_along_axis(grid[:, None, :], win,
                                                   axis=2)))
                            if hints.coarse_seeds:
                                arrays_i["mc_seeds"] = int(
                                    hints.coarse_seeds)
                            if hz:
                                arrays_i["mc_updates"] = int(hz)
                            solve(arrays_i, consts, self.shard, batch)
                    for W in widths:
                        if getattr(solve, "supports_refine_windows", False):
                            arrays2 = dict(fine, centers=centers,
                                           tail_start=tail_start,
                                           refine_stride=stride,
                                           refine_width=W)
                        else:  # host-built windows (e.g. Monte-Carlo)
                            _, win_grid, _ = refine_grid(grid, centers,
                                                         stride, width=W)
                            arrays2 = dict(
                                fine,
                                grid=np.ascontiguousarray(win_grid))
                        solve(arrays2, consts, self.shard, batch)

    def plan_many(self, scenarios: Sequence[Scenario],
                  consts: BoundConstants,
                  cache: Optional[PlanCache] = None,
                  pad_to: Optional[int] = None,
                  objective: Any = None,
                  grid_mode: Optional[str] = None,
                  timings: Optional[Dict[str, float]] = None
                  ) -> List[PlanRecord]:
        """Plan a request list, deduplicating through the cache.

        Cache hits (and in-batch duplicates, up to key quantisation) skip
        the solve; the remaining unique misses are padded — to ``pad_to``
        when given (a serving loop passes its micro-batch size so ONE
        kernel shape covers every batch), else to the next power of two —
        and solved in ONE ``plan_batch`` call.  Results come back in
        request order.  Cache entries are scoped to ``(consts, grid_size,
        grid_mode)`` AND the objective's ``cache_token()`` so one cache
        can serve several configurations, objectives AND grid modes
        without cross-talk: a refined plan can never answer a dense
        calibration request for the same scenario, even when the two
        coincide.

        ``timings``, when given, receives the phase attribution the
        serving spans report: ``cache_lookup_s`` (quantised-key probes +
        in-batch dedup) and ``solve_s`` (the ``plan_batch`` call,
        including result write-back) are ADDED into the dict, so a caller
        can pass one dict across several calls and read totals.
        """
        scenarios = list(scenarios)
        if not scenarios:
            return []
        objective = self._resolve_objective(objective)
        mode = self._resolve_grid_mode(grid_mode)

        def charge(phase: str, t0: float) -> float:
            now = time.perf_counter()
            if timings is not None:
                timings[phase] = timings.get(phase, 0.0) + (now - t0)
            return now

        records: List[Optional[PlanRecord]] = [None] * len(scenarios)
        if cache is None:
            t0 = time.perf_counter()
            fp = self.plan_batch(_pad_batch(scenarios, pad_to), consts,
                                 objective=objective, grid_mode=mode)
            out = [fp.record(i) for i in range(len(scenarios))]
            charge("solve_s", t0)
            return out

        ctx = self.cache_context(consts, mode)
        miss: "OrderedDict[tuple, List[int]]" = OrderedDict()
        t0 = time.perf_counter()
        for i, sc in enumerate(scenarios):
            rec = cache.get(sc, context=ctx, objective=objective)
            if rec is not None:
                records[i] = rec
            else:
                miss.setdefault(
                    cache.key(sc, context=ctx, objective=objective),
                    []).append(i)
        t0 = charge("cache_lookup_s", t0)
        if miss:
            reps = [scenarios[idxs[0]] for idxs in miss.values()]
            fp = self.plan_batch(_pad_batch(reps, pad_to), consts,
                                 objective=objective, grid_mode=mode)
            for j, idxs in enumerate(miss.values()):
                rec = fp.record(j)
                cache.put(scenarios[idxs[0]], rec, context=ctx,
                          objective=objective)
                for i in idxs:
                    records[i] = rec
            charge("solve_s", t0)
        return records  # type: ignore[return-value]
