"""``jax.numpy`` port of the Corollary-1 evaluator (eqs. 14-15).

Algebraically identical to the reference
:func:`repro.core.bounds.corollary1_bound` — the numpy implementation stays
the REFERENCE semantics; this port exists so the fleet planner can evaluate
the bound for thousands of scenarios in one jitted, device-sharded call.
Any change to the numpy math must land here too (the fleet property tests
enforce agreement to ~1e-12 relative).

Two deliberate restructurings for CPU throughput (the kernel is
transcendental-bound; these roughly halve its cost at identical results up
to float64 rounding):

  * powers become single exponentials of precomputed log-contractions:
    ``r ** n_p == exp(n_p log r)`` — and ``log r`` is clamped at the
    smallest normal so ``r == 0`` still yields ``rp == 0`` for ``n_p >= 1``
    and ``1`` for ``n_p == 0``, matching numpy's ``0 ** k``;
  * ONE geometric sum serves both regimes: each grid point only ever reads
    the sum with its own regime's term count (``B - 1`` in regime (a),
    ``ceil(B_d) - 1`` in regime (b)), so the two reference ``_geom_sum``
    calls collapse into one via a ``where`` on the count.

Quotients feeding ``floor``/``ceil`` (block counts, update counts) keep the
exact division of the reference — regime boundaries are knife-edges where
an ulp flips a whole block, so those must round identically to numpy.
"""
from __future__ import annotations

import jax.numpy as jnp


def corollary1_bound_jax(n_c, *, N, T, n_o, tau_p, sigma, e0, contraction):
    """Eq. (14)/(15) on broadcastable jnp arrays.

    ``n_c``/``n_o`` carry the grid axes, ``N``/``T``/``tau_p`` the
    per-scenario axes; ``sigma``/``e0``/``contraction`` are the three
    scalars from :class:`~repro.core.bounds.BoundConstants`
    (``variance_floor``, ``init_gap``, ``contraction``), passed as plain
    arguments so a jitted caller never retraces on new constants.  Call
    under ``jax.experimental.enable_x64()`` for float64 agreement with
    the reference.
    """
    n_c = jnp.asarray(n_c)
    n_o = jnp.asarray(n_o)
    dur = n_c + n_o
    B_d = N / n_c
    invB_d = n_c / N                      # bound terms only ever DIVIDE by B_d
    B = jnp.floor(T / dur)                # whole blocks that fit
    n_p = jnp.floor(dur / tau_p)          # SGD updates per block
    full = T > B_d * dur                  # regime (b)

    lr = jnp.log(jnp.maximum(contraction, jnp.finfo(dur.dtype).tiny))
    a = n_p * lr                          # log of the per-block contraction
    rp = jnp.exp(a)                       # r ** n_p
    tie = jnp.abs(1.0 - rp) < 1e-15
    inv_1mrp = 1.0 / jnp.where(tie, 1.0, 1.0 - rp)

    # sum_{l=1}^{k} rp^l with the regime's own term count k (eq. 14 wants
    # B - 1 terms, eq. 15 wants ceil(B_d) - 1): closed form
    # rp (1 - rp^k) / (1 - rp), degenerating to k when rp == 1, 0 when k <= 0
    k = jnp.where(full,
                  jnp.maximum(jnp.ceil(B_d) - 1.0, 0.0),
                  jnp.maximum(B - 1.0, 0.0))
    s_g = jnp.where(k <= 0, 0.0,
                    jnp.where(tie, k,
                              rp * (1.0 - jnp.exp(a * k)) * inv_1mrp))

    # ---- regime (a): T <= B_d (n_c + n_o)   (eq. 14) ----------------------
    frac = jnp.clip((B - 1.0) * invB_d, 0.0, 1.0)
    bound_a = sigma * frac + (1.0 - frac) * e0 + (e0 - sigma) * s_g * invB_d

    # ---- regime (b): T > B_d (n_c + n_o)    (eq. 15) ----------------------
    tau_l = jnp.maximum(T - B_d * dur, 0.0)
    n_l = jnp.floor(tau_l / tau_p)
    bound_b = sigma + jnp.exp(lr * n_l) * (e0 - sigma) * (1.0 + s_g) * invB_d

    return jnp.where(full, bound_b, bound_a)
