"""Trace accounting for the jitted fleet kernels.

A planning SERVICE promises that after warmup no request ever pays a
``jax.jit`` trace + compile (hundreds of milliseconds on the serving
path, against a sub-millisecond solve).  That promise is only auditable
if traces are observable, so every fleet kernel body calls
:func:`record_trace` as its first statement: a jitted function's Python
body runs exactly once per trace (never on cached executions), which
makes the counter an exact retrace detector — the property the serving
tests and the CI smoke assert with "zero traces after warmup".

Events are tagged with the kernel kind and its shape signature
``(kind, S, R, G[, scan])``, so the service's stats layer can report
per-bucket compile counts and a warmup sweep can verify it covered
every shape its configuration admits.  Counters are process-global and
lock-protected (the service traces from worker threads).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, Tuple

_LOCK = threading.Lock()
_EVENTS: Dict[Tuple, int] = {}
_TOTAL = 0


def record_trace(tag: Tuple) -> None:
    """Count one trace of the kernel identified by ``tag`` (a hashable
    ``(kind, *shape)`` tuple).  Called from inside jitted function bodies:
    executes during tracing only, so the count equals the trace count."""
    global _TOTAL
    with _LOCK:
        _EVENTS[tag] = _EVENTS.get(tag, 0) + 1
        _TOTAL += 1


def trace_count() -> int:
    """Total traces recorded since process start (monotone)."""
    with _LOCK:
        return _TOTAL


def trace_events() -> Dict[Tuple, int]:
    """Snapshot of per-tag trace counts ``{(kind, *shape): n}``."""
    with _LOCK:
        return dict(_EVENTS)


def trace_count_for(tag: Tuple) -> int:
    """Traces recorded for one specific ``(kind, *shape)`` tag."""
    with _LOCK:
        return _EVENTS.get(tag, 0)


@dataclass
class TraceDelta:
    """Traces recorded inside a :func:`trace_delta` block.  ``total`` and
    ``by_tag`` are live while the block runs and frozen at exit;
    ``by_tag`` keeps only tags whose count changed."""

    total: int = 0
    by_tag: Dict[Tuple, int] = field(default_factory=dict)

    def __bool__(self) -> bool:
        return self.total > 0


@contextmanager
def trace_delta() -> Iterator[TraceDelta]:
    """Count traces recorded within the block — the canonical replacement
    for hand-rolled ``t0 = trace_count(); ...; trace_count() - t0``
    subtraction, which silently double-counts when the two reads are
    interleaved with another thread's bracket.  The delta here is still
    process-global (traces ARE global state), but the bracketing is one
    expression, so callers cannot mismatch the reads."""
    with _LOCK:
        total0 = _TOTAL
        events0 = dict(_EVENTS)
    delta = TraceDelta()
    try:
        yield delta
    finally:
        with _LOCK:
            delta.total = _TOTAL - total0
            delta.by_tag = {
                tag: n - events0.get(tag, 0)
                for tag, n in _EVENTS.items()
                if n - events0.get(tag, 0)
            }
