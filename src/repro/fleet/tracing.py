"""Trace accounting for the jitted fleet kernels.

A planning SERVICE promises that after warmup no request ever pays a
``jax.jit`` trace + compile (hundreds of milliseconds on the serving
path, against a sub-millisecond solve).  That promise is only auditable
if traces are observable, so every fleet kernel body calls
:func:`record_trace` as its first statement: a jitted function's Python
body runs exactly once per trace (never on cached executions), which
makes the counter an exact retrace detector — the property the serving
tests and the CI smoke assert with "zero traces after warmup".

Events are tagged with the kernel kind and its shape signature
``(kind, S, R, G[, scan])``, so the service's stats layer can report
per-bucket compile counts and a warmup sweep can verify it covered
every shape its configuration admits.  Counters are process-global and
lock-protected (the service traces from worker threads).
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

_LOCK = threading.Lock()
_EVENTS: Dict[Tuple, int] = {}
_TOTAL = 0


def record_trace(tag: Tuple) -> None:
    """Count one trace of the kernel identified by ``tag`` (a hashable
    ``(kind, *shape)`` tuple).  Called from inside jitted function bodies:
    executes during tracing only, so the count equals the trace count."""
    global _TOTAL
    with _LOCK:
        _EVENTS[tag] = _EVENTS.get(tag, 0) + 1
        _TOTAL += 1


def trace_count() -> int:
    """Total traces recorded since process start (monotone)."""
    with _LOCK:
        return _TOTAL


def trace_events() -> Dict[Tuple, int]:
    """Snapshot of per-tag trace counts ``{(kind, *shape): n}``."""
    with _LOCK:
        return dict(_EVENTS)
