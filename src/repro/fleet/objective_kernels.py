"""Jitted batched objective kernels, one per registered planning objective.

The jax side of the pluggable objective registry
(:mod:`repro.core.objectives`), mirroring how
:mod:`repro.fleet.link_kernels` is the jax side of the link registry.  A
kernel BUILDER is registered per ``objective_id``; ``fleet_solve`` turns an
objective instance into a host-level solver

    ``solve(arrays, consts, shard, batch) -> dict of (S,)-leading arrays``

that evaluates the objective over the joint ``(rate, n_c)`` grid of every
scenario in one jitted x64 call and reduces it with the canonical
rate-major argmin tie-breaking — the exact layout the scalar
``ObjectivePlanner`` uses, so batched and scalar plans coincide.

Built-in kernels:

  * ``corollary1`` — the Corollary-1 bound at the stationary link-induced
    effective overhead (the pre-registry fleet solve, op-for-op);
  * ``markov_arq`` — the same bound, but Gilbert-Elliott scenarios get
    their expected block duration from the EXACT per-(rate, state)
    Markov-reward linear solve (closed-form 2x2, vectorised over the
    batch) instead of the stationary-loss approximation; the degenerate
    ``p_good == p_bad`` rows keep the stationary division form so the
    reduction to ``corollary1`` stays bitwise;
  * ``montecarlo`` — the empirical ridge objective: the scalar seed loop
    of ``average_final_loss`` vmapped over scenarios x rates x grid
    points x seeds over a shared padded update timeline.  RNG streams
    (per-run keys via ``seed_stream``, per-step splits, per-update
    sample draws) replicate the scalar path exactly, so fleet plans match
    the scalar Monte-Carlo planner seed-for-seed; training math runs in
    float32 (like the scalar path) while the timeline/overhead arithmetic
    stays float64.  Three simulation engines share that contract: the
    reference ``lax.scan`` (per-slot RNG in the loop), the table-driven
    CRN scan (``objective.crn=True``: slab-precomputed index/mask tables
    + shared per-slot uniforms + affine-fused update), and the pallas
    slab kernel (``mc_impl="pallas"``,
    :func:`repro.kernels.mc_ridge.mc_ridge_slab`, interpreted off-TPU)
    which consumes the same tables bitwise.

Registering a kernel for a custom grid objective needs only its value
function (see README "Planning objectives")::

    def _my_values(g, N, T, n_o_eff, tau_p, sigma, e0, contraction):
        return (g + n_o_eff) / g          # expected time per sample

    register_objective_kernel("throughput",
                              grid_objective_builder(_my_values))

Registration bumps :func:`objective_kernel_version`; jitted solves are
additionally keyed on the LINK kernel-table version, so late link plugins
retrace rather than stale-dispatch.
"""
from __future__ import annotations

import time
from functools import lru_cache, partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.links import P_ERR_MAX, GilbertElliottLink
from repro.core.objectives import objective_spec
from repro.core.pipeline import ridge_grad_sample, ridge_loss_full
from repro.fleet.bounds_jax import corollary1_bound_jax
from repro.fleet.link_kernels import kernel_table, kernel_table_version
from repro.fleet.tracing import record_trace
from repro.obs.runtime import record_solve

_BUILDERS: Dict[str, Callable] = {}
_VERSION = 0


def register_objective_kernel(objective_id: str, builder: Callable) -> None:
    """Register the batched kernel builder for ``objective_id``.

    ``builder(objective)`` must return a host-level callable
    ``solve(arrays, consts, shard, batch)``.  The objective must already
    be registered with :func:`repro.core.objectives.register_objective`.
    """
    global _VERSION
    objective_spec(objective_id)  # KeyError with guidance if no spec
    prior = _BUILDERS.get(objective_id)
    if prior is builder:
        return  # idempotent re-registration: no version bump
    if prior is not None:
        raise ValueError(
            f"objective {objective_id!r} already has a registered kernel")
    _BUILDERS[objective_id] = builder
    _VERSION += 1


def unregister_objective_kernel(objective_id: str) -> None:
    """Remove a kernel builder (plugin teardown / tests).  No-op if absent."""
    global _VERSION
    if _BUILDERS.pop(objective_id, None) is not None:
        _VERSION += 1


def objective_kernel_version() -> int:
    """Monotone counter bumped on (un)registration."""
    return _VERSION


def fleet_solve(objective) -> Callable:
    """The batched solver for an objective instance (KeyError if none)."""
    objective_id = getattr(objective, "objective_id", None)
    builder = _BUILDERS.get(objective_id)
    if builder is None:
        raise KeyError(
            f"objective {objective_id!r} has no registered fleet kernel; "
            "call repro.fleet.objective_kernels.register_objective_kernel "
            f"(known: {sorted(_BUILDERS)})")
    return builder(objective)


def _maybe_shard(arrays: dict, S: int) -> dict:
    """Lay the batch out across local devices over the scenario axis."""
    devices = jax.local_devices()
    if len(devices) <= 1 or S % len(devices) != 0:
        return arrays
    mesh = Mesh(np.asarray(devices), ("fleet",))
    sharding = NamedSharding(mesh, P("fleet"))
    return {k: jax.device_put(v, sharding) for k, v in arrays.items()}


def _switch_p_err(branches, link_model_id, link_params, rates):
    """Per-scenario link dispatch: lax.switch over the registered p_err
    kernels, vmapped over the batch (under vmap every branch runs and the
    result is selected — fine: p_err is O(R), the objective is O(R G))."""

    def p_err_one(mid, params, rate_row):
        return jax.lax.switch(mid, branches, params, rate_row)

    return jax.vmap(p_err_one)(link_model_id, link_params, rates)  # (S, R)


def _reduce_joint_argmin(vals, n_o_eff, p, N, T, rates, rate_mask, grid):
    """Two-stage argmin == flat rate-major argmin (ties: first grid point
    within a rate, then first rate), matching the scalar
    ``repro.core.scenario._finish_plan`` exactly.  Shared by every
    objective kernel so tie-breaking can never drift between objectives.

    ``grid`` is the per-scenario ``(S, G)`` grid shared across rates, or
    a per-rate ``(S, R, G)`` window grid — the fine pass of the
    coarse->fine solve hands every rate its own bracket, whose ascending
    dense-index order keeps the within-rate "first grid point" tie-break
    identical to the single-pass dense reduction.  Per-rate grids
    additionally return the chosen rate's window row (``sel_grid``), and
    every reduction reports the per-rate argmin lanes (``gi_per_rate``) —
    the coarse pass's output that the fine pass brackets around.
    """
    S = rates.shape[0]
    masked = jnp.where(rate_mask[:, :, None], vals, jnp.inf)
    gi_per_rate = jnp.argmin(masked, axis=2)                   # (S, R)
    ri = jnp.argmin(jnp.min(masked, axis=2), axis=1)           # (S,)
    s = jnp.arange(S)
    gi = gi_per_rate[s, ri]

    n_c = grid[s, gi] if grid.ndim == 2 else grid[s, ri, gi]
    best_no = n_o_eff[s, ri, gi]
    best_dur = n_c.astype(T.dtype) + best_no
    delivered = jnp.minimum(jnp.floor(T / best_dur) * n_c, N)
    out = {
        "n_c": n_c,
        "rate": rates[s, ri],
        "bound_value": vals[s, ri, gi],
        "p_err": p[s, ri],
        "n_o_eff": best_no,
        "full_transfer": delivered >= N,
        "bound_grid": vals[s, ri],
        "gi_per_rate": gi_per_rate,
        # per-rate minima: what the coarse pass ranks rates by when the
        # fine pass prunes to the top-K rates (RefineHints.refine_rates)
        "val_per_rate": jnp.min(masked, axis=2),
    }
    if grid.ndim == 3:
        out["sel_grid"] = grid[s, ri]
    return out


# ---------------------------------------------------------------------------
# grid objectives: any value function of the (S, R, G) effective overhead
# ---------------------------------------------------------------------------


_GE_MODEL_ID = GilbertElliottLink.model_id


def _ge_exact_arq_inflation(link_params, rates):
    """(S, R) exact burst-aware ARQ inflation from packed GE parameters —
    the jax mirror of ``GilbertElliottLink.exact_arq_inflation`` (same op
    order).  Rows of other models produce garbage here; callers mask."""
    beta, p_good, p_bad, p_gb, p_bg = (
        link_params[:, k:k + 1] for k in range(5))            # (S, 1)
    decay = jnp.exp(-beta * jnp.maximum(rates - 1.0, 0.0))
    p_g = jnp.minimum(1.0 - (1.0 - p_good) * decay, P_ERR_MAX)
    p_b = jnp.minimum(1.0 - (1.0 - p_bad) * decay, P_ERR_MAX)
    den_g = 1.0 - p_g * (1.0 - p_gb)
    den_b = 1.0 - p_b * (1.0 - p_bg)
    det = den_g * den_b - p_g * p_gb * p_b * p_bg
    t_g = (den_b + p_g * p_gb) / det
    t_b = (den_g + p_b * p_bg) / det
    pi_b = p_gb / (p_gb + p_bg)
    return t_g + pi_b * (t_b - t_g)


def _corollary1_values(g, N, T, n_o_eff, tau_p, sigma, e0, contraction):
    """The Corollary-1 bound as a grid-objective value function."""
    return corollary1_bound_jax(g, N=N, T=T, n_o=n_o_eff, tau_p=tau_p,
                                sigma=sigma, e0=e0, contraction=contraction)


def _build_grid_solve(branches, value_fn, exact_arq: bool):
    """Jit a grid-objective solve closed over a link-kernel branch table.

    Shapes: per-scenario vectors (S,), rate matrix (S, R), grid (S, G);
    output per-scenario reductions.  ``exact_arq`` swaps the stationary
    ARQ inflation for the exact Markov-reward block time on
    non-degenerate Gilbert-Elliott rows.

    Returns ``(solve, solve_windows)``: the single-pass solve over a
    ``(S, G)`` / per-rate ``(S, R, G)`` grid, and the FUSED fine pass of
    the coarse->fine solve, which builds the per-rate bracket+tail
    windows ON DEVICE from ``(centers, tail_start)`` — mirroring
    :func:`repro.core.planner.refine_window_bounds` op-for-op — so the
    serving hot path never materialises or transfers ``(S, R, W)``
    window arrays from the host.
    """

    def _core(N, T, union_no, tau_p, rates, rate_mask, grid,
              link_model_id, link_params, sigma, e0, contraction):
        # runs once per TRACE (both the dense jit and _solve_windows
        # funnel through this body) — the serving layer's retrace audit
        record_trace(("grid", int(exact_arq)) + tuple(grid.shape))
        rate = rates[:, :, None]                                   # (S, R, 1)
        # (S, G) shared grid broadcasts over rates; a (S, R, G) window
        # grid (the coarse->fine pass) evaluates per-rate points
        g = (grid[:, None, :] if grid.ndim == 2 else grid).astype(T.dtype)

        p = _switch_p_err(branches, link_model_id, link_params, rates)
        p3 = p[:, :, None]

        # expected_block_time under stop-and-wait ARQ, batched
        raw = g / rate + union_no[:, None, None]                   # (S, R, G)
        dur = raw / (1.0 - p3)
        if exact_arq:
            infl = _ge_exact_arq_inflation(link_params, rates)     # (S, R)
            exact = ((link_model_id == _GE_MODEL_ID)
                     & (link_params[:, 1] != link_params[:, 2]))
            dur = jnp.where(exact[:, None, None],
                            raw * infl[:, :, None], dur)
        n_o_eff = dur - g

        vals = value_fn(
            g, N[:, None, None].astype(T.dtype), T[:, None, None],
            n_o_eff, tau_p[:, None, None], sigma, e0, contraction)

        return _reduce_joint_argmin(vals, n_o_eff, p, N, T, rates,
                                    rate_mask, grid)

    @partial(jax.jit, static_argnames=("stride", "width"))
    def _solve_windows(N, T, union_no, tau_p, rates, rate_mask, grid,
                       link_model_id, link_params, sigma, e0, contraction,
                       centers, tail_start, *, stride, width):
        S, G = grid.shape
        # jnp mirror of repro.core.planner.refine_window_bounds (+ the
        # refine_grid padding rule): integer ops, so both paths agree
        # exactly and refine_grid stays the testable numpy reference
        lo = jnp.maximum(centers - stride, 0)                      # (S, R)
        hi = jnp.minimum(centers + stride, G - 1)
        t = jnp.clip(tail_start, 0, G)[:, None]
        t = jnp.broadcast_to(t, centers.shape)
        single = t <= hi + 1
        lo = jnp.where(single, jnp.minimum(lo, t), lo)
        hi2 = jnp.where(single, G - 1, hi)
        t2 = jnp.where(single, G, t)
        len1 = hi2 - lo + 1
        j = jnp.arange(width)
        pad = jnp.where(t2 < G, G - 1, hi2)
        win = lo[..., None] + j
        win = win + (t2 - lo - len1)[..., None] * (j >= len1[..., None])
        win = jnp.minimum(win, pad[..., None])                     # (S, R, W)
        win_grid = grid[jnp.arange(S)[:, None, None], win]
        return _core(N, T, union_no, tau_p, rates, rate_mask, win_grid,
                     link_model_id, link_params, sigma, e0, contraction)

    return jax.jit(_core), _solve_windows


@lru_cache(maxsize=16)
def _grid_solve_for(link_version: int, value_fn, exact_arq: bool):
    """Jitted grid solves for the CURRENT link-kernel table; keyed on the
    registry version so later link plugins get their own trace.  Bounded:
    stale versions' compiled programs are evicted rather than retained
    for the life of a long-running server."""
    del link_version  # cache key only
    return _build_grid_solve(kernel_table(), value_fn, exact_arq)


def grid_objective_builder(value_fn, exact_arq: bool = False) -> Callable:
    """Kernel builder for any objective of the form ``vals = f(grid,
    scenario params, effective overhead)`` — enough for most plugins.

    ``value_fn(g, N, T, n_o_eff, tau_p, sigma, e0, contraction)`` receives
    ``(S, R, G)``-broadcast jnp arrays (plus the three bound-constant
    scalars) and returns the ``(S, R, G)`` objective values to minimise.

    The built solve advertises ``supports_refine_windows``: the planner's
    coarse->fine fine pass then ships only ``(centers, tail_start)`` plus
    the static ``(refine_stride, refine_width)`` and the windows are
    gathered on device.
    """

    def build(objective):
        def solve(arrays, consts, shard, batch):
            dense_fn, win_fn = _grid_solve_for(kernel_table_version(),
                                               value_fn, exact_arq)
            arrays = dict(arrays)
            stride = arrays.pop("refine_stride", None)
            width = arrays.pop("refine_width", None)
            S = arrays["N"].shape[0]
            with enable_x64():
                if shard:
                    arrays = _maybe_shard(arrays, S)
                # device/host attribution: the fence makes the jitted
                # call's duration the device portion, asarray the host's
                t0 = time.perf_counter()
                if stride is None:
                    out = dense_fn(sigma=consts.variance_floor,
                                   e0=consts.init_gap,
                                   contraction=consts.contraction, **arrays)
                else:
                    out = win_fn(sigma=consts.variance_floor,
                                 e0=consts.init_gap,
                                 contraction=consts.contraction,
                                 stride=stride, width=width, **arrays)
                jax.block_until_ready(out)
                t1 = time.perf_counter()
                res = {k: np.asarray(v) for k, v in out.items()}
                record_solve(t1 - t0, time.perf_counter() - t1)
                return res
        solve.supports_refine_windows = True
        return solve

    return build


# ---------------------------------------------------------------------------
# Monte-Carlo objective: the empirical ridge loss, simulated in-batch
# ---------------------------------------------------------------------------


def pow2ceil(n: int) -> int:
    """Smallest power of two >= n — the shared padding rule that bounds
    how many compiled shapes (batch lengths, scan lengths) can exist."""
    p = 1
    while p < n:
        p *= 2
    return p


#: slab length of the table-driven Monte-Carlo engines (CRN scan and
#: pallas): the update timeline is processed in slabs of this many slots,
#: each slab's (slab, L) index/mask tables computed in one vectorised
#: shot so the inner per-slot loop is pure f32 training math.  A power of
#: two, so it always divides the pow2-padded ``max_updates``.  256 keeps
#: a slab's (slab, L) tables inside L2 at serving lane counts and
#: benches a few percent faster than 512/1024 on one CPU core; the slab
#: size only partitions the timeline, so plans are bitwise-invariant
#: to it.
MC_SLAB = 256


@lru_cache(maxsize=8)
def _mc_solve_for(objective, link_version: int, interpret: bool):
    """Jitted Monte-Carlo solve for one objective instance (its data and
    hyperparameters — including ``crn`` and ``seed_stream`` — are
    compile-time constants) and link-table version.  ``interpret`` runs
    the pallas engine through the Pallas interpreter (the CPU path)."""
    del link_version  # cache key only
    branches = kernel_table()
    # float32 mirrors the scalar path, which runs OUTSIDE enable_x64 and
    # downcasts the host float64 data on jnp.asarray
    X = jnp.asarray(np.asarray(objective.X, np.float32))
    y = jnp.asarray(np.asarray(objective.y, np.float32))
    n, d = X.shape
    lam = float(objective.lam)
    alpha = float(objective.alpha)
    n_runs = int(objective.n_runs)
    seed0 = int(objective.seed)
    crn = bool(getattr(objective, "crn", False))
    seed_stream = str(getattr(objective, "seed_stream", "legacy"))

    def run_key(r):
        # per-run key derivation, mirroring repro.core.pipeline.mc_run_key
        # (inlined: this runs under jit/vmap with a traced r)
        if seed_stream == "legacy":
            return jax.random.PRNGKey(seed0 + 97 * r)
        return jax.random.fold_in(jax.random.PRNGKey(seed0), r)

    @partial(jax.jit, static_argnames=("max_updates", "shard_lanes",
                                       "mc_impl", "mc_seeds"))
    def _solve(N, T, union_no, tau_p, rates, rate_mask, grid,
               link_model_id, link_params, *, max_updates,
               shard_lanes=False, mc_impl="scan", mc_seeds=None):
        runs = int(mc_seeds) if mc_seeds else n_runs
        record_trace(("montecarlo", mc_impl, crn, runs)
                     + tuple(grid.shape) + (max_updates,))
        S, R = rates.shape
        G = grid.shape[-1]
        rate = rates[:, :, None]
        gi = grid[:, None, :] if grid.ndim == 2 else grid      # (S, R?, G)
        g = gi.astype(T.dtype)

        p = _switch_p_err(branches, link_model_id, link_params, rates)
        raw = g / rate + union_no[:, None, None]
        dur = raw / (1.0 - p[:, :, None])                      # (S, R, G) f64
        n_o_eff = dur - g
        # the scalar path rebuilds the block duration as n_c + n_o_eff
        # (NOT the raw dur) — replicate so the f64 timeline is bitwise
        dur_sched = g + n_o_eff

        # one simulation lane per (scenario, rate, grid point); the lane
        # axis is scenario-major, so laying it out over the "fleet" mesh
        # agrees with _maybe_shard's scenario-axis placement of the inputs
        lane_nc = jnp.broadcast_to(gi, (S, R, G)).reshape(-1)
        lane_dur = dur_sched.reshape(-1)
        lane_tau = jnp.broadcast_to(tau_p[:, None, None], (S, R, G)).reshape(-1)
        lane_total = jnp.broadcast_to(
            jnp.floor(T / tau_p)[:, None, None], (S, R, G)).reshape(-1)
        L = lane_nc.shape[0]
        if shard_lanes:
            mesh = Mesh(np.asarray(jax.local_devices()), ("fleet",))
            lanes = NamedSharding(mesh, P("fleet"))
            constrain = partial(jax.lax.with_sharding_constraint,
                                shardings=lanes)
            lane_nc, lane_dur, lane_tau, lane_total = (
                constrain(lane_nc), constrain(lane_dur),
                constrain(lane_tau), constrain(lane_total))

        slab = min(MC_SLAB, max_updates)
        nslab = max_updates // slab
        c_reg = jnp.float32(2.0 * alpha * lam / n)
        c_2a = jnp.float32(-2.0 * alpha)

        def avail_at(j):
            # samples available at update slot j (f64, mirrors the
            # host-side BlockSchedule.updates_timeline bit-for-bit);
            # j may be a scalar slot or a (slab,) slot vector
            jf = j.astype(lane_dur.dtype)
            t = (jf[:, None] * lane_tau[None, :] if j.ndim
                 else jf * lane_tau)
            blocks = jnp.floor(t / lane_dur).astype(jnp.int64)
            a = jnp.minimum(blocks * lane_nc, n)
            live = (jf[:, None] if j.ndim else jf) < lane_total
            return jnp.where(live, a, 0).astype(jnp.int32)

        def crn_tables(j0, u_s):
            # the whole slab's timeline in one vectorised shot: the f64
            # slot->availability map needs no carried state, so it runs
            # OUTSIDE the per-slot loop and the loop body stays pure f32.
            a = avail_at(j0 + jnp.arange(slab))                # (slab, L)
            af = a.astype(jnp.float32)
            # common random numbers: ONE shared uniform per slot across
            # every lane; the comonotone floor(u * a) sample index keeps
            # neighbouring grid points on maximally-correlated paths
            ix = jnp.minimum((u_s[:, None] * af).astype(jnp.int32),
                             jnp.maximum(a - 1, 0))
            return ix, (a > 0).astype(jnp.float32)

        def exact_tables(k, j0):
            # exact per-slot RNG: one split + one vmapped randint per
            # slot, consuming the key stream exactly like the reference
            # scan engine (and the scalar planner) do
            def tstep(k, j):
                k, sub = jax.random.split(k)
                a = avail_at(j)
                idx = jax.vmap(
                    lambda b: jax.random.randint(sub, (), 0, b,
                                                 dtype=jnp.int32)
                )(jnp.maximum(a, 1))
                return k, (idx, (a > 0).astype(jnp.float32))
            return jax.lax.scan(tstep, k, j0 + jnp.arange(slab))

        def scan_slab(W, Xs, ys, ix, m):
            # the CRN scan engine's inner loop: affine-fused update, no
            # RNG, no f64 — einsum keeps the lane dot bitwise-identical
            # to the exact engine's vmapped jnp.dot (and to the pallas
            # kernel's interpret-mode dot)
            def inner(W, row):
                ixr, mr = row
                xr = Xs[ixr]
                yr = ys[ixr]
                dot = jnp.einsum("ld,ld->l", W, xr)
                c1 = 1.0 - mr * c_reg
                c2 = mr * c_2a * (dot - yr)
                return W * c1[:, None] + xr * c2[:, None], None
            W, _ = jax.lax.scan(inner, W, (ix, m), unroll=2)
            return W

        def pallas_slab(W, Xs, ys, ix, m):
            from repro.kernels.mc_ridge import mc_ridge_slab
            return mc_ridge_slab(W, Xs, ys, ix, m, alpha=alpha, lam=lam,
                                 fused=crn, interpret=interpret)

        def per_run_exact_scan(r):
            # the reference engine: per-slot split + randint INSIDE the
            # scan, vmapped ridge_grad_sample update — op-for-op the
            # scalar planner's stream, kept as the pinned escape hatch
            key = run_key(r)
            kp, kw, ks = jax.random.split(key, 3)
            perm = jax.random.permutation(kp, n)
            Xs, ys = X[perm], y[perm]
            w0 = jax.random.normal(kw, (d,), jnp.float32)
            W0 = jnp.broadcast_to(w0, (L, d))

            def step(carry, j):
                W, k = carry
                k, sub = jax.random.split(k)
                a = avail_at(j)
                # same key for every lane: the scalar path consumes ONE
                # split per update slot whatever the grid point
                idx = jax.vmap(
                    lambda b: jax.random.randint(sub, (), 0, b,
                                                 dtype=jnp.int32)
                )(jnp.maximum(a, 1))
                grads = jax.vmap(ridge_grad_sample,
                                 (0, 0, 0, None, None))(W, Xs[idx], ys[idx],
                                                        lam, n)
                W_new = W - alpha * grads
                W = jnp.where((a > 0)[:, None], W_new, W)
                return (W, k), None

            (W_fin, _), _ = jax.lax.scan(step, (W0, ks),
                                         jnp.arange(max_updates))
            return jax.vmap(lambda w: ridge_loss_full(w, X, y, lam))(W_fin)

        def per_run_slabbed(r):
            # table-driven engines: outer scan over slabs; each slab's
            # (slab, L) tables feed either the lean jnp inner scan or
            # one pallas_call — both consume IDENTICAL tables, so the
            # two engines agree bitwise
            key = run_key(r)
            kp, kw, ks = jax.random.split(key, 3)
            perm = jax.random.permutation(kp, n)
            Xs, ys = X[perm], y[perm]
            w0 = jax.random.normal(kw, (d,), jnp.float32)
            W0 = jnp.broadcast_to(w0, (L, d))
            run_slab = pallas_slab if mc_impl == "pallas" else scan_slab

            if crn:
                u = jax.random.uniform(ks, (max_updates,),
                                       jnp.float32).reshape(nslab, slab)

                def outer(W, inp):
                    s, u_s = inp
                    ix, m = crn_tables(s * slab, u_s)
                    return run_slab(W, Xs, ys, ix, m), None

                W_fin, _ = jax.lax.scan(outer, W0,
                                        (jnp.arange(nslab), u))
            else:
                def outer(carry, s):
                    W, k = carry
                    k, (ix, m) = exact_tables(k, s * slab)
                    return (run_slab(W, Xs, ys, ix, m), k), None

                (W_fin, _), _ = jax.lax.scan(outer, (W0, ks),
                                             jnp.arange(nslab))
            return jax.vmap(lambda w: ridge_loss_full(w, X, y, lam))(W_fin)

        def crn_scan_all_runs():
            # CRN scan engine, all runs in ONE pass over slabs: the f64
            # slot->availability tables depend only on the timeline (not
            # the run), so they are computed ONCE per slab and shared by
            # every run — the per-run work is just the f32 sample-index
            # map and the training scan.  Values are bitwise those of
            # the run-at-a-time form: same tables, same per-run streams,
            # same vmapped scan body.
            def prep(r):
                key = run_key(r)
                kp, kw, ks = jax.random.split(key, 3)
                perm = jax.random.permutation(kp, n)
                w0 = jax.random.normal(kw, (d,), jnp.float32)
                u = jax.random.uniform(ks, (max_updates,), jnp.float32)
                return (X[perm], y[perm], jnp.broadcast_to(w0, (L, d)),
                        u.reshape(nslab, slab))

            Xs_a, ys_a, W0_a, u_a = jax.vmap(prep)(jnp.arange(runs))

            def outer(W_a, inp):
                s, u_s = inp                           # u_s: (runs, slab)
                a = avail_at(s * slab + jnp.arange(slab))    # (slab, L)
                af = a.astype(jnp.float32)
                hi = jnp.maximum(a - 1, 0)
                m = (a > 0).astype(jnp.float32)

                def one(W, Xs, ys, u_r):
                    ix = jnp.minimum((u_r[:, None] * af).astype(jnp.int32),
                                     hi)
                    return scan_slab(W, Xs, ys, ix, m)

                return jax.vmap(one)(W_a, Xs_a, ys_a, u_s), None

            W_fin, _ = jax.lax.scan(outer, W0_a,
                                    (jnp.arange(nslab),
                                     jnp.moveaxis(u_a, 1, 0)))
            return jax.vmap(jax.vmap(
                lambda w: ridge_loss_full(w, X, y, lam)))(W_fin)

        if mc_impl == "pallas":
            # python loop over runs: vmapping a pallas_call would batch
            # the kernel grid; runs are few, so unrolled calls are fine
            losses = jnp.stack([per_run_slabbed(r) for r in range(runs)])
        elif crn:
            losses = crn_scan_all_runs()
        else:
            losses = jax.vmap(per_run_exact_scan)(jnp.arange(runs))
        vals = jnp.mean(losses, axis=0).astype(T.dtype).reshape(S, R, G)

        return _reduce_joint_argmin(vals, n_o_eff, p, N, T, rates,
                                    rate_mask, grid)

    return _solve


def montecarlo_builder(objective) -> Callable:
    """Kernel builder for ``MonteCarloObjective``: pads the shared update
    timeline to the next power of two over the batch (masked slots no-op,
    so plans are unaffected) to bound how many scan lengths can compile.

    Sharded like the grid solves: the batch arrays are laid out over the
    local devices' "fleet" mesh on the scenario axis via ``_maybe_shard``,
    and the kernel constrains its flattened scenario-major ``(S * R * G)``
    simulation-lane axis to the same mesh, so every device simulates its
    own scenarios' lanes.  Requires both ``S`` and the lane count to
    divide the device count; otherwise the solve runs unsharded (single
    device is the common case and is bitwise-unchanged by this path).
    """

    def solve(arrays, consts, shard, batch):
        del consts  # empirical objective
        arrays = dict(arrays)
        # host-side planner hints, popped before the arrays ship to the
        # device: the simulation engine, the coarse-pass seed count and
        # the coarse-pass horizon cap
        mc_impl = arrays.pop("mc_impl", "scan")
        mc_seeds = arrays.pop("mc_seeds", None)
        mc_updates = arrays.pop("mc_updates", None)
        # the pallas engine runs interpreted off-TPU (CPU CI included)
        fn = _mc_solve_for(objective, kernel_table_version(),
                           jax.default_backend() != "tpu")
        # the objective's min_updates floor pins the padded scan length
        # for serving: every batch below the floor shares ONE shape
        # (padded slots no-op, so plans are unaffected)
        max_updates = pow2ceil(max(1, batch.max_updates,
                                   int(getattr(objective, "min_updates",
                                               0) or 0)))
        if mc_updates:
            # truncated horizon (coarse-pass hint): train each lane for
            # at most this many update slots.  The CRN slot stream is
            # counter-based, so the truncated timeline is a bitwise
            # PREFIX of the full-horizon simulation.
            max_updates = min(max_updates,
                              pow2ceil(max(1, int(mc_updates))))
        S = arrays["N"].shape[0]
        n_dev = len(jax.local_devices())
        lanes = S * arrays["rates"].shape[1] * arrays["grid"].shape[-1]
        shard = bool(shard) and n_dev > 1 and S % n_dev == 0 \
            and lanes % n_dev == 0
        with enable_x64():
            if shard:
                arrays = _maybe_shard(arrays, S)
            t0 = time.perf_counter()
            out = fn(max_updates=max_updates, shard_lanes=shard,
                     mc_impl=str(mc_impl),
                     mc_seeds=None if mc_seeds is None else int(mc_seeds),
                     **arrays)
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            res = {k: np.asarray(v) for k, v in out.items()}
            record_solve(t1 - t0, time.perf_counter() - t1)
            return res

    solve.supports_mc_impl = True
    return solve


register_objective_kernel("corollary1",
                          grid_objective_builder(_corollary1_values))
register_objective_kernel("markov_arq",
                          grid_objective_builder(_corollary1_values,
                                                 exact_arq=True))
register_objective_kernel("montecarlo", montecarlo_builder)
