"""Quantised-key LRU cache of fleet plan records.

Real request streams are heavy-tailed: the same handful of device classes
(same ``N``/deadline/link quality up to measurement noise) show up over and
over.  :class:`PlanCache` keys a scenario by its parameters rounded to a
few SIGNIFICANT digits, so near-identical requests collapse onto one entry
and skip the solve entirely — the planner only batches the misses.

Quantisation is deliberately on the KEY only: the cached record is the
exact plan of the first scenario that produced the key, which is within
grid resolution of optimal for every scenario in the same bucket.

A plan is only reusable under the SAME planning configuration, so every
cache operation also takes a hashable ``context`` — the planner passes
``(consts, grid_size, grid_mode)``, so dense and coarse->fine refined
entries can never alias even when their plans coincide — plus the
planning ``objective``, whose ``cache_token()`` (stable id + every
optimum-relevant hyperparameter, e.g. the Monte-Carlo seed count and
data digest) is folded into the key.  Entries therefore never leak
across bound constants, grid resolutions, grid modes, or OBJECTIVES
sharing one cache: a Corollary-1 plan can never answer a Monte-Carlo
request, nor a refined plan a dense calibration request, for the same
scenario.
"""
from __future__ import annotations

import math
import threading
import zlib
from collections import OrderedDict
from typing import Callable, Dict, Hashable, Optional, Tuple

from repro.core.links import link_spec_for
from repro.core.scenario import Scenario


def objective_token(objective) -> Tuple:
    """The objective's contribution to the cache key: its declared
    ``cache_token()``, or ``()`` for ``None`` (objective-agnostic use).
    Objectives without a ``cache_token`` raise — a silent fallback could
    alias two objectives' plans onto one entry."""
    if objective is None:
        return ()
    token = getattr(objective, "cache_token", None)
    if not callable(token):
        raise TypeError(
            f"{type(objective).__name__} declares no cache_token(); "
            "planning objectives must expose their cache signature (see "
            "repro.core.objectives.Objective)")
    return tuple(token())


def quantise(x: float, sig_digits: int = 3) -> float:
    """Round ``x`` to ``sig_digits`` significant digits (0 stays 0)."""
    if x == 0.0 or not math.isfinite(x):
        return float(x)
    return round(x, sig_digits - 1 - math.floor(math.log10(abs(x))))


def scenario_key(scenario: Scenario, sig_digits: int = 3) -> Tuple:
    """Hashable quantised signature of a scenario's planning inputs.

    The link enters through the registry as ``(model_id, *params)`` —
    quantised like every other float — so near-identical requests collapse
    while requests from DIFFERENT channel families (or the same family
    with different physics) can never alias, whatever mix the request
    stream carries.  Unregistered link models raise ``KeyError``: a
    name-based fallback could silently serve one plugin's plan to another.
    """
    link = scenario.link
    spec = link_spec_for(link)
    link_sig = (spec.model_id,) + tuple(
        quantise(float(v), sig_digits) for v in link.pack_params())
    return (
        int(scenario.N),
        int(scenario.n_devices),
        quantise(scenario.T, sig_digits),
        quantise(scenario.n_o, sig_digits),
        quantise(scenario.tau_p, sig_digits),
        link_sig,
        tuple(quantise(r, sig_digits) for r in link.rates),
    )


def _objective_label(objective) -> str:
    """Stats-counter label for an objective: its registry id, or
    ``"default"`` for ``None`` (the planner's default objective)."""
    if objective is None:
        return "default"
    return str(getattr(objective, "objective_id", type(objective).__name__))


class PlanCache:
    """LRU map ``(context, scenario_key) -> PlanRecord`` with hit/miss
    accounting.  ``context`` is any hashable describing the planning
    configuration the record is valid under (constants, grid width);
    records from one configuration are invisible to another.

    Observability (what a serving stats layer reports): lifetime ``hits``
    / ``misses`` totals, the same split PER OBJECTIVE id
    (``hits_by_objective`` / ``misses_by_objective``), ``evictions``
    (LRU pressure) and ``invalidations`` (entries dropped by
    :meth:`invalidate`, e.g. on link-drift re-planning), plus the live
    entry count ``size``.  All operations take an internal lock, so one
    cache can back concurrent serving workers.
    """

    def __init__(self, maxsize: int = 4096, sig_digits: int = 3, *,
                 checksums: bool = False,
                 corruptor: Optional[Callable[[], bool]] = None):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.sig_digits = sig_digits
        # With checksums on, entries are stored as [record, crc32] and
        # verified on every counted read; a mismatch drops the entry
        # (counted in ``corruptions``) and reads as a miss, so a
        # corrupted plan is re-solved, never served.  ``corruptor`` is a
        # fault-injection hook: when it returns True on a read, the
        # stored checksum is flipped first — the detection path is what
        # chaos runs exercise, not the (deterministic) store itself.
        self.checksums = bool(checksums) or corruptor is not None
        self._corruptor = corruptor
        self._store: "OrderedDict[Hashable, object]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.corruptions = 0
        self.hits_by_objective: Dict[str, int] = {}
        self.misses_by_objective: Dict[str, int] = {}

    @staticmethod
    def _checksum(record) -> int:
        return zlib.crc32(repr(record).encode())

    def _wrap(self, record):
        if self.checksums:
            return [record, self._checksum(record)]
        return record

    def _load(self, k: Hashable, *, draw_corruption: bool):
        """Entry lookup + checksum verification (lock held by caller).
        Returns the record, or None for absent/corrupted (corrupted
        entries are dropped and counted)."""
        entry = self._store.get(k)
        if entry is None:
            return None
        if not self.checksums:
            return entry
        record, stored = entry
        if (draw_corruption and self._corruptor is not None
                and self._corruptor()):
            entry[1] = stored = stored ^ 0xA5A5A5A5
        if self._checksum(record) != stored:
            del self._store[k]
            self.corruptions += 1
            return None
        return record

    def key(self, scenario: Scenario, context: Hashable = (),
            objective=None) -> Tuple:
        return (context, objective_token(objective),
                scenario_key(scenario, self.sig_digits))

    def get(self, scenario: Scenario, context: Hashable = (),
            objective=None):
        """Cached record for this (quantised) scenario, or None (counted)."""
        k = self.key(scenario, context, objective)
        label = _objective_label(objective)
        with self._lock:
            rec = self._load(k, draw_corruption=True)
            if rec is None:
                self.misses += 1
                self.misses_by_objective[label] = \
                    self.misses_by_objective.get(label, 0) + 1
                return None
            self._store.move_to_end(k)
            self.hits += 1
            self.hits_by_objective[label] = \
                self.hits_by_objective.get(label, 0) + 1
            return rec

    def peek(self, scenario: Scenario, context: Hashable = (),
             objective=None):
        """Passive lookup: no hit/miss counting, no LRU promotion, no
        corruption draw (checksums are still verified — a corrupted
        entry reads as absent).  The degradation ladder's "cached" rung
        uses this so re-serving an old plan under deadline pressure
        doesn't skew the cache's hit-rate telemetry."""
        k = self.key(scenario, context, objective)
        with self._lock:
            return self._load(k, draw_corruption=False)

    def put(self, scenario: Scenario, record,
            context: Hashable = (), objective=None) -> None:
        k = self.key(scenario, context, objective)
        with self._lock:
            self._store[k] = self._wrap(record)
            self._store.move_to_end(k)
            while len(self._store) > self.maxsize:
                self._store.popitem(last=False)
                self.evictions += 1

    def get_by_key(self, key: Hashable, label: str = "default"):
        """Cached record under a caller-built raw key (counted under
        ``label`` in the per-objective stats), or ``None``.

        The escape hatch for workloads whose request is NOT one scenario
        — the federated round path keys on ``(round context,
        FEDERATED_TOKEN, population_key(...))``.  Raw keys share the LRU
        with scenario entries but can never collide with them: a
        scenario key's last element is a tuple of quantised scalars,
        a population key's a tuple of whole scenario signatures (and the
        federated token differs from every ``Objective.cache_token()``).
        """
        with self._lock:
            rec = self._load(key, draw_corruption=True)
            if rec is None:
                self.misses += 1
                self.misses_by_objective[label] = \
                    self.misses_by_objective.get(label, 0) + 1
                return None
            self._store.move_to_end(key)
            self.hits += 1
            self.hits_by_objective[label] = \
                self.hits_by_objective.get(label, 0) + 1
            return rec

    def put_by_key(self, key: Hashable, record) -> None:
        """Store a record under a caller-built raw key (see
        :meth:`get_by_key`)."""
        with self._lock:
            self._store[key] = self._wrap(record)
            self._store.move_to_end(key)
            while len(self._store) > self.maxsize:
                self._store.popitem(last=False)
                self.evictions += 1

    def invalidate(self, scenario: Scenario, context: Hashable = (),
                   objective=None) -> bool:
        """Drop the entry for this (quantised) scenario under ``context``
        and ``objective``, returning whether one existed.  The serving
        layer calls this when a session's OBSERVED link quality drifts
        from what the cached plan assumed: the prefix-keyed entry —
        ``(context, objective_token, scenario_key)`` — is removed so the
        re-enqueued scenario (and every other session collapsing onto the
        same quantised key) re-plans instead of replaying a stale answer.
        Neither a hit nor a miss is counted; ``invalidations`` is."""
        k = self.key(scenario, context, objective)
        with self._lock:
            if self._store.pop(k, None) is None:
                return False
            self.invalidations += 1
            return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    @property
    def size(self) -> int:
        """Live entry count (alias of ``len``, for stats reporting)."""
        return len(self)

    def __contains__(self, scenario: Scenario) -> bool:
        sig = scenario_key(scenario, self.sig_digits)
        with self._lock:
            return any(k[-1] == sig for k in self._store)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        """Consistent snapshot of every counter (one lock acquisition)."""
        with self._lock:
            return {
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "size": len(self._store),
                "maxsize": self.maxsize, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "corruptions": self.corruptions,
                "hits_by_objective": dict(self.hits_by_objective),
                "misses_by_objective": dict(self.misses_by_objective),
            }

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.invalidations = 0
            self.corruptions = 0
            self.hits_by_objective = {}
            self.misses_by_objective = {}
