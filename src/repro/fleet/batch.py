"""Struct-of-arrays batching of heterogeneous :class:`~repro.core.scenario.Scenario`s.

The fleet planner's unit of work is a :class:`ScenarioBatch`: every scalar
field of the PR-1 ``Scenario`` stacked into a ``(S,)`` array, the link
layer flattened through the pluggable registry
(:mod:`repro.core.links`) into a per-scenario ``link_model_id`` vector
plus a right-padded ``(S, MAX_LINK_PARAMS)`` parameter table, and the
candidate rates into a padded ``(S, R)`` matrix.  Padding keeps the batch
rectangular — the shape invariance ``jit``/``vmap`` need — and
``rate_mask`` marks which columns are real candidates (padded columns
repeat the last real rate and are masked out of the argmin with ``+inf``).

Any REGISTERED link model batches without touching this module: the table
row is ``link.pack_params()`` and reconstruction goes through
``spec.cls.from_params`` — one batch can mix every channel family and the
jitted fleet kernel dispatches per scenario via ``jax.lax.switch``.

``from_scenarios`` / ``__getitem__`` round-trip losslessly, with one
documented normalisation: a ``MultiDevice(1)`` topology comes back as the
equivalent ``SingleDevice()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.links import MAX_LINK_PARAMS, link_spec, link_spec_for
from repro.core.scenario import (MultiDevice, Scenario, SingleDevice)


@dataclass(frozen=True)
class ScenarioBatch:
    """Stacked scenario parameters; all arrays share leading dim ``S``."""

    N: np.ndarray              # (S,) int64   total samples
    T: np.ndarray              # (S,) float64 deadline
    n_o: np.ndarray            # (S,) float64 per-device per-block overhead
    tau_p: np.ndarray          # (S,) float64 time per SGD update
    n_devices: np.ndarray      # (S,) int64   TDMA device count
    link_model_id: np.ndarray  # (S,) int32   registry id of the link class
    link_params: np.ndarray    # (S, MAX_LINK_PARAMS) float64 packed params
    rates: np.ndarray          # (S, R) float64 candidate rates, right-padded
    rate_mask: np.ndarray      # (S, R) bool   True where the candidate is real

    def __post_init__(self):
        S = self.N.shape[0]
        for name in ("T", "n_o", "tau_p", "n_devices", "link_model_id"):
            arr = getattr(self, name)
            if arr.shape != (S,):
                raise ValueError(f"{name} has shape {arr.shape}, want ({S},)")
        if self.link_params.shape != (S, MAX_LINK_PARAMS):
            raise ValueError(
                f"link_params has shape {self.link_params.shape}, want "
                f"({S}, {MAX_LINK_PARAMS})")
        if self.rates.ndim != 2 or self.rates.shape[0] != S:
            raise ValueError(f"rates has shape {self.rates.shape}")
        if self.rate_mask.shape != self.rates.shape:
            raise ValueError("rate_mask/rates shape mismatch")
        if not self.rate_mask[:, 0].all():
            raise ValueError("every scenario needs >= 1 valid rate")

    def __len__(self) -> int:
        return int(self.N.shape[0])

    @property
    def n_rates(self) -> int:
        """Padded width R of the candidate-rate matrix."""
        return int(self.rates.shape[1])

    @property
    def union_overhead(self) -> np.ndarray:
        """(S,) per-union-block overhead after the TDMA reduction."""
        return self.n_devices.astype(np.float64) * self.n_o

    @property
    def max_updates(self) -> int:
        """Largest per-scenario update-slot count ``floor(T / tau_p)`` in
        the batch — the static scan length the batched Monte-Carlo
        objective kernel pads its shared simulation timeline to."""
        return int(np.max(np.floor(self.T / self.tau_p)))

    @classmethod
    def from_scenarios(cls, scenarios: Sequence[Scenario]) -> "ScenarioBatch":
        if len(scenarios) == 0:
            raise ValueError("empty scenario list")
        R = max(len(sc.link.rates) for sc in scenarios)
        S = len(scenarios)
        rates = np.ones((S, R), np.float64)
        mask = np.zeros((S, R), bool)
        model_id = np.zeros(S, np.int32)
        params = np.zeros((S, MAX_LINK_PARAMS), np.float64)
        for i, sc in enumerate(scenarios):
            try:
                spec = link_spec_for(sc.link)
            except KeyError as e:
                raise TypeError(f"scenario {i}: {e.args[0]}") from None
            r = np.asarray(sc.link.rates, np.float64)
            rates[i, :r.size] = r
            rates[i, r.size:] = r[-1]          # pad: repeat last real rate
            mask[i, :r.size] = True
            model_id[i] = spec.model_id
            pv = np.asarray(sc.link.pack_params(), np.float64)
            if pv.shape != (spec.n_params,):
                raise ValueError(
                    f"scenario {i}: {spec.name}.pack_params() returned shape "
                    f"{pv.shape}, spec declares ({spec.n_params},)")
            params[i, :spec.n_params] = pv
        return cls(
            N=np.asarray([sc.N for sc in scenarios], np.int64),
            T=np.asarray([sc.T for sc in scenarios], np.float64),
            n_o=np.asarray([sc.n_o for sc in scenarios], np.float64),
            tau_p=np.asarray([sc.tau_p for sc in scenarios], np.float64),
            n_devices=np.asarray([sc.n_devices for sc in scenarios], np.int64),
            link_model_id=model_id, link_params=params,
            rates=rates, rate_mask=mask)

    def __getitem__(self, i: int) -> Scenario:
        """Reconstruct the i-th :class:`Scenario` (inverse of from_scenarios)."""
        i = int(i)
        rates = tuple(float(r) for r in self.rates[i][self.rate_mask[i]])
        spec = link_spec(int(self.link_model_id[i]))
        link = spec.cls.from_params(self.link_params[i, :spec.n_params],
                                    rates=rates)
        D = int(self.n_devices[i])
        topology = MultiDevice(D) if D > 1 else SingleDevice()
        return Scenario(N=int(self.N[i]), T=float(self.T[i]),
                        n_o=float(self.n_o[i]), tau_p=float(self.tau_p[i]),
                        link=link, topology=topology)

    def scenarios(self) -> List[Scenario]:
        return [self[i] for i in range(len(self))]
