"""Fleet planning engine: batched, sharded, cached Corollary-1 planning.

The PR-1 ``Scenario``/``Planner``/``Simulator`` triple makes ONE
device-edge pair plannable; this package makes the FLEET the unit of work:

  * :class:`~repro.fleet.batch.ScenarioBatch` — struct-of-arrays stacking
    of thousands of heterogeneous scenarios (round-trips to ``Scenario``);
  * :class:`~repro.fleet.planner.FleetPlanner` — the joint ``(rate, n_c)``
    grid for every scenario evaluated in one jitted, x64, device-sharded
    call through the ``jax.numpy`` bound port in
    :mod:`~repro.fleet.bounds_jax`;
  * :class:`~repro.fleet.cache.PlanCache` — quantised-key LRU so repeated
    or near-identical requests skip the solve;
  * ``repro.launch.plan_server`` — the micro-batching request-stream
    driver reporting plans/sec (see ``python -m repro.launch.plan_server``).
"""
from repro.fleet.batch import ScenarioBatch
from repro.fleet.bounds_jax import corollary1_bound_jax
from repro.fleet.cache import PlanCache, scenario_key
from repro.fleet.planner import FleetPlan, FleetPlanner, PlanRecord

__all__ = [
    "ScenarioBatch", "corollary1_bound_jax",
    "PlanCache", "scenario_key",
    "FleetPlan", "FleetPlanner", "PlanRecord",
]
