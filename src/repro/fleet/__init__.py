"""Fleet planning engine: batched, sharded, cached Corollary-1 planning.

The PR-1 ``Scenario``/``Planner``/``Simulator`` triple makes ONE
device-edge pair plannable; this package makes the FLEET the unit of work:

  * :class:`~repro.fleet.batch.ScenarioBatch` — struct-of-arrays stacking
    of thousands of heterogeneous scenarios (round-trips to ``Scenario``);
  * :class:`~repro.fleet.planner.FleetPlanner` — the joint ``(rate, n_c)``
    grid for every scenario evaluated in one jitted, x64, device-sharded
    call against ANY registered planning objective;
  * :mod:`~repro.fleet.link_kernels` — the jax side of the pluggable link
    registry: one ``p_err(params, rate)`` kernel per registered model,
    dispatched per scenario via ``jax.lax.switch`` so ONE compilation
    plans batches mixing every channel family;
  * :mod:`~repro.fleet.objective_kernels` — the jax side of the pluggable
    OBJECTIVE registry (:mod:`repro.core.objectives`): batched kernels
    for the Corollary-1 bound (``jax.numpy`` port in
    :mod:`~repro.fleet.bounds_jax`), the exact burst-aware Markov-ARQ
    bound, and the vmapped empirical Monte-Carlo ridge objective;
  * :class:`~repro.fleet.cache.PlanCache` — quantised-key LRU so repeated
    or near-identical requests skip the solve (keys carry the link's
    ``(model_id, params)`` signature AND the objective's cache token);
  * ``repro.launch.plan_server`` — the micro-batching request-stream
    driver reporting plans/sec (see ``python -m repro.launch.plan_server``).
"""
from repro.fleet.batch import ScenarioBatch
from repro.fleet.bounds_jax import corollary1_bound_jax
from repro.fleet.cache import PlanCache, objective_token, scenario_key
from repro.fleet.link_kernels import (kernel_table, kernel_table_version,
                                      register_link_kernel,
                                      unregister_link_kernel)
from repro.fleet.objective_kernels import (fleet_solve,
                                           grid_objective_builder,
                                           objective_kernel_version,
                                           register_objective_kernel,
                                           unregister_objective_kernel)
from repro.fleet.planner import (GRID_MODES, MC_IMPLS, FleetPlan,
                                 FleetPlanner, PlanRecord)
from repro.fleet.tracing import record_trace, trace_count, trace_events

__all__ = [
    "ScenarioBatch", "corollary1_bound_jax",
    "PlanCache", "scenario_key", "objective_token",
    "FleetPlan", "FleetPlanner", "PlanRecord", "GRID_MODES", "MC_IMPLS",
    "register_link_kernel", "unregister_link_kernel",
    "kernel_table", "kernel_table_version",
    "register_objective_kernel", "unregister_objective_kernel",
    "objective_kernel_version", "grid_objective_builder", "fleet_solve",
    "record_trace", "trace_count", "trace_events",
]
