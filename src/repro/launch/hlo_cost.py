"""While-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each ``while`` (scan) body ONCE — with
scan-over-layers models that under-reports FLOPs/bytes by orders of
magnitude.  This module parses the optimized (SPMD-partitioned, per-device)
HLO text and multiplies loop-body costs by the compiler-known trip count
(``backend_config={"known_trip_count":{"n":...}}``), recursively.

Counted per op:
  * dot: 2 * prod(result_dims) * contracted_size FLOPs, + result/operand bytes
  * fusion: result + operand bytes (HBM traffic model: every materialised
    buffer written once, read once per consumer); dots inside fused
    computations contribute FLOPs
  * all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute: ICI bytes (ring multipliers), + HBM bytes
  * while: trip_count x body + trip_count x cond
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_OP_MULTIPLIER = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\((.*?)\)\s*->")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_OPNAME_RE = re.compile(r"^\s*(?:\(|\w+\[[^\]]*\][^\s]*\s+)?([\w\-]+)\(")


def shape_info(type_str: str):
    """(total_bytes, dims_list_of_first_array) from an HLO type string."""
    total = 0
    first_dims: Optional[List[int]] = None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = d
    return total, (first_dims or [])


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    result_bytes: int
    operands: List[str]
    line: str
    called: List[str] = field(default_factory=list)
    trip: int = 1


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.coll_bytes += other.coll_bytes * times
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v * times
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * times)


_SKIP_KINDS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "iota", "partition-id", "replica-id",
    "reshape",
}


def _split_operands(line: str) -> List[str]:
    """Operand names from 'op(%a, %b, ...)' (first paren group)."""
    i = line.find("(")
    if i < 0:
        return []
    depth, j = 0, i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = line[i + 1:j]
    return re.findall(r"%[\w\.\-]+", inner)


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.shapes: Dict[str, str] = {}  # op name -> result type string
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.rstrip().endswith("{"):
                cur = hdr.group(1)
                self.comps[cur] = []
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # result type = prefix of rhs up to the op name.  Tuple types may
            # contain nested parens and /*index=N*/ comments — match parens.
            if rhs.startswith("("):
                depth = 0
                end = 0
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i + 1
                            break
                rtype = rhs[:end]
                rest = rhs[end:].lstrip()
            else:
                sp = rhs.find(" ")
                if sp < 0:
                    continue
                rtype = rhs[:sp]
                rest = rhs[sp + 1:].lstrip()
            kp = re.match(r"([\w\-]+)\(", rest)
            if not kp:
                continue
            kind = kp.group(1)
            rbytes, _ = shape_info(rtype)
            op = Op(name=name, kind=kind, result_type=rtype,
                    result_bytes=rbytes,
                    operands=_split_operands(rest[len(kind):]),
                    line=rhs)
            for c in _CALLED_RE.finditer(rhs):
                op.called.append(c.group(1))
            tm = _TRIP_RE.search(rhs)
            if tm:
                op.trip = int(tm.group(1))
            self.comps[cur].append(op)
            self.shapes[name] = rtype

    # -- cost ---------------------------------------------------------------

    def _operand_bytes(self, op: Op) -> int:
        total = 0
        for o in op.operands:
            t = self.shapes.get(o)
            # tuple-typed operands (while-carry params) are not read wholesale;
            # the get-tuple-element projections account for actual reads
            if t and not t.startswith("("):
                total += shape_info(t)[0]
        return total

    def _dot_flops(self, op: Op) -> float:
        _, rdims = shape_info(op.result_type)
        out = 1
        for d in rdims:
            out *= d
        # contracted size from lhs shape + lhs_contracting_dims
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        if not cm or not op.operands:
            return 2.0 * out  # inner dim unknown; floor
        lhs_t = self.shapes.get(op.operands[0], "")
        _, ldims = shape_info(lhs_t)
        csize = 1
        for idx in cm.group(1).split(","):
            if idx != "" and int(idx) < len(ldims):
                csize *= ldims[int(idx)]
        return 2.0 * out * csize

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total  # guard (no recursion cycles in HLO)
        for op in self.comps.get(name, []):
            k = op.kind
            if k in _SKIP_KINDS:
                continue
            if k == "while":
                body_cost = Cost()
                for c in op.called:
                    body_cost.add(self.comp_cost(c))
                total.add(body_cost, times=op.trip)
                continue
            if k in ("call", "conditional", "async-start"):
                for c in op.called:
                    total.add(self.comp_cost(c))
                total.bytes += op.result_bytes
                continue
            if k == "fusion":
                total.bytes += op.result_bytes + self._operand_bytes(op)
                for c in op.called:  # count dot flops inside fusions
                    inner = self.comp_cost(c)
                    total.flops += inner.flops
                continue
            if k == "dot":
                total.flops += self._dot_flops(op)
                total.bytes += op.result_bytes + self._operand_bytes(op)
                continue
            base = k.replace("-start", "")
            if base in _OP_MULTIPLIER and not k.endswith("-done"):
                b = op.result_bytes
                if base == "all-gather":
                    # result includes the gathered axis; ICI moves result bytes
                    pass
                w = b * _OP_MULTIPLIER[base]
                total.coll_bytes += w
                total.coll_by_op[base] = total.coll_by_op.get(base, 0.0) + w
                total.coll_count[base] = total.coll_count.get(base, 0) + 1
                total.bytes += b + self._operand_bytes(op)
                continue
            if k in ("copy", "copy-start", "transpose", "broadcast", "convert",
                     "slice", "dynamic-slice", "dynamic-update-slice", "pad",
                     "concatenate", "reduce", "sort", "scatter", "gather",
                     "select-and-scatter", "reverse", "cholesky",
                     "triangular-solve", "custom-call", "rng", "exp", "add",
                     "multiply", "subtract", "divide", "tanh", "select",
                     "maximum", "minimum", "compare", "clamp"):
                total.bytes += op.result_bytes + self._operand_bytes(op)
                continue
            # default: count result bytes
            total.bytes += op.result_bytes
        return total

    def entry_cost(self) -> Cost:
        # ENTRY computation is the one not called by any other
        called = set()
        for ops in self.comps.values():
            for op in ops:
                called.update(op.called)
        entries = [n for n in self.comps if n not in called]
        total = Cost()
        # XLA text has exactly one entry; fall back to summing roots
        for e in entries[-1:] if entries else list(self.comps)[-1:]:
            total.add(self.comp_cost(e))
        return total


def analyze_hlo(text: str) -> Cost:
    return HloModule(text).entry_cost()
