"""Always-on planning service driver: warm up, serve a mixed stream, report.

Entry point for :class:`repro.serve.PlanningService` — the long-lived
front end over the fleet planning engine.  It AOT-warms every configured
(objective, grid mode, batch bucket) executable, then feeds a synthetic
heterogeneous request stream (every registered link model, mixed
objectives and grid modes, drift-prone Gilbert-Elliott sessions) through
the continuous micro-batcher and prints the service stats: enqueue-to-
plan p50/p99, plans/sec, per-bucket compile/request counters, cache
hit/miss/invalidation counters and the post-warmup trace count (the
zero-trace SLO).

  PYTHONPATH=src python -m repro.launch.serve \
      --requests 2048 --buckets 64,256 --flush-ms 10 --grid 64 \
      --models all --objective corollary1,markov_arq --policy link_aware \
      --metrics-textfile metrics.prom --journal events.jsonl

Observability hooks: ``--metrics-textfile`` dumps the unified Prometheus
exposition (optionally every ``--metrics-interval`` seconds from a
background thread, node-exporter textfile style, plus a final dump);
``--journal`` appends every audit event (warmup, drift, session
lifecycle) to a JSONL file; ``--profile-dir`` wraps the serving stream
in a ``jax.profiler`` trace.  The final report includes the per-phase
latency breakdown (batch-wait / pad / cache-lookup / solve / resolve)
and the device-fenced solve fraction.

Unknown model/objective/grid-mode/policy names exit with code 2 (usage
error), like the other launch drivers.  The LLM decode driver that
previously lived at this path is now ``repro.launch.serve_decode``.
"""
from __future__ import annotations

import argparse
import sys
import threading
from typing import Optional, Sequence

import numpy as np

from repro.chaos import parse_chaos_spec
from repro.fleet import GRID_MODES
from repro.obs import profile_capture
from repro.serve import (ALL_MODELS, ALL_OBJECTIVES, PlanningService,
                         RequestShed, ServiceConfig, mc_update_floor,
                         parse_models, policy_spec, resolve_grid_modes,
                         resolve_objectives, synth_requests)


def _parse_buckets(spec: str):
    try:
        buckets = tuple(int(s) for s in spec.split(",") if s.strip())
    except ValueError as e:
        raise ValueError(f"bad bucket list {spec!r}: {e}") from None
    if not buckets:
        raise ValueError(f"bad bucket list {spec!r}: no buckets")
    return buckets


def run_service(args) -> int:
    """Build/warm the service, push the stream through, print stats."""
    try:
        models = parse_models(args.models)
        objective_ids = tuple(resolve_objectives(args.objective))
        grid_modes = tuple(resolve_grid_modes(args.grid_mode))
        policy_spec(args.policy)  # fail fast on a typo'd policy id
        if args.chaos_spec:
            parse_chaos_spec(args.chaos_spec)  # usage-error on a typo
        config = ServiceConfig(
            grid_size=args.grid, batch_buckets=_parse_buckets(args.buckets),
            flush_interval=args.flush_ms / 1e3, objective_ids=objective_ids,
            grid_modes=grid_modes, policy_id=args.policy,
            cache_size=args.cache_size, sig_digits=args.sig_digits,
            n_max=args.n_max, warm_models=models,
            mc_impl=args.mc_impl, mc_crn=args.mc_crn,
            mc_seed_stream=args.mc_seed_stream,
            mc_coarse_seeds=args.mc_coarse_seeds,
            mc_refine_rates=args.mc_refine_rates,
            mc_coarse_strides=(tuple(
                int(s) for s in args.mc_coarse_strides.split(","))
                if args.mc_coarse_strides else None),
            mc_fine_radius=args.mc_fine_radius,
            mc_coarse_updates=args.mc_coarse_updates,
            journal_path=args.journal,
            journal_max_bytes=args.journal_max_bytes,
            journal_keep=args.journal_keep,
            journal_fsync=args.journal_fsync,
            max_pending=args.max_pending,
            default_budget_s=(args.budget_ms / 1e3
                              if args.budget_ms > 0 else None),
            retry_attempts=args.retry_attempts,
            breaker_threshold=args.breaker_threshold,
            breaker_cooldown_s=args.breaker_cooldown_ms / 1e3,
            chaos_spec=args.chaos_spec or None)
        requests = synth_requests(args.requests, seed=args.seed,
                                  dup_frac=args.dup, models=models,
                                  n_max=args.n_max)
    except (KeyError, ValueError) as e:
        # KeyError str() wraps its message in quotes; unwrap for the CLI
        print(f"error: {e.args[0] if isinstance(e, KeyError) else e}",
              file=sys.stderr)
        return 2

    service = PlanningService(config)
    n_traces = service.warmup()
    print(f"warmup: {n_traces} kernel traces in "
          f"{service.warmup_seconds:.2f}s over "
          f"{len(service.objectives)} objective(s) x "
          f"{len(config.grid_modes)} grid mode(s) x "
          f"{len(config.batch_buckets)} bucket(s)")

    # round-robin some requests through explicit (objective, mode)
    # assignments so the stream exercises every configured pair even if
    # the admission policy wouldn't route there; the rest go through the
    # policy (objective=None) like un-annotated production traffic
    rng = np.random.default_rng(args.seed + 1)
    instances = list(service.objectives.values())

    # optional background metrics dumper: a node-exporter-style textfile
    # refreshed every --metrics-interval seconds while the stream runs
    dumper_stop = threading.Event()
    dumper = None
    if args.metrics_textfile and args.metrics_interval > 0:
        def _dump_loop():
            while not dumper_stop.wait(args.metrics_interval):
                service.metrics.write_textfile(args.metrics_textfile)
        dumper = threading.Thread(target=_dump_loop, daemon=True,
                                  name="metrics-dumper")
        dumper.start()

    try:
        with profile_capture(args.profile_dir), service:
            futures = []
            n_shed = 0
            for i, scenario in enumerate(requests):
                try:
                    if rng.random() < args.policy_frac:
                        futures.append(service.submit(scenario))
                    else:
                        obj = instances[i % len(instances)]
                        mode = config.grid_modes[i % len(config.grid_modes)]
                        futures.append(service.submit(
                            scenario, objective=obj, grid_mode=mode))
                except RequestShed:
                    n_shed += 1  # explicit overload rejection, not a bug
            records = []
            n_failed = 0
            for f in futures:
                try:
                    records.append(f.result(timeout=args.timeout))
                except Exception as e:  # noqa: BLE001 — counted, reported
                    n_failed += 1
                    print(f"request failed: {type(e).__name__}: {e}",
                          file=sys.stderr)
    finally:
        dumper_stop.set()
        if dumper is not None:
            dumper.join(timeout=5.0)
        service.journal.close()
    stats = service.stats()

    print(f"served {stats.n_planned} plans in {stats.n_batches} "
          f"micro-batches (flush <= {config.max_batch} or "
          f"{args.flush_ms:.0f} ms)")
    print(f"throughput: {stats.plans_per_sec:,.0f} plans/sec; "
          f"enqueue-to-plan latency p50={stats.latency_p50_ms:.2f} ms "
          f"p99={stats.latency_p99_ms:.2f} ms "
          f"max={stats.latency_max_ms:.2f} ms")
    post = stats.counters.get("post_warmup_traces", 0)
    print(f"post-warmup jit traces: {post} "
          f"({'SLO met' if post == 0 else 'SLO VIOLATED'})")
    res = stats.resilience
    if n_shed or n_failed or res.get("fallbacks") \
            or res.get("faults_injected") or res.get("retries"):
        import collections
        levels = collections.Counter(r.fallback for r in records)
        print(f"resilience: {n_failed} failed, {n_shed} shed, "
              f"levels {dict(levels)}; retries={res.get('retries', 0)} "
              f"backoff={res.get('backoff_seconds', 0.0):.3f}s "
              f"faults={res.get('faults_injected', {})}")
        for key, b in sorted(res.get("breakers", {}).items()):
            print(f"  breaker {key[0]}/{key[1]}: {b['state']} "
                  f"(trips={b['trips']} probes={b['probes']} "
                  f"recoveries={b['recoveries']})")
    print(f"health: {service.health().state}")
    means = service.spans.phase_means_ms()
    breakdown = " ".join(f"{name}={means[name]:.2f}"
                         for name in ("batch_wait", "pad", "cache_lookup",
                                      "solve", "resolve"))
    print(f"phase breakdown (mean ms/request): {breakdown} "
          f"| latency={means['latency']:.2f}")
    print(f"solve fraction: {stats.solve_fraction:.1%} of enqueue-to-plan "
          f"latency (device-fenced "
          f"{stats.phases.get('solve_device', 0.0):.3f}s of "
          f"{stats.phases.get('solve', 0.0):.3f}s solve)")
    for (oid, mode, bucket), slot in sorted(stats.buckets.items()):
        print(f"  bucket {oid}/{mode}/{bucket}: "
              f"{slot['requests']} requests, {slot['batches']} batches, "
              f"{slot['compiles']} compiles")
    cache = stats.cache
    print(f"cache: {cache.get('hits', 0)} hits / "
          f"{cache.get('misses', 0)} misses "
          f"(hit rate {cache.get('hit_rate', 0.0):.1%}, "
          f"{cache.get('size', 0)} entries, "
          f"{cache.get('invalidations', 0)} invalidations)")
    if records:
        sample = records[0]
        print(f"sample plan: n_c={sample.n_c} rate={sample.rate} "
              f"objective={sample.objective} "
              f"bound={sample.bound_value:.4g}")
    if args.metrics_textfile:
        service.metrics.write_textfile(args.metrics_textfile)
        print(f"metrics: wrote Prometheus textfile "
              f"{args.metrics_textfile}")
    if args.journal:
        rotated = (f" ({service.journal.rotations} rotations)"
                   if service.journal.rotations else "")
        print(f"journal: {service.journal.emitted} events appended to "
              f"{args.journal}{rotated}")
    return 0 if (post == 0 and n_failed == 0) else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--buckets", default="64,256",
                    help="comma-separated pow2 micro-batch pad shapes; the "
                         "largest is the flush size")
    ap.add_argument("--flush-ms", type=float, default=10.0,
                    help="deadline: flush when the oldest pending request "
                         "has waited this long")
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--cache-size", type=int, default=8192)
    ap.add_argument("--sig-digits", type=int, default=3)
    ap.add_argument("--dup", type=float, default=0.5,
                    help="fraction of requests hitting a known device class")
    ap.add_argument("--models", default="all",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--objective", default="corollary1,markov_arq",
                    help="comma-separated served objectives, or 'all' "
                         f"({', '.join(ALL_OBJECTIVES)}); montecarlo "
                         "warmup cost scales with --n-max")
    ap.add_argument("--grid-mode", default="all",
                    help="comma-separated served grid modes, or 'all' "
                         f"({', '.join(GRID_MODES)})")
    ap.add_argument("--policy", default="link_aware",
                    help="admission policy id for un-annotated requests")
    ap.add_argument("--policy-frac", type=float, default=0.5,
                    help="fraction of the stream routed by the admission "
                         "policy (the rest cycles through every configured "
                         "(objective, mode) pair explicitly)")
    ap.add_argument("--n-max", type=int, default=32768,
                    help="cap on drawn dataset sizes (keep small when the "
                         "mix includes the simulated montecarlo objective)")
    ap.add_argument("--mc-impl", default="auto",
                    choices=["auto", "scan", "pallas"],
                    help="Monte-Carlo simulation engine: the fused Pallas "
                         "kernel, the lax.scan reference, or auto "
                         "(pallas on TPU, scan elsewhere)")
    ap.add_argument("--mc-crn", action="store_true",
                    help="common random numbers for the Monte-Carlo "
                         "objective: share the per-slot uniform draw "
                         "across all simulation lanes (a lower-variance "
                         "estimator of the same objective; plans are not "
                         "bitwise-pinned to the reference stream)")
    ap.add_argument("--mc-seed-stream", default="fold_in",
                    choices=["fold_in", "legacy"],
                    help="per-run RNG key derivation (legacy reproduces "
                         "the historical colliding seed+97r streams)")
    ap.add_argument("--mc-coarse-seeds", type=int, default=None,
                    help="Monte-Carlo seed count for refine-mode coarse "
                         "passes (0 = bound-guided coarse pass)")
    ap.add_argument("--mc-refine-rates", type=int, default=None,
                    help="keep only the top-K rates per scenario in the "
                         "refine-mode fine pass")
    ap.add_argument("--mc-coarse-strides", default=None,
                    help="comma-separated descending multi-level stride "
                         "schedule for refine mode, e.g. '32,6'")
    ap.add_argument("--mc-fine-radius", type=int, default=None,
                    help="widen the refine-mode dense fine window to "
                         "+/- this many grid steps (decoupled from the "
                         "last coarse stride)")
    ap.add_argument("--mc-coarse-updates", type=int, default=None,
                    help="cap the simulated update horizon of refine-mode "
                         "coarse passes (the fine pass always trains the "
                         "full horizon); keep >= 2048")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-request future timeout, seconds")
    ap.add_argument("--metrics-textfile", default=None,
                    help="write the Prometheus text exposition here (final "
                         "dump always; periodic with --metrics-interval)")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="refresh --metrics-textfile every N seconds from "
                         "a background thread while serving (0 = final "
                         "dump only)")
    ap.add_argument("--journal", default=None,
                    help="append audit events (warmup, drift, session "
                         "lifecycle) to this JSONL file")
    ap.add_argument("--journal-max-bytes", type=int, default=0,
                    help="rotate the journal file at this size, keeping "
                         "--journal-keep rotated files (0 = never)")
    ap.add_argument("--journal-keep", type=int, default=3,
                    help="rotated journal files to keep")
    ap.add_argument("--journal-fsync", action="store_true",
                    help="fsync every journal event (durable crash "
                         "journal; serialises on disk latency)")
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="per-request enqueue-to-plan latency budget; "
                         "requests the service can't solve in time "
                         "degrade along the fallback ladder (0 = none)")
    ap.add_argument("--chaos-spec", default=None,
                    help="deterministic fault injection, e.g. 'seed=7,"
                         "solve_error=0.2,solve_latency=0.1:25ms,"
                         "cache_corrupt=0.05,queue_stall=0.02:10ms'")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="bound the ingestion queue; a full queue sheds "
                         "new submits explicitly (0 = unbounded)")
    ap.add_argument("--retry-attempts", type=int, default=3,
                    help="solve attempts per chunk before degrading")
    ap.add_argument("--breaker-threshold", type=int, default=5,
                    help="consecutive failures tripping a per-"
                         "(objective, grid mode) circuit breaker")
    ap.add_argument("--breaker-cooldown-ms", type=float, default=250.0,
                    help="open -> half-open probe cooldown")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the serving "
                         "stream into this directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if "montecarlo" in args.objective and args.n_max > 4096:
        # the MC scan floor is ~6 n_max slots; keep warmup tractable
        print(f"note: clamping --n-max {args.n_max} -> 2048 for the "
              f"montecarlo mix (scan floor {mc_update_floor(args.n_max)} "
              "slots is too heavy to warm)", file=sys.stderr)
        args.n_max = 2048
    return run_service(args)


if __name__ == "__main__":
    raise SystemExit(main())
