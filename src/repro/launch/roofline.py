"""Roofline-term derivation from compiled dry-run artifacts.

Three terms (seconds, per device — the SPMD-partitioned module cost
analysis is per device):

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes_accessed / HBM_bandwidth
  collective = sum(collective result bytes x op multiplier) / ICI_bandwidth

collective bytes are NOT in cost_analysis: we parse the partitioned HLO
(``compiled.as_text()``) and sum the result-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with ring-algorithm byte multipliers (all-reduce moves ~2x its buffer).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.launch.mesh import HBM_BANDWIDTH, ICI_BANDWIDTH, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# bytes moved over ICI per byte of result buffer (ring algorithms)
_OP_MULTIPLIER = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\w+\[[^\]]*\][^ ]*|\()[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in a result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def weighted_bytes(self) -> float:
        return sum(b * _OP_MULTIPLIER[o] for o, b in self.bytes_by_op.items())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if f" {op}-done" in line:
            continue  # async completion carries the same buffer
        b = _shape_bytes(shape_str)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    collective_bytes: float       # per-device weighted ICI bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float            # 6*N*D (analytic, global)
    useful_ratio: float           # model_flops / (flops * chips)
    collectives: Dict[str, int]

    def to_dict(self):
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops,
            "useful_flops_ratio": self.useful_ratio,
            "collective_breakdown": self.collectives,
        }


def analyze(cost: dict, hlo_text: str, *, n_chips: int,
            model_flops: float) -> Roofline:
    """cost: raw compiled.cost_analysis() (recorded for reference only — it
    counts while bodies once); the roofline terms come from the while-aware
    HLO analyzer (repro.launch.hlo_cost)."""
    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text)
    flops = hc.flops
    hbm = hc.bytes

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm / HBM_BANDWIDTH
    collective_s = hc.coll_bytes / ICI_BANDWIDTH
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops * n_chips
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=hc.coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        collectives={k: int(v) for k, v in hc.coll_by_op.items()},
    )


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D prefill/decode
    (N = active params, D = tokens processed this step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens
