import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
against ShapeDtypeStruct stand-ins (no allocation), record memory analysis,
cost analysis and the collective schedule for the roofline report.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out benchmarks/artifacts]
"""

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops_for
from repro.models import model as model_mod
from repro.optim.optimizers import make_optimizer


def _shardings(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_lowering(arch: str, shape_name: str, mesh, *, optimizer="adamw"):
    """Returns (lowered, n_chips, model_flops)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = cfg.supports_shape(shape)
    if not ok:
        raise SkipShape(reason)

    batch_abs = {k: v for k, v in model_mod.input_specs(cfg, shape).items()}
    batch_specs = shd.batch_specs(cfg, shape, mesh)
    params_abs = model_mod.abstract_params(cfg)
    pspecs = shd.param_specs(cfg, params_abs, mesh)

    if shape.kind == "train":
        opt = make_optimizer(optimizer, 1e-4)
        opt_abs = model_mod.abstract_opt_state(opt, params_abs)
        ospecs = shd.opt_state_specs(cfg, opt_abs, params_abs, mesh)
        micro_sh = None
        grad_sh = None
        if cfg.grad_accum > 1:
            micro_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, P(None, *tuple(s))),
                batch_specs, is_leaf=lambda x: isinstance(x, P))
            grad_sh = _shardings(
                mesh, shd.zero_sharded_specs(cfg, params_abs, mesh))
        step_fn = model_mod.make_train_step(cfg, opt, grad_accum=cfg.grad_accum,
                                            microbatch_shardings=micro_sh,
                                            grad_shardings=grad_sh)
        in_sh = (_shardings(mesh, pspecs), _shardings(mesh, ospecs),
                 NamedSharding(mesh, P()), _shardings(mesh, batch_specs))
        out_sh = (_shardings(mesh, pspecs), _shardings(mesh, ospecs),
                  {"loss": NamedSharding(mesh, P())})
        step_abs = jax.ShapeDtypeStruct((), jax.numpy.int32)
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=(0, 1)).lower(
                params_abs, opt_abs, step_abs, batch_abs)
    elif shape.kind == "prefill":
        step_fn = model_mod.make_prefill_step(cfg)
        in_sh = (_shardings(mesh, pspecs), _shardings(mesh, batch_specs))
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=in_sh).lower(
                params_abs, batch_abs)
    else:  # decode
        cache_abs = model_mod.abstract_cache(cfg, shape)
        cspecs = shd.cache_specs(cfg, shape, mesh, cache_abs)
        step_fn = model_mod.make_decode_step(cfg, shape)
        in_sh = (_shardings(mesh, pspecs), _shardings(mesh, cspecs),
                 _shardings(mesh, batch_specs))
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=in_sh,
                              donate_argnums=(1,)).lower(
                params_abs, cache_abs, batch_abs)

    n_chips = mesh.devices.size
    return lowered, n_chips, model_flops_for(cfg, shape)


class SkipShape(Exception):
    pass


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str = None,
            verbose: bool = True, tag_suffix: str = ""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}{tag_suffix}"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, n_chips, mflops = build_lowering(arch, shape_name, mesh)
    except SkipShape as e:
        rec = {"tag": tag, "status": "SKIP", "reason": str(e)}
        _emit(rec, out_dir, tag, verbose)
        return rec

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    roof = analyze(cost, hlo, n_chips=n_chips, model_flops=mflops)

    mem_rec = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    per_dev_total = (mem_rec.get("argument_size_in_bytes", 0)
                     + mem_rec.get("temp_size_in_bytes", 0))
    rec = {
        "tag": tag, "status": "OK", "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_rec, "per_device_bytes": per_dev_total,
        "roofline": roof.to_dict(),
    }
    _emit(rec, out_dir, tag, verbose)
    return rec


def _emit(rec, out_dir, tag, verbose):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "OK":
            r = rec["roofline"]
            print(f"[OK]   {tag}: {rec['per_device_bytes']/2**30:.2f} GiB/dev, "
                  f"compute {r['compute_s']*1e3:.2f} ms, "
                  f"memory {r['memory_s']*1e3:.2f} ms, "
                  f"collective {r['collective_s']*1e3:.2f} ms "
                  f"-> {r['dominant']} bound "
                  f"(useful {r['useful_flops_ratio']*100:.0f}%, "
                  f"lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
        else:
            print(f"[SKIP] {tag}: {rec['reason']}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--flash-vjp", action="store_true",
                    help="enable the flash-attention custom-VJP perf path")
    ap.add_argument("--tag-suffix", default="",
                    help="suffix appended to artifact tags (perf iterations)")
    args = ap.parse_args()

    if args.flash_vjp:
        from repro.models import runtime
        runtime.set_flag("flash_vjp", True)

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_one(arch, shape, multi_pod=mp, out_dir=args.out, tag_suffix=args.tag_suffix)
                except Exception:
                    failures.append((arch, shape, mp))
                    print(f"[FAIL] {arch} {shape} multi_pod={mp}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
