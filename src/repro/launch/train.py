"""End-to-end training driver.

Two modes:
  * ``--stream`` (default): the paper's pipelined streaming schedule — data
    blocks arrive on the Fig.-2 timeline while the mesh trains on the
    delivered prefix; block size comes from the Corollary-1 planner unless
    ``--n-c`` overrides it.
  * ``--no-stream``: conventional training (all data available up front).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.core import (BoundConstants, BoundPlanner, Scenario, Simulator,
                        StreamingTask)
from repro.data.synthetic import SyntheticTokens
from repro.models import init_params, make_train_step
from repro.optim import linear_warmup_cosine
from repro.optim.optimizers import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--stream", dest="stream", action="store_true", default=True)
    ap.add_argument("--no-stream", dest="stream", action="store_false")
    ap.add_argument("--n-c", type=int, default=0, help="block size override")
    ap.add_argument("--n-o", type=float, default=8.0, help="per-block overhead")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={jax.device_count()}")

    opt = make_optimizer(args.optimizer,
                         linear_warmup_cosine(args.lr, 10, args.steps))
    params = init_params(cfg, args.seed)
    opt_state = opt.init(params)
    train_step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    n_seqs = max(args.steps * args.batch // 4, args.batch * 4)
    data = SyntheticTokens(cfg.vocab_size, args.seq + 1, n_seqs, args.seed).batch(0)

    def make_batch(tok):
        return {"tokens": jnp.asarray(tok[:, : args.seq])}

    if args.stream:
        # the unified API: Scenario -> Planner -> Simulator
        scenario = Scenario(N=n_seqs, T=float(args.steps), n_o=args.n_o,
                            tau_p=1.0)
        consts = BoundConstants(L=1.0, c=0.05, M=1.0, M_G=1.0, D=2.0,
                                alpha=min(args.lr, 1.0))
        # --n-c pins the grid to the override; otherwise search 1..N
        planner = BoundPlanner(grid=[args.n_c] if args.n_c else None)
        plan = planner.plan(scenario, consts)
        if not args.n_c:
            print(f"planner: n_c-tilde = {plan.n_c} "
                  f"(bound {plan.bound_value:.4f})")
        task = StreamingTask(
            train_step=train_step, params=params, opt_state=opt_state,
            dataset=np.asarray(data), batch_size=args.batch,
            make_batch=make_batch, seed=args.seed)
        t0 = time.time()
        report = Simulator().run(scenario, plan, task)
        dt = time.time() - t0
        state = report.state
        losses = [h["loss"] for h in state.history]
        trace = (f"loss {losses[0]:.4f} -> {losses[-1]:.4f}" if losses
                 else "no logged updates (deadline too short for log_every)")
        print(f"streamed {report.delivered}/{n_seqs} seqs, "
              f"{state.step + 1} updates in {dt:.1f}s; {trace}")
        params = state.params
    else:
        step_j = jnp.zeros((), jnp.int32)
        rng = np.random.default_rng(args.seed)
        t0 = time.time()
        first = last = None
        for j in range(args.steps):
            idx = rng.integers(0, n_seqs, size=args.batch)
            batch = make_batch(data[idx])
            params, opt_state, m = train_step(params, opt_state, step_j, batch)
            step_j = step_j + 1
            loss = float(m["loss"])
            first = loss if first is None else first
            last = loss
        print(f"{args.steps} steps in {time.time()-t0:.1f}s; "
              f"loss {first:.4f} -> {last:.4f}")

    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
