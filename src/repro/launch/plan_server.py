"""Fleet plan server: micro-batch a scenario request stream, report plans/sec.

Serving loop for the fleet planning engine (``repro.fleet``): requests —
heterogeneous ``Scenario``s, one per edge device asking "what block size /
rate should I use?" — are collected into fixed-size micro-batches, deduped
through the quantised-key :class:`~repro.fleet.cache.PlanCache`, and the
residual misses solved in one jitted ``FleetPlanner.plan_batch`` call per
batch (padded to powers of two so only O(log batch) kernel shapes ever
compile).  The stream may mix every registered link model — cache keys
carry ``(model_id, params)`` and the kernel dispatches per scenario via
``jax.lax.switch`` — AND every registered planning objective: each request
may name the objective it wants (Corollary-1 bound, exact burst-aware
Markov-ARQ, empirical Monte-Carlo), micro-batches group by objective, and
cache keys carry the objective token so answers never cross objectives.
Each request also carries a GRID MODE — ``refine`` (two-pass
coarse->fine) or ``dense`` (single-pass reference) — so serving policies
can mix refined bound traffic with dense calibration traffic;
micro-batches group by (objective, mode), the stats count requests per
mode, and cache keys fold the mode in so the two streams never alias.

  PYTHONPATH=src python -m repro.launch.plan_server \
      --requests 4096 --batch 256 --grid 64 --dup 0.5 \
      --models erasure,fading,gilbert_elliott --objective all \
      --grid-mode all

The synthetic stream mimics a production mix: device classes are drawn
from a finite catalogue with per-request jitter, so a fraction of requests
(--dup, after quantisation) hit the cache.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.chaos import FaultPlan, InjectedFault, parse_chaos_spec
from repro.core.bounds import BoundConstants
from repro.core.links import link_spec, link_spec_for
from repro.core.scenario import Scenario
from repro.fleet import GRID_MODES, FleetPlanner, PlanCache, PlanRecord
# The shared serving catalogue now lives in repro.serve.catalogue (it
# serves both this one-shot driver and the always-on PlanningService);
# re-exported here so existing imports of the plan_server module keep
# working.
from repro.serve.batcher import group_requests
from repro.serve.export import oneshot_metrics
from repro.serve.catalogue import (ALL_MODELS, ALL_OBJECTIVES,  # noqa: F401
                                   LINK_FACTORIES, OBJECTIVE_FACTORIES,
                                   RATE_SET, default_consts,
                                   make_montecarlo_objective, parse_models,
                                   resolve_grid_modes, resolve_objectives,
                                   synth_requests)
from repro.serve.stats import percentiles

# historic private aliases, kept for callers of the old module layout
_parse_models = parse_models
_make_montecarlo_objective = make_montecarlo_objective


@dataclass(frozen=True)
class ServeStats:
    records: List[PlanRecord]
    n_requests: int
    n_batches: int
    seconds: float
    plans_per_sec: float
    cache_hit_rate: float
    #: request counts keyed by link model_id (registry ids)
    requests_per_model: Dict[int, int] = field(default_factory=dict)
    #: request counts keyed by planning objective_id (registry ids)
    requests_per_objective: Dict[str, int] = field(default_factory=dict)
    #: request counts keyed by grid mode ("dense" / "refine")
    requests_per_grid_mode: Dict[str, int] = field(default_factory=dict)
    #: wall-clock per-micro-batch solve latency percentiles (milliseconds);
    #: 0.0 on an empty stream — the batch is the serving unit, so these
    #: are what a per-request SLO inherits from batching
    batch_p50_ms: float = 0.0
    batch_p99_ms: float = 0.0
    batch_max_ms: float = 0.0
    #: requests served by the dense Corollary-1 bound fallback instead of
    #: their requested objective (injected solve failure, or a per-batch
    #: budget the estimated solve would blow) — every one is stamped
    #: ``fallback="bound"`` on its record
    n_degraded: int = 0
    #: fault-injection fire counts per point (empty without a chaos spec)
    faults_injected: Dict[str, int] = field(default_factory=dict)


def serve(requests: Sequence[Scenario], *, planner: FleetPlanner,
          consts: BoundConstants, cache: Optional[PlanCache] = None,
          batch_size: int = 256, warm: bool = True,
          objectives: Optional[Sequence[Any]] = None,
          grid_modes: Optional[Sequence[str]] = None,
          faults: Optional[FaultPlan] = None,
          budget_s: Optional[float] = None) -> ServeStats:
    """Micro-batch the request list and plan it end to end.

    Single-objective streams pad every miss-batch to ``batch_size``
    (``plan_many(pad_to=)``) so the stream exercises exactly ONE kernel
    shape and ``warm=True`` compiles it up front — reported throughput is
    steady-state, not jit compilation.  Mixed-objective streams pad each
    per-objective sub-group to the next power of two instead (O(log
    batch) shapes per objective, without re-solving ``batch_size``-wide
    pad filler per group); warm-up replays the FIRST micro-batch window's
    exact grouping plus one batch per remaining objective, so the common
    shapes are precompiled but a first-seen pow2 shape later in the
    stream still compiles inside the timed loop.

    ``objectives`` assigns each request a planning objective: ``None``
    (the planner's default for every request) or a per-request sequence
    of objective INSTANCES (reuse one instance per distinct objective —
    identity keys the jitted Monte-Carlo kernel cache; registry ids
    resolve through :func:`resolve_objectives`).  ``grid_modes``
    likewise assigns each request a grid mode (``None`` means the
    planner's default for every request; names resolve through
    :func:`resolve_grid_modes`), so one stream can mix refined bound
    traffic with dense calibration traffic.  Micro-batches group by
    (objective, grid mode), so a mixed stream dispatches every
    registered kernel and both solve strategies in one pass.

    The reported hit-rate covers THIS stream only (delta of the cache
    counters, not its lifetime totals) and is 0.0 — never NaN — on an
    empty stream; ``requests_per_model`` / ``requests_per_objective`` /
    ``requests_per_grid_mode`` count requests by link ``model_id``,
    ``objective_id`` and grid mode so mixed traffic is visible in the
    stats.

    ``faults`` (a :class:`~repro.chaos.FaultPlan`) injects the one-shot
    loop's resilience path: each micro-batch group draws
    ``solve.latency`` (artificial delay) and ``solve.error`` before its
    solve; a failed solve is retried once, and a second failure degrades
    the group to the dense Corollary-1 bound fallback — every request
    still gets an answer, stamped ``fallback="bound"`` and counted in
    ``n_degraded``.  ``budget_s`` is a per-micro-batch solve budget: when
    the running estimate (EWMA of observed solve seconds for that
    (objective, mode) group) says the full solve would blow it, the group
    goes straight to the bound fallback instead.  Both default off, and
    with both off the records are bitwise identical to a run without
    this machinery.
    """
    requests = list(requests)
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if objectives is None:
        objs: List[Any] = [None] * len(requests)
    else:
        objs = list(objectives)
        if len(objs) != len(requests):
            raise ValueError(
                f"objectives has length {len(objs)}, want one per request "
                f"({len(requests)})")
    if grid_modes is None:
        modes: List[str] = [planner.grid_mode] * len(requests)
    else:
        modes = [planner._resolve_grid_mode(m) for m in grid_modes]
        if len(modes) != len(requests):
            raise ValueError(
                f"grid_modes has length {len(modes)}, want one per request "
                f"({len(requests)})")
    per_model: Dict[int, int] = {}
    per_objective: Dict[str, int] = {}
    per_mode: Dict[str, int] = {}
    default_id = planner._resolve_objective(None).objective_id
    for sc, obj, mode in zip(requests, objs, modes):
        mid = link_spec_for(sc.link).model_id
        per_model[mid] = per_model.get(mid, 0) + 1
        oid = default_id if obj is None else obj.objective_id
        per_objective[oid] = per_objective.get(oid, 0) + 1
        per_mode[mode] = per_mode.get(mode, 0) + 1

    def _grouped(idxs):
        """Consecutive request indices grouped by (objective identity,
        grid mode), first-seen order (one plan_many call per group) —
        the same canonical grouping the always-on batcher uses."""
        return group_requests(list(idxs),
                              key=lambda i: (id(objs[i]), modes[i]))

    # single-group streams pad every micro-batch to ONE kernel shape;
    # mixed streams pad each per-(objective, mode) sub-group to the next
    # power of two instead (still O(log batch) shapes per group, but no
    # lanes wasted re-solving the pad filler batch_size-wide per group)
    mixed = len({(id(o), m) for o, m in zip(objs, modes)}) > 1
    pad_to = None if mixed else batch_size
    # the degradation target: dense Corollary-1 bound — the cheapest
    # objective in the catalogue, solved without the cache so a degraded
    # answer can never shadow a full one under the requested objective
    fallback_obj = None
    if faults is not None or budget_s is not None:
        fallback_obj = resolve_objectives(("corollary1",))["corollary1"]

    def _degrade(idxs):
        recs = planner.plan_many([requests[i] for i in idxs], consts,
                                 cache=None, pad_to=pad_to,
                                 objective=fallback_obj, grid_mode="dense")
        return [dataclasses.replace(r, fallback="bound") for r in recs]

    if warm and requests:
        warmed = set()
        # the first window's exact grouping: compiles the shapes the
        # timed loop starts with
        for idxs in _grouped(range(min(batch_size, len(requests)))):
            planner.plan_many([requests[i] for i in idxs], consts,
                              cache=None, pad_to=pad_to,
                              objective=objs[idxs[0]],
                              grid_mode=modes[idxs[0]])
            warmed.add((id(objs[idxs[0]]), modes[idxs[0]]))
        # groups absent from the first window still warm once
        for idxs in _grouped(range(len(requests))):
            if (id(objs[idxs[0]]), modes[idxs[0]]) not in warmed:
                planner.plan_many([requests[i] for i in idxs[:batch_size]],
                                  consts, cache=None, pad_to=pad_to,
                                  objective=objs[idxs[0]],
                                  grid_mode=modes[idxs[0]])
        if fallback_obj is not None:
            # a degraded group may fire any time once chaos/budgets are
            # on, so its kernel shape warms with everything else
            planner.plan_many(requests[:batch_size], consts, cache=None,
                              pad_to=pad_to, objective=fallback_obj,
                              grid_mode="dense")
    hits0, misses0 = (cache.hits, cache.misses) if cache is not None \
        else (0, 0)
    records: List[Optional[PlanRecord]] = [None] * len(requests)
    n_batches = 0
    n_degraded = 0
    # per-(objective, mode) EWMA of observed full-solve seconds: the
    # budget triage's estimate of what the NEXT group solve will cost
    solve_est: Dict[Tuple[int, str], float] = {}
    batch_seconds: List[float] = []
    t0 = time.perf_counter()
    for lo in range(0, len(requests), batch_size):
        for idxs in _grouped(range(lo, min(lo + batch_size,
                                           len(requests)))):
            gkey = (id(objs[idxs[0]]), modes[idxs[0]])
            tb = time.perf_counter()
            degraded = False
            if budget_s is not None \
                    and solve_est.get(gkey, 0.0) > budget_s:
                recs, degraded = _degrade(idxs), True
            else:
                try:
                    if faults is not None:
                        stall = faults.draw("solve.latency")
                        if stall is not None:
                            time.sleep(stall.duration_s)
                        if faults.draw("solve.error") is not None:
                            raise InjectedFault("solve.error")
                    recs = planner.plan_many(
                        [requests[i] for i in idxs], consts, cache=cache,
                        pad_to=pad_to, objective=objs[idxs[0]],
                        grid_mode=modes[idxs[0]])
                except Exception:
                    # one retry (the fault draw advances, so a transient
                    # fault clears), then degrade to the bound fallback
                    try:
                        if faults is not None \
                                and faults.draw("solve.error") is not None:
                            raise InjectedFault("solve.error")
                        recs = planner.plan_many(
                            [requests[i] for i in idxs], consts,
                            cache=cache, pad_to=pad_to,
                            objective=objs[idxs[0]],
                            grid_mode=modes[idxs[0]])
                    except Exception:
                        recs, degraded = _degrade(idxs), True
            dt_b = time.perf_counter() - tb
            batch_seconds.append(dt_b)
            if degraded:
                n_degraded += len(idxs)
            elif budget_s is not None:
                prev = solve_est.get(gkey)
                solve_est[gkey] = dt_b if prev is None \
                    else 0.5 * prev + 0.5 * dt_b
            for i, rec in zip(idxs, recs):
                records[i] = rec
            n_batches += 1
    dt = time.perf_counter() - t0
    if cache is not None:
        d_hits = cache.hits - hits0
        d_total = d_hits + (cache.misses - misses0)
        hit_rate = d_hits / d_total if d_total else 0.0
    else:
        hit_rate = 0.0
    b50, b99 = percentiles(batch_seconds)
    return ServeStats(
        records=records, n_requests=len(requests), n_batches=n_batches,
        seconds=dt, plans_per_sec=len(requests) / dt if dt > 0 else 0.0,
        cache_hit_rate=hit_rate, requests_per_model=per_model,
        requests_per_objective=per_objective,
        requests_per_grid_mode=per_mode,
        batch_p50_ms=b50 * 1e3, batch_p99_ms=b99 * 1e3,
        batch_max_ms=(max(batch_seconds) * 1e3 if batch_seconds else 0.0),
        n_degraded=n_degraded,
        faults_injected=dict(faults.fires) if faults is not None else {})


def _parse_models(spec: str) -> Sequence[str]:
    if spec == "all":
        return ALL_MODELS
    return tuple(m.strip() for m in spec.split(",") if m.strip())


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--cache-size", type=int, default=8192)
    ap.add_argument("--sig-digits", type=int, default=3)
    ap.add_argument("--dup", type=float, default=0.5,
                    help="fraction of requests hitting a known device class")
    ap.add_argument("--models", default="erasure",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--objective", default="corollary1",
                    help="comma-separated planning-objective mix, or 'all' "
                         f"({', '.join(ALL_OBJECTIVES)})")
    ap.add_argument("--grid-mode", default="dense",
                    help="comma-separated grid-mode mix, or 'all' "
                         f"({', '.join(GRID_MODES)}); 'refine' is the "
                         "two-pass coarse->fine solve, 'dense' the "
                         "single-pass reference")
    ap.add_argument("--n-max", type=int, default=32768,
                    help="cap on drawn dataset sizes (keep small when the "
                         "mix includes the simulated montecarlo objective)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--metrics-textfile", default=None,
                    help="write the run's Prometheus text exposition here "
                         "(repro_plan_server_* families + cache + traces)")
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="per-micro-batch solve budget in ms (0 = off); "
                         "groups whose estimated solve would blow it are "
                         "degraded to the dense Corollary-1 bound fallback")
    ap.add_argument("--chaos-spec", default=None,
                    help="deterministic fault-injection spec, e.g. "
                         "'seed=7,solve_error=0.2,solve_latency=0.1:5ms,"
                         "cache_corrupt=0.05'")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    try:
        catalogue = resolve_objectives(args.objective)
        mode_mix = resolve_grid_modes(args.grid_mode)
        faults = parse_chaos_spec(args.chaos_spec) \
            if args.chaos_spec else None
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    requests = synth_requests(args.requests, seed=args.seed,
                              dup_frac=args.dup,
                              models=_parse_models(args.models),
                              n_max=args.n_max)
    instances = list(catalogue.values())
    rng = np.random.default_rng(args.seed + 1)
    objectives = [instances[int(rng.integers(len(instances)))]
                  for _ in requests]
    grid_modes = [mode_mix[int(rng.integers(len(mode_mix)))]
                  for _ in requests]
    planner = FleetPlanner(grid_size=args.grid)
    corruptor = None
    if faults is not None and faults.enabled("cache.corrupt"):
        corruptor = lambda: faults.draw("cache.corrupt") is not None  # noqa: E731
    cache = None if args.no_cache else PlanCache(
        maxsize=args.cache_size, sig_digits=args.sig_digits,
        checksums=faults is not None, corruptor=corruptor)
    stats = serve(requests, planner=planner, consts=default_consts(),
                  cache=cache, batch_size=args.batch, objectives=objectives,
                  grid_modes=grid_modes, faults=faults,
                  budget_s=args.budget_ms / 1e3 if args.budget_ms > 0
                  else None)
    print(f"served {stats.n_requests} plan requests in {stats.n_batches} "
          f"micro-batches of <= {args.batch}")
    print(f"throughput: {stats.plans_per_sec:,.0f} plans/sec "
          f"({stats.seconds * 1e3:.1f} ms total, grid={args.grid})")
    print(f"micro-batch latency: p50={stats.batch_p50_ms:.2f} ms "
          f"p99={stats.batch_p99_ms:.2f} ms max={stats.batch_max_ms:.2f} ms")
    by_model = ", ".join(
        f"{link_spec(mid).name}[{mid}]={n}"
        for mid, n in sorted(stats.requests_per_model.items()))
    print(f"request mix: {by_model}")
    by_objective = ", ".join(
        f"{oid}={n}"
        for oid, n in sorted(stats.requests_per_objective.items()))
    print(f"objective mix: {by_objective}")
    by_mode = ", ".join(
        f"{mode}={n}"
        for mode, n in sorted(stats.requests_per_grid_mode.items()))
    print(f"grid-mode mix: {by_mode}")
    if cache is not None:
        print(f"cache: {cache.hits} hits / {cache.misses} misses "
              f"(hit rate {stats.cache_hit_rate:.1%}, {len(cache)} entries)")
        if cache.corruptions:
            print(f"cache corruptions detected (re-solved): "
                  f"{cache.corruptions}")
    if faults is not None or args.budget_ms > 0:
        fired = ", ".join(f"{p}={n}" for p, n in
                          sorted(stats.faults_injected.items())) or "none"
        n_ok = sum(r is not None for r in stats.records)
        print(f"resilience: degraded={stats.n_degraded} "
              f"(bound fallback), completed={n_ok}/{stats.n_requests}, "
              f"faults fired: {fired}")
    if stats.records:
        sample = stats.records[0]
        print(f"sample plan: n_c={sample.n_c} rate={sample.rate} "
              f"objective={sample.objective} "
              f"bound={sample.bound_value:.4g}")
    if args.metrics_textfile:
        oneshot_metrics(stats, cache).write_textfile(args.metrics_textfile)
        print(f"metrics: wrote Prometheus textfile {args.metrics_textfile}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
