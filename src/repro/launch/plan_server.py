"""Fleet plan server: micro-batch a scenario request stream, report plans/sec.

Serving loop for the fleet planning engine (``repro.fleet``): requests —
heterogeneous ``Scenario``s, one per edge device asking "what block size /
rate should I use?" — are collected into fixed-size micro-batches, deduped
through the quantised-key :class:`~repro.fleet.cache.PlanCache`, and the
residual misses solved in one jitted ``FleetPlanner.plan_batch`` call per
batch (padded to powers of two so only O(log batch) kernel shapes ever
compile).  The stream may mix every registered link model — cache keys
carry ``(model_id, params)`` and the kernel dispatches per scenario via
``jax.lax.switch`` — AND every registered planning objective: each request
may name the objective it wants (Corollary-1 bound, exact burst-aware
Markov-ARQ, empirical Monte-Carlo), micro-batches group by objective, and
cache keys carry the objective token so answers never cross objectives.
Each request also carries a GRID MODE — ``refine`` (two-pass
coarse->fine) or ``dense`` (single-pass reference) — so serving policies
can mix refined bound traffic with dense calibration traffic;
micro-batches group by (objective, mode), the stats count requests per
mode, and cache keys fold the mode in so the two streams never alias.

  PYTHONPATH=src python -m repro.launch.plan_server \
      --requests 4096 --batch 256 --grid 64 --dup 0.5 \
      --models erasure,fading,gilbert_elliott --objective all \
      --grid-mode all

The synthetic stream mimics a production mix: device classes are drawn
from a finite catalogue with per-request jitter, so a fraction of requests
(--dup, after quantisation) hit the cache.
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants
from repro.core.links import link_spec, link_spec_for
from repro.core.objectives import (BoundObjective, MarkovARQObjective,
                                   MonteCarloObjective)
from repro.core.scenario import (ErasureLink, FadingLink, GilbertElliottLink,
                                 IdealLink, MultiDevice, Scenario,
                                 SingleDevice)
from repro.fleet import GRID_MODES, FleetPlanner, PlanCache, PlanRecord

RATE_SET = (1.0, 1.25, 1.5, 2.0, 3.0)


def resolve_grid_modes(spec) -> Sequence[str]:
    """Validate a grid-mode mix: "all", one mode, or a comma list of
    :data:`repro.fleet.GRID_MODES`.  Unknown names raise ``ValueError``
    (the CLI maps that to exit code 2) — serving policies mix refined
    bound traffic with dense calibration traffic, and a typo silently
    falling back to one mode would skew both streams."""
    if spec == "all":
        return GRID_MODES
    names = (tuple(s.strip() for s in spec.split(",") if s.strip())
             if isinstance(spec, str) else tuple(spec))
    unknown = [m for m in names if m not in GRID_MODES]
    if unknown:
        raise ValueError(
            f"unknown grid mode(s) {unknown}; available: {list(GRID_MODES)}")
    if not names:
        raise ValueError(f"no grid mode requested; "
                         f"available: {list(GRID_MODES)}")
    return names


def default_consts() -> BoundConstants:
    """The paper's edge-ridge bound constants (Sec. 5)."""
    return BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0,
                          alpha=EP.alpha)


def _draw_ideal(rng) -> IdealLink:
    return IdealLink(rates=RATE_SET)


def _draw_erasure(rng) -> ErasureLink:
    return ErasureLink(beta=float(rng.uniform(0.05, 1.5)),
                       p_base=float(rng.uniform(0.0, 0.5)), rates=RATE_SET)


def _draw_fading(rng) -> FadingLink:
    return FadingLink(snr=float(rng.uniform(2.0, 50.0)), rates=RATE_SET)


def _draw_gilbert_elliott(rng) -> GilbertElliottLink:
    p_good = float(rng.uniform(0.0, 0.2))
    return GilbertElliottLink(
        p_gb=float(rng.uniform(0.01, 0.3)),
        p_bg=float(rng.uniform(0.2, 0.9)),
        p_good=p_good,
        p_bad=float(rng.uniform(p_good, 0.9)),
        beta=float(rng.uniform(0.05, 1.0)), rates=RATE_SET)


#: Synthetic device-class link factories, by model name (--models values).
LINK_FACTORIES = {
    "ideal": _draw_ideal,
    "erasure": _draw_erasure,
    "fading": _draw_fading,
    "gilbert_elliott": _draw_gilbert_elliott,
}

#: The full mixed-model catalogue (every built-in channel family).
ALL_MODELS = tuple(LINK_FACTORIES)


def _make_montecarlo_objective() -> MonteCarloObjective:
    """Small deterministic ridge task (the canonical generator, scaled
    down) for Monte-Carlo objective serving."""
    from repro.data.synthetic import make_regression_dataset

    X, y, _ = make_regression_dataset(n=256, d=8, seed=0)
    return MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0)


#: Planning-objective factories, by registry id (--objective values).
OBJECTIVE_FACTORIES = {
    "corollary1": BoundObjective,
    "markov_arq": MarkovARQObjective,
    "montecarlo": _make_montecarlo_objective,
}

#: The full mixed-objective catalogue (every built-in objective).
ALL_OBJECTIVES = tuple(OBJECTIVE_FACTORIES)


def resolve_objectives(spec) -> Dict[str, Any]:
    """Instantiate the requested objectives ONCE each (instance identity
    keys the jitted Monte-Carlo kernel cache).  ``spec`` is "all", a
    comma-separated string, or a sequence of registry ids; unknown names
    raise ``ValueError`` with the available ids.
    """
    if spec == "all":
        names: Sequence[str] = ALL_OBJECTIVES
    elif isinstance(spec, str):
        names = tuple(s.strip() for s in spec.split(",") if s.strip())
    else:
        names = tuple(spec)
    unknown = [o for o in names if o not in OBJECTIVE_FACTORIES]
    if unknown:
        raise ValueError(
            f"unregistered planning objective(s) {unknown}; "
            f"available: {sorted(OBJECTIVE_FACTORIES)}")
    if not names:
        raise ValueError("no planning objective requested; "
                         f"available: {sorted(OBJECTIVE_FACTORIES)}")
    return {name: OBJECTIVE_FACTORIES[name]() for name in names}


def synth_requests(n: int, *, seed: int = 0, dup_frac: float = 0.5,
                   n_classes: int = 64,
                   models: Sequence[str] = ("erasure",),
                   n_max: int = 32768) -> List[Scenario]:
    """Heterogeneous request stream over a catalogue of device classes.

    ``dup_frac`` of the requests resample a previously seen class with
    tiny parameter jitter (below the cache's quantisation step), the rest
    draw a fresh class — so the achievable cache hit-rate is ~``dup_frac``.
    Each fresh class draws its link from one of ``models`` (keys of
    :data:`LINK_FACTORIES`) uniformly, so ``models=ALL_MODELS`` yields a
    stream mixing every channel family.  ``n_max`` caps the drawn dataset
    sizes — Monte-Carlo serving simulates the update timeline, so its
    streams use a small cap to bound the scan length.
    """
    unknown = [m for m in models if m not in LINK_FACTORIES]
    if unknown:
        raise ValueError(
            f"unknown link model name(s) {unknown}; "
            f"available: {sorted(LINK_FACTORIES)}")
    if n_max <= 256:
        raise ValueError(f"n_max must be > 256, got {n_max}")
    rng = np.random.default_rng(seed)
    classes: List[dict] = []

    def fresh_class() -> dict:
        N = int(rng.integers(256, n_max))
        return dict(
            N=N, T=float(rng.uniform(1.1, 3.0)) * N,
            n_o=float(rng.uniform(1.0, 1000.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
            link=LINK_FACTORIES[models[int(rng.integers(len(models)))]](rng),
            D=int(rng.choice([1, 1, 2, 4, 8])))

    out: List[Scenario] = []
    for _ in range(n):
        if classes and rng.random() < dup_frac:
            c = classes[int(rng.integers(len(classes)))]
        else:
            c = fresh_class()
            if len(classes) < n_classes:
                classes.append(c)
        jitter = 1.0 + rng.uniform(-1e-5, 1e-5)   # below quantisation step
        out.append(Scenario(
            N=c["N"], T=c["T"] * jitter, n_o=c["n_o"], tau_p=c["tau_p"],
            link=c["link"],
            topology=MultiDevice(c["D"]) if c["D"] > 1 else SingleDevice()))
    return out


@dataclass(frozen=True)
class ServeStats:
    records: List[PlanRecord]
    n_requests: int
    n_batches: int
    seconds: float
    plans_per_sec: float
    cache_hit_rate: float
    #: request counts keyed by link model_id (registry ids)
    requests_per_model: Dict[int, int] = field(default_factory=dict)
    #: request counts keyed by planning objective_id (registry ids)
    requests_per_objective: Dict[str, int] = field(default_factory=dict)
    #: request counts keyed by grid mode ("dense" / "refine")
    requests_per_grid_mode: Dict[str, int] = field(default_factory=dict)


def serve(requests: Sequence[Scenario], *, planner: FleetPlanner,
          consts: BoundConstants, cache: Optional[PlanCache] = None,
          batch_size: int = 256, warm: bool = True,
          objectives: Optional[Sequence[Any]] = None,
          grid_modes: Optional[Sequence[str]] = None) -> ServeStats:
    """Micro-batch the request list and plan it end to end.

    Single-objective streams pad every miss-batch to ``batch_size``
    (``plan_many(pad_to=)``) so the stream exercises exactly ONE kernel
    shape and ``warm=True`` compiles it up front — reported throughput is
    steady-state, not jit compilation.  Mixed-objective streams pad each
    per-objective sub-group to the next power of two instead (O(log
    batch) shapes per objective, without re-solving ``batch_size``-wide
    pad filler per group); warm-up replays the FIRST micro-batch window's
    exact grouping plus one batch per remaining objective, so the common
    shapes are precompiled but a first-seen pow2 shape later in the
    stream still compiles inside the timed loop.

    ``objectives`` assigns each request a planning objective: ``None``
    (the planner's default for every request) or a per-request sequence
    of objective INSTANCES (reuse one instance per distinct objective —
    identity keys the jitted Monte-Carlo kernel cache; registry ids
    resolve through :func:`resolve_objectives`).  ``grid_modes``
    likewise assigns each request a grid mode (``None`` means the
    planner's default for every request; names resolve through
    :func:`resolve_grid_modes`), so one stream can mix refined bound
    traffic with dense calibration traffic.  Micro-batches group by
    (objective, grid mode), so a mixed stream dispatches every
    registered kernel and both solve strategies in one pass.

    The reported hit-rate covers THIS stream only (delta of the cache
    counters, not its lifetime totals) and is 0.0 — never NaN — on an
    empty stream; ``requests_per_model`` / ``requests_per_objective`` /
    ``requests_per_grid_mode`` count requests by link ``model_id``,
    ``objective_id`` and grid mode so mixed traffic is visible in the
    stats.
    """
    requests = list(requests)
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if objectives is None:
        objs: List[Any] = [None] * len(requests)
    else:
        objs = list(objectives)
        if len(objs) != len(requests):
            raise ValueError(
                f"objectives has length {len(objs)}, want one per request "
                f"({len(requests)})")
    if grid_modes is None:
        modes: List[str] = [planner.grid_mode] * len(requests)
    else:
        modes = [planner._resolve_grid_mode(m) for m in grid_modes]
        if len(modes) != len(requests):
            raise ValueError(
                f"grid_modes has length {len(modes)}, want one per request "
                f"({len(requests)})")
    per_model: Dict[int, int] = {}
    per_objective: Dict[str, int] = {}
    per_mode: Dict[str, int] = {}
    default_id = planner._resolve_objective(None).objective_id
    for sc, obj, mode in zip(requests, objs, modes):
        mid = link_spec_for(sc.link).model_id
        per_model[mid] = per_model.get(mid, 0) + 1
        oid = default_id if obj is None else obj.objective_id
        per_objective[oid] = per_objective.get(oid, 0) + 1
        per_mode[mode] = per_mode.get(mode, 0) + 1

    def _grouped(idxs):
        """Consecutive request indices grouped by (objective identity,
        grid mode), first-seen order (one plan_many call per group)."""
        groups: "Dict[tuple, List[int]]" = {}
        order: List[tuple] = []
        for i in idxs:
            k = (id(objs[i]), modes[i])
            if k not in groups:
                groups[k] = []
                order.append(k)
            groups[k].append(i)
        return [groups[k] for k in order]

    # single-group streams pad every micro-batch to ONE kernel shape;
    # mixed streams pad each per-(objective, mode) sub-group to the next
    # power of two instead (still O(log batch) shapes per group, but no
    # lanes wasted re-solving the pad filler batch_size-wide per group)
    mixed = len({(id(o), m) for o, m in zip(objs, modes)}) > 1
    pad_to = None if mixed else batch_size
    if warm and requests:
        warmed = set()
        # the first window's exact grouping: compiles the shapes the
        # timed loop starts with
        for idxs in _grouped(range(min(batch_size, len(requests)))):
            planner.plan_many([requests[i] for i in idxs], consts,
                              cache=None, pad_to=pad_to,
                              objective=objs[idxs[0]],
                              grid_mode=modes[idxs[0]])
            warmed.add((id(objs[idxs[0]]), modes[idxs[0]]))
        # groups absent from the first window still warm once
        for idxs in _grouped(range(len(requests))):
            if (id(objs[idxs[0]]), modes[idxs[0]]) not in warmed:
                planner.plan_many([requests[i] for i in idxs[:batch_size]],
                                  consts, cache=None, pad_to=pad_to,
                                  objective=objs[idxs[0]],
                                  grid_mode=modes[idxs[0]])
    hits0, misses0 = (cache.hits, cache.misses) if cache is not None \
        else (0, 0)
    records: List[Optional[PlanRecord]] = [None] * len(requests)
    n_batches = 0
    t0 = time.perf_counter()
    for lo in range(0, len(requests), batch_size):
        for idxs in _grouped(range(lo, min(lo + batch_size,
                                           len(requests)))):
            recs = planner.plan_many(
                [requests[i] for i in idxs], consts, cache=cache,
                pad_to=pad_to, objective=objs[idxs[0]],
                grid_mode=modes[idxs[0]])
            for i, rec in zip(idxs, recs):
                records[i] = rec
            n_batches += 1
    dt = time.perf_counter() - t0
    if cache is not None:
        d_hits = cache.hits - hits0
        d_total = d_hits + (cache.misses - misses0)
        hit_rate = d_hits / d_total if d_total else 0.0
    else:
        hit_rate = 0.0
    return ServeStats(
        records=records, n_requests=len(requests), n_batches=n_batches,
        seconds=dt, plans_per_sec=len(requests) / dt if dt > 0 else 0.0,
        cache_hit_rate=hit_rate, requests_per_model=per_model,
        requests_per_objective=per_objective,
        requests_per_grid_mode=per_mode)


def _parse_models(spec: str) -> Sequence[str]:
    if spec == "all":
        return ALL_MODELS
    return tuple(m.strip() for m in spec.split(",") if m.strip())


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--cache-size", type=int, default=8192)
    ap.add_argument("--sig-digits", type=int, default=3)
    ap.add_argument("--dup", type=float, default=0.5,
                    help="fraction of requests hitting a known device class")
    ap.add_argument("--models", default="erasure",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--objective", default="corollary1",
                    help="comma-separated planning-objective mix, or 'all' "
                         f"({', '.join(ALL_OBJECTIVES)})")
    ap.add_argument("--grid-mode", default="dense",
                    help="comma-separated grid-mode mix, or 'all' "
                         f"({', '.join(GRID_MODES)}); 'refine' is the "
                         "two-pass coarse->fine solve, 'dense' the "
                         "single-pass reference")
    ap.add_argument("--n-max", type=int, default=32768,
                    help="cap on drawn dataset sizes (keep small when the "
                         "mix includes the simulated montecarlo objective)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    try:
        catalogue = resolve_objectives(args.objective)
        mode_mix = resolve_grid_modes(args.grid_mode)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    requests = synth_requests(args.requests, seed=args.seed,
                              dup_frac=args.dup,
                              models=_parse_models(args.models),
                              n_max=args.n_max)
    instances = list(catalogue.values())
    rng = np.random.default_rng(args.seed + 1)
    objectives = [instances[int(rng.integers(len(instances)))]
                  for _ in requests]
    grid_modes = [mode_mix[int(rng.integers(len(mode_mix)))]
                  for _ in requests]
    planner = FleetPlanner(grid_size=args.grid)
    cache = None if args.no_cache else PlanCache(
        maxsize=args.cache_size, sig_digits=args.sig_digits)
    stats = serve(requests, planner=planner, consts=default_consts(),
                  cache=cache, batch_size=args.batch, objectives=objectives,
                  grid_modes=grid_modes)
    print(f"served {stats.n_requests} plan requests in {stats.n_batches} "
          f"micro-batches of <= {args.batch}")
    print(f"throughput: {stats.plans_per_sec:,.0f} plans/sec "
          f"({stats.seconds * 1e3:.1f} ms total, grid={args.grid})")
    by_model = ", ".join(
        f"{link_spec(mid).name}[{mid}]={n}"
        for mid, n in sorted(stats.requests_per_model.items()))
    print(f"request mix: {by_model}")
    by_objective = ", ".join(
        f"{oid}={n}"
        for oid, n in sorted(stats.requests_per_objective.items()))
    print(f"objective mix: {by_objective}")
    by_mode = ", ".join(
        f"{mode}={n}"
        for mode, n in sorted(stats.requests_per_grid_mode.items()))
    print(f"grid-mode mix: {by_mode}")
    if cache is not None:
        print(f"cache: {cache.hits} hits / {cache.misses} misses "
              f"(hit rate {stats.cache_hit_rate:.1%}, {len(cache)} entries)")
    if stats.records:
        sample = stats.records[0]
        print(f"sample plan: n_c={sample.n_c} rate={sample.rate} "
              f"objective={sample.objective} "
              f"bound={sample.bound_value:.4g}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
