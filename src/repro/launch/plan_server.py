"""Fleet plan server: micro-batch a scenario request stream, report plans/sec.

Serving loop for the fleet planning engine (``repro.fleet``): requests —
heterogeneous ``Scenario``s, one per edge device asking "what block size /
rate should I use?" — are collected into fixed-size micro-batches, deduped
through the quantised-key :class:`~repro.fleet.cache.PlanCache`, and the
residual misses solved in one jitted ``FleetPlanner.plan_batch`` call per
batch (padded to powers of two so only O(log batch) kernel shapes ever
compile).  The stream may mix every registered link model — cache keys
carry ``(model_id, params)`` and the kernel dispatches per scenario via
``jax.lax.switch``, so a mixed-model stream solves in the same single
compilation as a homogeneous one.

  PYTHONPATH=src python -m repro.launch.plan_server \
      --requests 4096 --batch 256 --grid 64 --dup 0.5 \
      --models erasure,fading,gilbert_elliott

The synthetic stream mimics a production mix: device classes are drawn
from a finite catalogue with per-request jitter, so a fraction of requests
(--dup, after quantisation) hit the cache.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants
from repro.core.links import link_spec, link_spec_for
from repro.core.scenario import (ErasureLink, FadingLink, GilbertElliottLink,
                                 IdealLink, MultiDevice, Scenario,
                                 SingleDevice)
from repro.fleet import FleetPlanner, PlanCache, PlanRecord

RATE_SET = (1.0, 1.25, 1.5, 2.0, 3.0)


def default_consts() -> BoundConstants:
    """The paper's edge-ridge bound constants (Sec. 5)."""
    return BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0,
                          alpha=EP.alpha)


def _draw_ideal(rng) -> IdealLink:
    return IdealLink(rates=RATE_SET)


def _draw_erasure(rng) -> ErasureLink:
    return ErasureLink(beta=float(rng.uniform(0.05, 1.5)),
                       p_base=float(rng.uniform(0.0, 0.5)), rates=RATE_SET)


def _draw_fading(rng) -> FadingLink:
    return FadingLink(snr=float(rng.uniform(2.0, 50.0)), rates=RATE_SET)


def _draw_gilbert_elliott(rng) -> GilbertElliottLink:
    p_good = float(rng.uniform(0.0, 0.2))
    return GilbertElliottLink(
        p_gb=float(rng.uniform(0.01, 0.3)),
        p_bg=float(rng.uniform(0.2, 0.9)),
        p_good=p_good,
        p_bad=float(rng.uniform(p_good, 0.9)),
        beta=float(rng.uniform(0.05, 1.0)), rates=RATE_SET)


#: Synthetic device-class link factories, by model name (--models values).
LINK_FACTORIES = {
    "ideal": _draw_ideal,
    "erasure": _draw_erasure,
    "fading": _draw_fading,
    "gilbert_elliott": _draw_gilbert_elliott,
}

#: The full mixed-model catalogue (every built-in channel family).
ALL_MODELS = tuple(LINK_FACTORIES)


def synth_requests(n: int, *, seed: int = 0, dup_frac: float = 0.5,
                   n_classes: int = 64,
                   models: Sequence[str] = ("erasure",)) -> List[Scenario]:
    """Heterogeneous request stream over a catalogue of device classes.

    ``dup_frac`` of the requests resample a previously seen class with
    tiny parameter jitter (below the cache's quantisation step), the rest
    draw a fresh class — so the achievable cache hit-rate is ~``dup_frac``.
    Each fresh class draws its link from one of ``models`` (keys of
    :data:`LINK_FACTORIES`) uniformly, so ``models=ALL_MODELS`` yields a
    stream mixing every channel family.
    """
    unknown = [m for m in models if m not in LINK_FACTORIES]
    if unknown:
        raise ValueError(
            f"unknown link model name(s) {unknown}; "
            f"available: {sorted(LINK_FACTORIES)}")
    rng = np.random.default_rng(seed)
    classes: List[dict] = []

    def fresh_class() -> dict:
        N = int(rng.integers(256, 32768))
        return dict(
            N=N, T=float(rng.uniform(1.1, 3.0)) * N,
            n_o=float(rng.uniform(1.0, 1000.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
            link=LINK_FACTORIES[models[int(rng.integers(len(models)))]](rng),
            D=int(rng.choice([1, 1, 2, 4, 8])))

    out: List[Scenario] = []
    for _ in range(n):
        if classes and rng.random() < dup_frac:
            c = classes[int(rng.integers(len(classes)))]
        else:
            c = fresh_class()
            if len(classes) < n_classes:
                classes.append(c)
        jitter = 1.0 + rng.uniform(-1e-5, 1e-5)   # below quantisation step
        out.append(Scenario(
            N=c["N"], T=c["T"] * jitter, n_o=c["n_o"], tau_p=c["tau_p"],
            link=c["link"],
            topology=MultiDevice(c["D"]) if c["D"] > 1 else SingleDevice()))
    return out


@dataclass(frozen=True)
class ServeStats:
    records: List[PlanRecord]
    n_requests: int
    n_batches: int
    seconds: float
    plans_per_sec: float
    cache_hit_rate: float
    #: request counts keyed by link model_id (registry ids)
    requests_per_model: Dict[int, int] = field(default_factory=dict)


def serve(requests: Sequence[Scenario], *, planner: FleetPlanner,
          consts: BoundConstants, cache: Optional[PlanCache] = None,
          batch_size: int = 256, warm: bool = True) -> ServeStats:
    """Micro-batch the request list and plan it end to end.

    Every miss-batch is padded to ``batch_size`` (``plan_many(pad_to=)``)
    so the whole stream exercises exactly ONE kernel shape, and
    ``warm=True`` pre-plans one batch (uncached, untimed) to compile it —
    reported throughput is steady-state, not jit compilation.

    The reported hit-rate covers THIS stream only (delta of the cache
    counters, not its lifetime totals) and is 0.0 — never NaN — on an
    empty stream; ``requests_per_model`` counts requests by link
    ``model_id`` so mixed-model traffic is visible in the stats.
    """
    requests = list(requests)
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    per_model: Dict[int, int] = {}
    for sc in requests:
        mid = link_spec_for(sc.link).model_id
        per_model[mid] = per_model.get(mid, 0) + 1
    if warm and requests:
        planner.plan_many(requests[:batch_size], consts, cache=None,
                          pad_to=batch_size)
    hits0, misses0 = (cache.hits, cache.misses) if cache is not None \
        else (0, 0)
    records: List[PlanRecord] = []
    n_batches = 0
    t0 = time.perf_counter()
    for lo in range(0, len(requests), batch_size):
        records.extend(planner.plan_many(
            requests[lo:lo + batch_size], consts, cache=cache,
            pad_to=batch_size))
        n_batches += 1
    dt = time.perf_counter() - t0
    if cache is not None:
        d_hits = cache.hits - hits0
        d_total = d_hits + (cache.misses - misses0)
        hit_rate = d_hits / d_total if d_total else 0.0
    else:
        hit_rate = 0.0
    return ServeStats(
        records=records, n_requests=len(requests), n_batches=n_batches,
        seconds=dt, plans_per_sec=len(requests) / dt if dt > 0 else 0.0,
        cache_hit_rate=hit_rate, requests_per_model=per_model)


def _parse_models(spec: str) -> Sequence[str]:
    if spec == "all":
        return ALL_MODELS
    return tuple(m.strip() for m in spec.split(",") if m.strip())


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--cache-size", type=int, default=8192)
    ap.add_argument("--sig-digits", type=int, default=3)
    ap.add_argument("--dup", type=float, default=0.5,
                    help="fraction of requests hitting a known device class")
    ap.add_argument("--models", default="erasure",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    requests = synth_requests(args.requests, seed=args.seed,
                              dup_frac=args.dup,
                              models=_parse_models(args.models))
    planner = FleetPlanner(grid_size=args.grid)
    cache = None if args.no_cache else PlanCache(
        maxsize=args.cache_size, sig_digits=args.sig_digits)
    stats = serve(requests, planner=planner, consts=default_consts(),
                  cache=cache, batch_size=args.batch)
    print(f"served {stats.n_requests} plan requests in {stats.n_batches} "
          f"micro-batches of <= {args.batch}")
    print(f"throughput: {stats.plans_per_sec:,.0f} plans/sec "
          f"({stats.seconds * 1e3:.1f} ms total, grid={args.grid})")
    by_model = ", ".join(
        f"{link_spec(mid).name}[{mid}]={n}"
        for mid, n in sorted(stats.requests_per_model.items()))
    print(f"request mix: {by_model}")
    if cache is not None:
        print(f"cache: {cache.hits} hits / {cache.misses} misses "
              f"(hit rate {stats.cache_hit_rate:.1%}, {len(cache)} entries)")
    if stats.records:
        sample = stats.records[0]
        print(f"sample plan: n_c={sample.n_c} rate={sample.rate} "
              f"bound={sample.bound_value:.4g}")


if __name__ == "__main__":
    main()
