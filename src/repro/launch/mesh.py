"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BANDWIDTH = 819e9         # bytes/s per chip
ICI_BANDWIDTH = 50e9          # bytes/s per link (~ per-direction)
