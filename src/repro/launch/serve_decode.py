"""Serving driver: prefill a batch of requests, then decode tokens.

  PYTHONPATH=src python -m repro.launch.serve_decode --arch llama3.2-1b \
      --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.models import init_params, make_decode_step, make_prefill_step
from repro.models.decode import init_cache


def greedy_generate(cfg, params, prompts, gen_tokens: int, max_len: int):
    """prompts: (B, P) int32.  Returns (B, gen_tokens)."""
    b, p = prompts.shape
    shape = InputShape("serve", max_len, b, "decode")
    cache = init_cache(cfg, shape)
    # empty-cache start: mark all slots invalid, then prefill token-by-token
    cache = dict(cache)
    if "k_pos" in cache and cache["k_pos"] is not None:
        cache["k_pos"] = jnp.full_like(cache["k_pos"], -1)
    step = jax.jit(make_decode_step(cfg, shape), donate_argnums=(1,))

    tok = prompts[:, :1]
    out = []
    for pos in range(p + gen_tokens - 1):
        logits, cache = step(params, cache,
                             {"token": tok, "pos": jnp.asarray(pos, jnp.int32)})
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        if pos + 1 < p:
            tok = prompts[:, pos + 1: pos + 2]  # teacher-forced prefill
        else:
            tok = nxt
            out.append(nxt)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = init_params(cfg, args.seed)
    key = jax.random.PRNGKey(args.seed)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = greedy_generate(cfg, params, prompts, args.gen,
                           max_len=args.prompt_len + args.gen)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"served {args.batch} requests ({total} tokens) in {dt:.1f}s "
          f"({total/dt:.0f} tok/s incl. compile)")
    print("sample generations:", toks[:2].tolist())


if __name__ == "__main__":
    main()
