"""Federated round driver: warm the round path, plan rounds, validate.

Entry point for the federated subsystem (:mod:`repro.federated`) through
the serving layer: AOT-warm the round kernel at every configured
population bucket, then plan ``--rounds`` federated rounds over
synthetic candidate populations (mixed link families — Gilbert-Elliott
burst chains are the natural stragglers) and print each round's
participant count, straggler-bounded round time and aggregated bound.

  PYTHONPATH=src python -m repro.launch.federated \\
      --devices 64 --rounds 4 --pop-buckets 64 --grid 64 \\
      --models all --verify --simulate \\
      --metrics-textfile metrics.prom

``--verify`` re-plans every round with the pure-numpy reference
(:func:`repro.federated.plan_round_reference`) and exits 1 on any
participant-set or operating-point mismatch; ``--simulate`` runs the
first round end-to-end through :class:`repro.federated.
FederatedSimulator` (sharded local SGD + deadline-gated averaging) on a
small synthetic ridge task.  Exit codes: 2 on unknown names (usage), 1
on post-warmup traces or a parity mismatch, 0 otherwise.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

import numpy as np

from repro.federated import FederatedSimulator, plan_round_reference
from repro.serve import (ALL_MODELS, PlanningService, ServiceConfig,
                         parse_models, synth_population)
from repro.serve.export import write_textfile


def _parse_buckets(spec: str):
    try:
        buckets = tuple(int(s) for s in spec.split(",") if s.strip())
    except ValueError as e:
        raise ValueError(f"bad bucket list {spec!r}: {e}") from None
    if not buckets:
        raise ValueError(f"bad bucket list {spec!r}: no buckets")
    return buckets


def run_federated(args) -> int:
    try:
        models = parse_models(args.models)
        config = ServiceConfig(
            grid_size=args.grid, batch_buckets=(8,),
            grid_modes=("dense",), objective_ids=("corollary1",),
            population_buckets=_parse_buckets(args.pop_buckets),
            n_max=args.n_max, warm_models=models,
            journal_path=args.journal)
        # fail fast on unknown model names before paying warmup
        synth_population(1, seed=args.seed, models=models,
                         n_max=args.n_max)
    except (KeyError, ValueError) as e:
        print(f"error: {e.args[0] if isinstance(e, KeyError) else e}",
              file=sys.stderr)
        return 2

    service = PlanningService(config)
    n_traces = service.warmup()
    print(f"warmup: {n_traces} kernel traces in "
          f"{service.warmup_seconds:.2f}s over "
          f"{len(config.population_buckets)} population bucket(s) "
          f"{list(config.population_buckets)}")

    mismatches = 0
    records = []
    for r in range(args.rounds):
        pop, deadline = synth_population(
            args.devices, seed=args.seed + r, models=models,
            n_max=args.n_max, deadline_frac=args.deadline_frac)
        record = service.submit_round(pop, deadline=deadline)
        records.append((pop, deadline, record))
        if record.feasible:
            print(f"round {r}: K={record.n_participants} of "
                  f"{record.n_eligible} eligible "
                  f"({len(pop)} candidates); round_time="
                  f"{record.round_time:.1f} of deadline={deadline:.1f}; "
                  f"F={record.objective_value:.6g}")
        else:
            print(f"round {r}: INFEASIBLE — no device can deliver by "
                  f"deadline={deadline:.1f}")
        if args.verify:
            ref = plan_round_reference(pop, service.consts,
                                       deadline=deadline,
                                       grid_size=args.grid).record()
            if (ref.participants != record.participants
                    or ref.n_c != record.n_c or ref.rate != record.rate):
                mismatches += 1
                print(f"round {r}: PARITY MISMATCH vs numpy reference\n"
                      f"  served:    {record.participants} {record.n_c}\n"
                      f"  reference: {ref.participants} {ref.n_c}",
                      file=sys.stderr)

    if args.simulate and records:
        pop, deadline, record = next(
            ((p, d, rec) for p, d, rec in records if rec.feasible),
            records[0])
        if record.feasible:
            from repro.data.synthetic import make_regression_dataset
            X, y, _ = make_regression_dataset(n=512, d=8, seed=args.seed)
            from repro.core.scenario import RidgeTask
            plan = service.round_planner.plan_round(
                pop, service.consts, deadline=deadline,
                pad_to=service._population_bucket(len(pop)))
            report = FederatedSimulator().run_round(
                pop, plan, RidgeTask(X=X, y=y), seed=args.seed)
            print(f"simulate: {report.n_completed}/"
                  f"{len(report.participants)} participants completed "
                  f"by T={report.deadline:.1f}; aggregated ridge loss "
                  f"{report.aggregated_loss:.4f}")
        else:
            print("simulate: skipped (no feasible round)")

    stats = service.stats()
    post = stats.counters.get("post_warmup_traces", 0)
    print(f"post-warmup jit traces: {post} "
          f"({'SLO met' if post == 0 else 'SLO VIOLATED'})")
    snap = service.federated.snapshot()
    print(f"rounds: {snap['rounds']} planned, "
          f"{snap['participants']} participants selected, "
          f"{snap['infeasible_rounds']} infeasible")
    if args.verify:
        print(f"verify: {mismatches} mismatches over {args.rounds} "
              f"round(s) vs the numpy reference")
    if args.metrics_textfile:
        write_textfile(service.metrics, args.metrics_textfile)
        print(f"metrics: wrote Prometheus textfile "
              f"{args.metrics_textfile}")
    service.journal.close()
    return 0 if post == 0 and mismatches == 0 else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=64,
                    help="candidate devices per round")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--models", default="all",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--grid", type=int, default=64,
                    help="per-device n_c grid width")
    ap.add_argument("--pop-buckets", default="64,256",
                    help="comma-separated pow2 population pad shapes "
                         "(AOT-warmed; rounds inside the largest pay no "
                         "trace)")
    ap.add_argument("--n-max", type=int, default=4096,
                    help="cap on drawn per-device dataset sizes")
    ap.add_argument("--deadline-frac", type=float, default=1.6,
                    help="round deadline as a multiple of the median "
                         "device dataset size")
    ap.add_argument("--verify", action="store_true",
                    help="check every round against the numpy reference "
                         "(exit 1 on mismatch)")
    ap.add_argument("--simulate", action="store_true",
                    help="run the first feasible round end-to-end through "
                         "FederatedSimulator on a synthetic ridge task")
    ap.add_argument("--metrics-textfile", default=None,
                    help="write the Prometheus text exposition here")
    ap.add_argument("--journal", default=None,
                    help="append audit events to this JSONL file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_federated(args)


if __name__ == "__main__":
    raise SystemExit(main())
