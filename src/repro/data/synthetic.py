"""Synthetic datasets.

``make_regression_dataset`` reproduces the statistics the paper reports for
its California-Housing ridge-regression experiment (Sec. 5): N=18576 samples,
8 features, data-Gramian extreme eigenvalues matched to the paper's
L = 1.908 (largest) and c = 0.061 (smallest).  sklearn/network are
unavailable offline, so we synthesise a set with the same spectrum — the
paper's *claims* (bound-optimal block size close to experimental optimum,
overhead/block-size trend, pipelining gain) are spectrum-level properties.

``token_batches`` generates deterministic LM token streams for the
streaming-trainer examples and smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


def make_regression_dataset(n: int = 18_576, d: int = 8, *,
                            l_max: float = 1.908, l_min: float = 0.061,
                            noise: float = 0.3, seed: int = 0):
    """Returns (X, y, w_true).  Gramian (1/N) X^T X has spectrum in
    [l_min, l_max] with the extremes matched exactly."""
    rng = np.random.default_rng(seed)
    # orthonormal basis
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    eigs = np.concatenate([[l_min], np.exp(
        rng.uniform(np.log(l_min), np.log(l_max), d - 2)), [l_max]])
    Z = rng.standard_normal((n, d))
    Z = (Z - Z.mean(0)) / Z.std(0)
    # orthogonalise columns so the sample Gramian hits the target spectrum
    U, _, Vt = np.linalg.svd(Z, full_matrices=False)
    X = U @ np.diag(np.sqrt(n * eigs)) @ Vt @ Q.T
    w_true = rng.standard_normal(d)
    y = X @ w_true + noise * rng.standard_normal(n)
    return X.astype(np.float32), y.astype(np.float32), w_true.astype(np.float32)


@dataclass
class SyntheticTokens:
    """Deterministic Zipf-ish token stream (for LM smoke training)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed + step)
        # Zipf-like marginal so the loss actually decreases during smoke runs
        ranks = np.arange(1, self.vocab_size + 1)
        p = 1.0 / ranks
        p /= p.sum()
        return rng.choice(self.vocab_size, size=(self.batch_size, self.seq_len),
                          p=p).astype(np.int32)


def token_batches(vocab_size: int, seq_len: int, batch_size: int,
                  steps: int, seed: int = 0) -> Iterator[np.ndarray]:
    src = SyntheticTokens(vocab_size, seq_len, batch_size, seed)
    for s in range(steps):
        yield src.batch(s)
