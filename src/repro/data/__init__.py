from repro.data.synthetic import (SyntheticTokens, make_regression_dataset,
                                  token_batches)
from repro.data.stream import BlockStreamer

__all__ = ["SyntheticTokens", "make_regression_dataset", "token_batches",
           "BlockStreamer"]
