"""Host-side block streamer — the device side of the paper's protocol.

Models the device->edge link of Fig. 1/2: the dataset lives on the "device"
(host); each block ``b`` delivers ``n_c`` new samples (chosen uniformly at
random from the not-yet-sent remainder, exactly as in Sec. 2) after a
block time of ``n_c + n_o`` normalised units.  The edge trainer consumes
blocks while training on what has already arrived.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class BlockStreamer:
    n_samples: int
    n_c: int
    n_o: float
    seed: int = 0
    _perm: np.ndarray = field(init=False, repr=False)
    _sent: int = field(init=False, default=0)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # uniform random selection without replacement == a random permutation
        # consumed prefix-first
        self._perm = rng.permutation(self.n_samples)

    @property
    def block_duration(self) -> float:
        return self.n_c + self.n_o

    @property
    def n_blocks_total(self) -> int:
        return -(-self.n_samples // self.n_c)

    def next_block(self) -> Optional[np.ndarray]:
        """Indices delivered by the next block (None when exhausted)."""
        if self._sent >= self.n_samples:
            return None
        idx = self._perm[self._sent: self._sent + self.n_c]
        self._sent += len(idx)
        return idx

    @property
    def delivered(self) -> int:
        return self._sent

    def reset(self):
        self._sent = 0
