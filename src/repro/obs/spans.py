"""Per-request lifecycle spans: the enqueue-to-plan latency, decomposed.

A service that reports one opaque enqueue-to-plan number cannot be
steered: 318 ms might be queue backlog (add workers), flush-deadline
wait (shrink the interval), padding waste (re-bucket), or a slow solve
(optimise the kernel) — four different fixes.  :class:`RequestSpan`
attaches the decomposition to every request:

    enqueue --(batch_wait)--> chunk start --(pad)--> plan_many
            --(cache_lookup)--> --(solve [device|host])--> --(resolve)-->
            future resolved

The phases are CONTIGUOUS intervals cut from the same monotonic clock,
so ``batch_wait + pad + cache_lookup + solve + resolve == latency``
exactly (``resolve`` is defined as the remainder after the measured
sub-intervals, absorbing per-chunk bookkeeping; the serving tests assert
the sum).  ``admit_s`` — admission-policy routing BEFORE the request
enters the queue — is recorded but sits outside the enqueue-to-plan
window, matching how the SLO is stated.  ``solve_device_s <= solve_s``
is the ``block_until_ready``-fenced device portion of the solve (see
:mod:`repro.obs.runtime`).

:class:`SpanRecorder` keeps completed spans in a fixed-capacity ring
(old spans fall off; an always-on service cannot keep every trace) plus
running phase TOTALS that survive ring eviction — the totals are what
the solve-fraction SLO and the Prometheus export read, so they must
cover the whole lifetime, not the window.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List

#: Phase names, in lifecycle order.  Their durations partition the
#: enqueue-to-plan latency exactly.
PHASES = ("batch_wait", "pad", "cache_lookup", "solve", "resolve")


@dataclass(frozen=True)
class RequestSpan:
    """One completed request trace.  Durations are seconds; chunk-level
    phases (pad/cache/solve/resolve) are shared by every request solved
    in the same micro-batch chunk, ``batch_wait`` is per-request."""

    objective: str
    grid_mode: str
    bucket: int
    enqueue_t: float        # perf_counter at enqueue (clock origin)
    admit_s: float          # pre-enqueue admission routing (outside SLO)
    batch_wait_s: float     # enqueue -> chunk taken by the worker
    pad_s: float            # chunk formation + bucket selection
    cache_lookup_s: float   # quantised-key cache probe inside plan_many
    solve_s: float          # plan_batch wall clock (host view)
    solve_device_s: float   # block_until_ready-fenced device portion
    resolve_s: float        # record fan-out + future resolution remainder
    latency_s: float        # enqueue -> future resolved (the SLO number)

    @property
    def phase_sum(self) -> float:
        return (self.batch_wait_s + self.pad_s + self.cache_lookup_s
                + self.solve_s + self.resolve_s)

    def phases(self) -> Dict[str, float]:
        return {"batch_wait": self.batch_wait_s, "pad": self.pad_s,
                "cache_lookup": self.cache_lookup_s, "solve": self.solve_s,
                "resolve": self.resolve_s}


class SpanRecorder:
    """Thread-safe fixed-capacity ring of :class:`RequestSpan` plus
    lifetime phase totals.  One lock acquisition per request — the
    overhead budget is <= 5% of serve-bench throughput, asserted by the
    bench's throughput floor."""

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._ring: Deque[RequestSpan] = deque(maxlen=capacity)
        self._totals = {name: 0.0 for name in PHASES}
        self._totals["admit"] = 0.0
        self._totals["solve_device"] = 0.0
        self._totals["latency"] = 0.0
        self._count = 0

    def record(self, span: RequestSpan) -> None:
        with self._lock:
            self._ring.append(span)
            t = self._totals
            t["batch_wait"] += span.batch_wait_s
            t["pad"] += span.pad_s
            t["cache_lookup"] += span.cache_lookup_s
            t["solve"] += span.solve_s
            t["resolve"] += span.resolve_s
            t["admit"] += span.admit_s
            t["solve_device"] += span.solve_device_s
            t["latency"] += span.latency_s
            self._count += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def recorded(self) -> int:
        """Lifetime span count (>= ring length once the ring wraps)."""
        with self._lock:
            return self._count

    def snapshot(self) -> List[RequestSpan]:
        """The ring's current window, oldest first."""
        with self._lock:
            return list(self._ring)

    def totals(self) -> Dict[str, float]:
        """Lifetime phase-duration totals (seconds) plus ``count``."""
        with self._lock:
            out = dict(self._totals)
            out["count"] = self._count
            return out

    @property
    def solve_fraction(self) -> float:
        """Lifetime solve share of enqueue-to-plan latency — the number
        that says whether the service is compute-bound (optimise the
        kernel) or wait-bound (tune batching); 0.0 before any span."""
        with self._lock:
            lat = self._totals["latency"]
            return self._totals["solve"] / lat if lat > 0.0 else 0.0

    def phase_means_ms(self) -> Dict[str, float]:
        """Mean per-request phase durations in milliseconds (the
        human-readable breakdown the CLI and bench print)."""
        with self._lock:
            if self._count == 0:
                return {name: 0.0 for name in (*PHASES, "latency")}
            return {name: self._totals[name] / self._count * 1e3
                    for name in (*PHASES, "latency")}
