"""MetricsRegistry: one snapshot over every counter source, exportable.

The serving stack grew counters in four places — ``StatsRecorder``,
``PlanCache.stats()``, the ``repro.fleet.tracing`` trace events, and the
session drift audit log — each with its own snapshot call and naming.
:class:`MetricsRegistry` unifies them: sources register a zero-argument
callable returning :class:`Metric` families, ``collect()`` merges them
(same name + kind merge their samples; a name registered under two KINDS
raises — that is a bug, not a merge), and the result renders as
Prometheus text exposition (:func:`render_prometheus`).

The registry's :meth:`~MetricsRegistry.snapshot` is deliberately
``parse_exposition(render_prometheus(collect()))`` — every programmatic
read round-trips through the wire format, so an export that stopped
parsing fails the first test or CI gate that looks at any metric, not a
Prometheus scrape three deploys later.

:func:`parse_exposition` is a STRICT parser of the Prometheus text
format (names, label escaping, float values, histogram structure:
``le``-cumulative monotonicity and ``_sum``/``_count`` presence).  It is
dependency-free on purpose: CI validates the textfile dump with it, and
``prometheus_client`` — when installed — is only a cross-check in the
test suite, never a requirement.
"""
from __future__ import annotations

import math
import re
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Tuple, Union

from repro.obs.hist import LogHistogram

KINDS = ("counter", "gauge", "histogram")

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# one sample line: name, optional {labels}, value (labels parsed apart)
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})?\s+(\S+)$")
_LABEL_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

Labels = Tuple[Tuple[str, str], ...]
Value = Union[float, LogHistogram]


def _labels_key(labels: Dict[str, str]) -> Labels:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclass
class Metric:
    """One metric family: a name, a kind, and labelled samples.

    ``samples`` maps a label dict to a float (counter/gauge) or a
    :class:`~repro.obs.hist.LogHistogram` (histogram).  Counter names
    follow the Prometheus convention of a ``_total`` suffix; histogram
    values render as ``_bucket``/``_sum``/``_count`` series.
    """

    name: str
    kind: str
    help: str = ""
    samples: List[Tuple[Dict[str, str], Value]] = field(default_factory=list)

    def __post_init__(self):
        if not _NAME_RE.match(self.name):
            raise ValueError(f"invalid metric name {self.name!r}")
        if self.kind not in KINDS:
            raise ValueError(
                f"invalid metric kind {self.kind!r}; valid: {KINDS}")

    def add(self, value: Value, **labels) -> "Metric":
        for k in labels:
            if not _LABEL_NAME_RE.match(k):
                raise ValueError(f"invalid label name {k!r}")
        self.samples.append((dict(labels), value))
        return self


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _render_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def render_prometheus(metrics: Sequence[Metric]) -> str:
    """Prometheus text exposition (format version 0.0.4) of the metric
    families, deterministically ordered (by name, then label set) so
    textfile dumps diff cleanly between scrapes."""
    lines: List[str] = []
    for m in sorted(metrics, key=lambda m: m.name):
        if m.help:
            lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        samples = sorted(m.samples, key=lambda s: _labels_key(s[0]))
        for labels, value in samples:
            if m.kind == "histogram":
                if not isinstance(value, LogHistogram):
                    raise TypeError(
                        f"{m.name}: histogram samples must be LogHistogram, "
                        f"got {type(value).__name__}")
                for le, n in value.cumulative():
                    ll = dict(labels)
                    ll["le"] = "+Inf" if math.isinf(le) else _fmt_value(le)
                    lines.append(
                        f"{m.name}_bucket{_render_labels(ll)} {n}")
                lines.append(f"{m.name}_sum{_render_labels(labels)} "
                             f"{_fmt_value(value.sum)}")
                lines.append(f"{m.name}_count{_render_labels(labels)} "
                             f"{value.count}")
            else:
                lines.append(f"{m.name}{_render_labels(labels)} "
                             f"{_fmt_value(float(value))}")
    return "\n".join(lines) + "\n"


def _unescape_label(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, c + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return math.inf
    if tok == "-Inf":
        return -math.inf
    if tok == "NaN":
        return math.nan
    try:
        return float(tok)
    except ValueError:
        raise ValueError(f"bad sample value {tok!r}") from None


def parse_exposition(text: str) -> Dict[str, Dict[Labels, float]]:
    """Strict parse of Prometheus text exposition back into
    ``{metric_name: {sorted_label_tuple: value}}``.

    Raises ``ValueError`` on anything malformed: bad names, unparseable
    label pairs, non-float values, a histogram whose ``le``-cumulative
    bucket counts decrease, or a histogram missing its ``_sum`` /
    ``_count`` series.  The CI metrics smoke step runs this over the
    dumped textfile, so an export regression fails the build.
    """
    out: Dict[str, Dict[Labels, float]] = OrderedDict()
    types: Dict[str, str] = {}
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 2 and parts[1] in ("HELP", "TYPE"):
                if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                    raise ValueError(f"line {ln}: malformed {parts[1]} line")
                if parts[1] == "TYPE":
                    kind = parts[3] if len(parts) > 3 else ""
                    if kind not in KINDS:
                        raise ValueError(
                            f"line {ln}: unknown metric type {kind!r}")
                    types[parts[2]] = kind
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample line {raw!r}")
        name, _, label_blob, value_tok = m.groups()
        labels: Dict[str, str] = {}
        if label_blob:
            consumed = 0
            for lm in _LABEL_RE.finditer(label_blob):
                labels[lm.group(1)] = _unescape_label(lm.group(2))
                consumed = lm.end()
            rest = label_blob[consumed:].strip().strip(",").strip()
            if rest:
                raise ValueError(
                    f"line {ln}: malformed labels {label_blob!r}")
        out.setdefault(name, OrderedDict())[_labels_key(labels)] = \
            _parse_value(value_tok)

    # histogram structure validation
    for name, kind in types.items():
        if kind != "histogram":
            continue
        buckets = out.get(name + "_bucket", {})
        if not buckets:
            raise ValueError(f"histogram {name} has no _bucket series")
        if name + "_sum" not in out or name + "_count" not in out:
            raise ValueError(f"histogram {name} missing _sum/_count")
        by_series: Dict[Labels, List[Tuple[float, float]]] = {}
        for labels, v in buckets.items():
            rest = tuple((k, val) for k, val in labels if k != "le")
            le = dict(labels)["le"]
            by_series.setdefault(rest, []).append((_parse_value(le), v))
        for rest, series in by_series.items():
            series.sort(key=lambda t: t[0])
            counts = [n for _, n in series]
            if any(b < a for a, b in zip(counts, counts[1:])):
                raise ValueError(
                    f"histogram {name}{dict(rest)} has non-monotone "
                    f"cumulative buckets: {counts}")
            if not math.isinf(series[-1][0]):
                raise ValueError(
                    f"histogram {name}{dict(rest)} lacks a +Inf bucket")
    return out


class MetricsRegistry:
    """Named metric sources behind one collect/snapshot/export surface.

    A source is a zero-argument callable returning a list of
    :class:`Metric`; sources are invoked at collect time, so they snapshot
    live state (locks are the source's business).  Same-name same-kind
    families from different sources merge their samples; a kind conflict
    raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._sources: "OrderedDict[str, Callable[[], List[Metric]]]" = \
            OrderedDict()

    def register_source(self, name: str,
                        fn: Callable[[], List[Metric]]) -> None:
        with self._lock:
            if name in self._sources:
                raise ValueError(f"metric source {name!r} already registered")
            self._sources[name] = fn

    def unregister_source(self, name: str) -> None:
        with self._lock:
            if self._sources.pop(name, None) is None:
                raise KeyError(f"unknown metric source {name!r}")

    def sources(self) -> List[str]:
        with self._lock:
            return list(self._sources)

    def collect(self) -> List[Metric]:
        with self._lock:
            sources = list(self._sources.items())
        merged: "OrderedDict[str, Metric]" = OrderedDict()
        for source_name, fn in sources:
            for metric in fn():
                have = merged.get(metric.name)
                if have is None:
                    merged[metric.name] = Metric(
                        metric.name, metric.kind, metric.help,
                        list(metric.samples))
                elif have.kind != metric.kind:
                    raise ValueError(
                        f"metric {metric.name!r} registered as both "
                        f"{have.kind!r} and {metric.kind!r} "
                        f"(source {source_name!r})")
                else:
                    have.samples.extend(metric.samples)
        return list(merged.values())

    def prometheus_text(self) -> str:
        return render_prometheus(self.collect())

    def snapshot(self) -> Dict[str, Dict[Labels, float]]:
        """Collect, render, and re-parse — the returned mapping is what a
        Prometheus scrape would see, and taking it validates the export
        end to end."""
        return parse_exposition(self.prometheus_text())

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """One sample's value from a fresh snapshot (``default`` when the
        series does not exist — absent counters read as zero)."""
        series = self.snapshot().get(name)
        if not series:
            return default
        return series.get(_labels_key(labels), default)

    def write_textfile(self, path: str) -> str:
        """Dump the exposition to ``path`` atomically (write-then-rename,
        the node-exporter textfile-collector contract: a scrape never
        sees a half-written file).  Returns the rendered text."""
        import os
        text = self.prometheus_text()
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        return text
