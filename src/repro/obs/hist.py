"""Log-spaced mergeable histograms and exact-window reservoirs.

Two bounded-memory representations of a latency distribution, for two
different jobs:

  * :class:`LogHistogram` — fixed log-spaced buckets whose counts MERGE
    by addition (associative and commutative, enforced by the property
    tests), so per-(objective, grid mode, bucket) histograms roll up
    into one service-wide distribution, and histograms from many service
    instances roll up into one fleet-wide distribution, without ever
    shipping raw samples.  Percentiles are geometric interpolation
    within a bucket: relative error is bounded by the bucket width
    (``10^(1/per_decade)``, ~26% at the default 10/decade), which is the
    usual dashboard trade for O(1) memory and mergeability.
  * :class:`Reservoir` — a raw-sample window keeping the most recent
    half on overflow, for EXACT percentiles where sample counts are
    small (per-micro-batch solve latencies).  Halving keeps the window
    describing recent traffic — what an SLO dashboard wants — and the
    continuity test pins that halving cannot jump the percentiles of a
    stationary stream.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import numpy as np


def percentiles(samples, qs=(50.0, 99.0)) -> Tuple[float, ...]:
    """Percentiles of a sample list; zeros when there are no samples yet
    (a fresh service must report finite stats, never NaN)."""
    if not len(samples):
        return tuple(0.0 for _ in qs)
    arr = np.asarray(samples, np.float64)
    return tuple(float(np.percentile(arr, q)) for q in qs)


class Reservoir:
    """Bounded raw-sample window: beyond ``max_samples`` the buffer drops
    its OLDER half, so percentiles describe recent traffic.  Not
    internally locked — callers that share one across threads hold their
    own lock (as :class:`repro.serve.stats.StatsRecorder` did when this
    logic lived there)."""

    def __init__(self, max_samples: int = 65536):
        if max_samples < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self.max_samples = max_samples
        self._samples: List[float] = []

    def record(self, x: float) -> None:
        self._samples.append(float(x))
        if len(self._samples) > self.max_samples:
            del self._samples[:len(self._samples) // 2]

    def __len__(self) -> int:
        return len(self._samples)

    @property
    def samples(self) -> List[float]:
        return list(self._samples)

    def percentiles(self, qs=(50.0, 99.0)) -> Tuple[float, ...]:
        return percentiles(self._samples, qs)


class LogHistogram:
    """Fixed log-spaced histogram over ``(0, +inf)`` seconds.

    Buckets span ``[lo, hi]`` with ``per_decade`` geometric buckets per
    decade; samples below ``lo`` land in an underflow bucket (reported
    as ``<= lo``), samples above ``hi`` in an overflow bucket (reported
    via the tracked exact max).  ``merge`` adds counts/sum/count and
    takes the max — integer counts make the merge exactly associative,
    the property the fleet roll-up relies on.
    """

    __slots__ = ("lo", "hi", "per_decade", "edges", "counts",
                 "count", "sum", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 per_decade: int = 10):
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if per_decade < 1:
            raise ValueError(f"per_decade must be >= 1, got {per_decade}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.per_decade = int(per_decade)
        n = int(math.ceil((math.log10(hi) - math.log10(lo)) * per_decade))
        # edges[0] == lo; edges[-1] >= hi (the last decade may be partial)
        self.edges = [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]
        # counts[0] = underflow (<= lo); counts[1+i] covers
        # (edges[i], edges[i+1]]; counts[-1] = overflow (> edges[-1])
        self.counts = [0] * (n + 2)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        if x > self.edges[-1]:
            return len(self.counts) - 1
        # ceil of the log-position: x in (edges[i], edges[i+1]] -> 1 + i
        pos = (math.log10(x) - math.log10(self.lo)) * self.per_decade
        idx = int(math.ceil(pos - 1e-12))
        return min(max(idx, 1), len(self.counts) - 2)

    def record(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x) or x < 0.0:
            raise ValueError(f"histogram samples must be finite >= 0: {x}")
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x

    def compatible(self, other: "LogHistogram") -> bool:
        return (self.lo == other.lo and self.hi == other.hi
                and self.per_decade == other.per_decade)

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """In-place add of ``other``'s counts (returns self).  Raises on
        mismatched bucket layouts — silently merging different layouts
        would corrupt both distributions."""
        if not self.compatible(other):
            raise ValueError(
                f"cannot merge histograms with different layouts: "
                f"(lo={self.lo}, hi={self.hi}, /dec={self.per_decade}) vs "
                f"(lo={other.lo}, hi={other.hi}, /dec={other.per_decade})")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.max = max(self.max, other.max)
        return self

    def copy(self) -> "LogHistogram":
        out = LogHistogram(self.lo, self.hi, self.per_decade)
        out.counts = list(self.counts)
        out.count = self.count
        out.sum = self.sum
        out.max = self.max
        return out

    @classmethod
    def merged(cls, hists: Sequence["LogHistogram"]) -> "LogHistogram":
        """Out-of-place merge of any number of histograms (empty default
        layout when ``hists`` is empty)."""
        hists = list(hists)
        if not hists:
            return cls()
        out = hists[0].copy()
        for h in hists[1:]:
            out.merge(h)
        return out

    def percentile(self, q: float) -> float:
        """The q-th percentile by geometric interpolation within the
        containing bucket; 0.0 when empty.  Clamped to the tracked exact
        max so high quantiles never exceed an observed sample."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, int(math.ceil(q / 100.0 * self.count)))
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                frac = (rank - cum) / c
                if i == 0:                       # underflow: <= lo
                    return min(self.lo, self.max)
                if i == len(self.counts) - 1:    # overflow: > last edge
                    return self.max
                lo, hi = self.edges[i - 1], self.edges[i]
                return min(lo * (hi / lo) ** frac, self.max)
            cum += c
        return self.max  # unreachable when counts sum to count

    def cumulative(self) -> List[Tuple[float, int]]:
        """Prometheus-style cumulative buckets ``[(le, n_le), ...,
        (inf, count)]``: ``n_le`` counts samples ``<= le``."""
        out: List[Tuple[float, int]] = []
        cum = self.counts[0]
        out.append((self.edges[0], cum))
        for i in range(1, len(self.counts) - 1):
            cum += self.counts[i]
            out.append((self.edges[i], cum))
        out.append((math.inf, self.count))
        return out

    def to_dict(self) -> Dict[str, object]:
        """JSON-serialisable snapshot (round-trips via
        :meth:`from_dict`); counts are sparse ``{bucket_index: n}``."""
        return {
            "lo": self.lo, "hi": self.hi, "per_decade": self.per_decade,
            "counts": {str(i): c for i, c in enumerate(self.counts) if c},
            "count": self.count, "sum": self.sum, "max": self.max,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "LogHistogram":
        out = cls(float(d["lo"]), float(d["hi"]), int(d["per_decade"]))
        for i, c in dict(d["counts"]).items():
            out.counts[int(i)] = int(c)
        out.count = int(d["count"])
        out.sum = float(d["sum"])
        out.max = float(d["max"])
        return out
