"""Device-vs-host solve attribution and the profiler capture hook.

``plan_batch`` wall clock conflates two very different costs: device
compute (the jitted solve itself) and host work (padding, transfer,
``np.asarray`` materialisation).  The kernel wrappers in
``repro.fleet.objective_kernels`` fence the jitted call with
``jax.block_until_ready`` and report both portions here via
:func:`record_solve`; the serving layer brackets each micro-batch chunk
with :func:`solve_delta` to read back exactly the solve time that chunk
incurred.

Accumulators are kept BOTH process-global (:func:`solve_totals`, for
whole-run reporting) and per-thread (what :func:`solve_delta` reads) —
the test suite runs several services concurrently, and a per-thread
delta cannot be contaminated by another service's worker solving at the
same moment.

:func:`profile_capture` is the opt-in ``jax.profiler`` hook
(``--profile-dir`` on the serve CLI): a no-op unless a directory is
given, import-guarded so environments without the profiler plugin still
serve.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

_LOCK = threading.Lock()
_GLOBAL = {"device_s": 0.0, "host_s": 0.0, "calls": 0}
_TLS = threading.local()


def _tls_totals() -> Dict[str, float]:
    t = getattr(_TLS, "totals", None)
    if t is None:
        t = _TLS.totals = {"device_s": 0.0, "host_s": 0.0, "calls": 0}
    return t


def record_solve(device_s: float, host_s: float = 0.0) -> None:
    """Called by the kernel solve wrappers after every fenced solve.
    ``device_s`` is the ``block_until_ready``-fenced jitted-call
    duration; ``host_s`` the host-side materialisation that follows."""
    device_s = max(0.0, float(device_s))
    host_s = max(0.0, float(host_s))
    with _LOCK:
        _GLOBAL["device_s"] += device_s
        _GLOBAL["host_s"] += host_s
        _GLOBAL["calls"] += 1
    t = _tls_totals()
    t["device_s"] += device_s
    t["host_s"] += host_s
    t["calls"] += 1


def solve_totals() -> Dict[str, float]:
    """Process-lifetime solve attribution across all threads."""
    with _LOCK:
        return dict(_GLOBAL)


@dataclass
class SolveDelta:
    """Solve time accrued on THIS thread inside a :func:`solve_delta`
    block.  Live while the block runs, frozen at exit."""

    device_s: float = 0.0
    host_s: float = 0.0
    calls: int = 0

    @property
    def total_s(self) -> float:
        return self.device_s + self.host_s


@contextmanager
def solve_delta() -> Iterator[SolveDelta]:
    """Measure solve time recorded by the current thread within the
    block.  Per-thread on purpose: a service worker bracketing its own
    ``plan_many`` call must not absorb another worker's solves."""
    t = _tls_totals()
    before = dict(t)
    delta = SolveDelta()
    try:
        yield delta
    finally:
        delta.device_s = t["device_s"] - before["device_s"]
        delta.host_s = t["host_s"] - before["host_s"]
        delta.calls = int(t["calls"] - before["calls"])


@contextmanager
def profile_capture(profile_dir: Optional[str]) -> Iterator[None]:
    """Wrap a block in a ``jax.profiler`` trace written to
    ``profile_dir`` (view with TensorBoard / Perfetto).  Falsy dir ->
    no-op; a missing/broken profiler degrades to a no-op rather than
    taking the service down with it."""
    if not profile_dir:
        yield
        return
    try:
        from jax import profiler
        profiler.start_trace(profile_dir)
    except Exception:
        yield
        return
    try:
        yield
    finally:
        try:
            profiler.stop_trace()
        except Exception:
            pass
