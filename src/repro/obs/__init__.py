"""Observability subsystem: spans, histograms, metrics, event journal.

The serving stack's measurement layer, deliberately free of any
``repro.serve`` / ``repro.fleet`` imports so every layer (kernels,
planner, service, CLIs, benches) can flow through it without cycles:

  * :mod:`repro.obs.hist` — log-spaced MERGEABLE histograms (the
    bounded-memory latency representation a fleet of service instances
    can aggregate by addition) plus the exact-window :class:`Reservoir`;
  * :mod:`repro.obs.spans` — the per-request lifecycle trace (enqueue ->
    admit -> batch-wait -> bucket/pad -> cache lookup -> solve ->
    resolve) in a low-overhead ring buffer, decomposing the
    enqueue-to-plan latency EXACTLY into phases;
  * :mod:`repro.obs.metrics` — :class:`MetricsRegistry` unifying every
    counter source behind one snapshot, with Prometheus text exposition
    (:func:`render_prometheus`) and a strict parser
    (:func:`parse_exposition`) so exports are validated, not assumed;
  * :mod:`repro.obs.journal` — the JSONL event journal (audit log for
    drift / re-plan / session lifecycle events);
  * :mod:`repro.obs.runtime` — device-vs-host solve attribution via
    ``block_until_ready`` timing fences inside the jitted kernels, and
    the optional ``jax.profiler`` capture hook.
"""
from repro.obs.hist import LogHistogram, Reservoir, percentiles
from repro.obs.journal import EventJournal, read_jsonl
from repro.obs.metrics import (Metric, MetricsRegistry, parse_exposition,
                               render_prometheus)
from repro.obs.runtime import (SolveDelta, profile_capture, record_solve,
                               solve_delta, solve_totals)
from repro.obs.spans import PHASES, RequestSpan, SpanRecorder

__all__ = [
    "EventJournal", "LogHistogram", "Metric", "MetricsRegistry", "PHASES",
    "RequestSpan", "Reservoir", "SolveDelta", "SpanRecorder",
    "parse_exposition", "percentiles", "profile_capture", "read_jsonl",
    "record_solve", "render_prometheus", "solve_delta", "solve_totals",
]
