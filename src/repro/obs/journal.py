"""JSONL event journal: the serving audit log.

Counters say HOW MANY drift re-plans happened; the journal says WHICH
session drifted, when, from what observed loss, and what the service did
— the record an operator replays after an incident.  Events are plain
dicts stamped with a wall-clock ``ts`` and a ``kind``; they live in a
bounded in-memory ring (for ``tail()`` and the per-kind counters the
metrics layer exports) and, when a path is given, are appended to a
JSONL file one event per line — the exporter format the CLI's
``--journal`` flag wires up.

The file sink is bounded and optionally durable: with ``max_bytes`` set
the journal rotates size-based (``path`` -> ``path.1`` -> ... ->
``path.<keep>``, oldest dropped) so a long-lived service can't fill the
disk, and ``fsync=True`` fsyncs every appended event — the
crash-journal posture, where the record of what the service decided
must survive the service dying mid-decision.  :func:`read_jsonl` reads
a rotated set back in emission order.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional


def _rotated_paths(path: str) -> List[str]:
    """Existing rotated siblings of ``path`` (``path.N``), oldest
    (highest N) first — prepend to ``path`` for full emission order."""
    directory, name = os.path.split(path)
    prefix = name + "."
    indices = []
    for entry in os.listdir(directory or "."):
        if entry.startswith(prefix):
            suffix = entry[len(prefix):]
            if suffix.isdigit():
                indices.append(int(suffix))
    return [os.path.join(directory, f"{name}.{i}")
            for i in sorted(indices, reverse=True)]


def read_jsonl(path: str) -> List[dict]:
    """Parse a JSONL file back into event dicts (strict: a malformed
    line raises — an audit log that silently skips records is worse than
    none).  A rotated set (``path.N`` ... ``path.1`` + ``path``) is read
    oldest-first, so callers see one continuous event stream."""
    out: List[dict] = []
    for p in _rotated_paths(path) + [path]:
        if p != path and not os.path.exists(p):
            continue
        with open(p) as f:
            for i, line in enumerate(f):
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{p}:{i + 1}: bad JSONL line: {e}") from None
    return out


class EventJournal:
    """Thread-safe bounded event ring with optional JSONL file sink.

    ``emit`` stamps ``ts`` (``time.time()``, wall clock: audit logs are
    correlated with external systems, unlike the spans' monotonic clock)
    and appends; the file (when configured) is opened lazily on first
    emit and written line-buffered so a crash loses at most the final
    event.  ``max_bytes`` > 0 turns on size-based rotation keeping
    ``keep`` rotated files; ``fsync=True`` makes every event durable
    before ``emit`` returns.  ``close()`` (or context-manager exit)
    flushes and detaches the sink; in-memory emission keeps working
    afterwards.
    """

    def __init__(self, capacity: int = 4096, path: Optional[str] = None,
                 *, max_bytes: int = 0, keep: int = 3,
                 fsync: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.path = path
        self.max_bytes = int(max_bytes)
        self.keep = int(keep)
        self.fsync = bool(fsync)
        self.rotations = 0
        self._lock = threading.Lock()
        self._ring: Deque[dict] = deque(maxlen=capacity)
        self._counts: Dict[str, int] = {}
        self._count = 0
        self._file = None
        self._closed = False

    def _rotate_locked(self) -> None:
        """Shift ``path`` -> ``path.1`` -> ... (lock held, file open).
        The oldest file past ``keep`` is dropped."""
        self._file.close()
        self._file = None
        oldest = f"{self.path}.{self.keep}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        os.replace(self.path, f"{self.path}.1")
        self.rotations += 1

    def emit(self, kind: str, **fields) -> dict:
        event = {"ts": time.time(), "kind": str(kind), **fields}
        line = json.dumps(event, default=str, sort_keys=True)
        with self._lock:
            self._ring.append(event)
            self._counts[event["kind"]] = \
                self._counts.get(event["kind"], 0) + 1
            self._count += 1
            if self.path is not None and not self._closed:
                if self._file is None:
                    self._file = open(self.path, "a", buffering=1)
                self._file.write(line + "\n")
                if self.fsync:
                    self._file.flush()
                    os.fsync(self._file.fileno())
                if self.max_bytes > 0 \
                        and self._file.tell() >= self.max_bytes:
                    self._rotate_locked()
        return event

    def counts(self) -> Dict[str, int]:
        """Lifetime per-kind event counts (survive ring eviction)."""
        with self._lock:
            return dict(self._counts)

    @property
    def emitted(self) -> int:
        with self._lock:
            return self._count

    def tail(self, n: int = 20) -> List[dict]:
        """The most recent ``n`` events, oldest first."""
        with self._lock:
            events = list(self._ring)
        return events[-max(0, int(n)):]

    def close(self) -> None:
        with self._lock:
            self._closed = True
            if self._file is not None:
                self._file.close()
                self._file = None

    def __enter__(self) -> "EventJournal":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
