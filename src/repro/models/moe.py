"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Two sharding regimes (see DESIGN.md §4):
  * ``tp``  — experts replicated over the ``model`` axis, each expert's FFN
              hidden dim tensor-parallel (natural when num_experts < axis size,
              e.g. Mixtral 8e over a 16-way axis).  No all-to-all.
  * ``ep``  — experts sharded over ``model`` (expert parallelism; DeepSeekMoE
              64e).  GSPMD inserts the dispatch all-to-all from the
              token-sharded input to the expert-sharded buffers.

Dispatch is sort-based (argsort tokens by expert id, gather into per-expert
capacity slots, einsum, scatter-add back with gate weights) — the dropped-token
capacity formulation; capacity_factor bounds memory.  The jnp reference
``moe_dense_reference`` computes every expert for every token and is the
oracle for tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import truncated_normal


def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, m.d_ff_expert ** -0.5
    params = {
        "router": truncated_normal(keys[0], (d, m.num_experts), s_in, jnp.float32),
        "gate": truncated_normal(keys[1], (m.num_experts, d, m.d_ff_expert), s_in, dtype),
        "up": truncated_normal(keys[2], (m.num_experts, d, m.d_ff_expert), s_in, dtype),
        "down": truncated_normal(keys[3], (m.num_experts, m.d_ff_expert, d), s_out, dtype),
    }
    if m.num_shared_experts:
        ff_shared = m.num_shared_experts * m.d_ff_expert
        ks = jax.random.split(keys[4], 3)
        params["shared"] = {
            "gate": truncated_normal(ks[0], (d, ff_shared), s_in, dtype),
            "up": truncated_normal(ks[1], (d, ff_shared), s_in, dtype),
            "down": truncated_normal(ks[2], (ff_shared, d), ff_shared ** -0.5, dtype),
        }
    return params


def _router(params, x2d, m: MoEConfig):
    """x2d: (T, d) -> gates (T, k), experts (T, k), aux load-balance loss."""
    logits = (x2d.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # renormalise
    # Switch-style aux loss: E * sum_e f_e * p_e
    counts = jnp.zeros((m.num_experts,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = counts / (x2d.shape[0] * m.top_k)
    p = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f * p) * m.router_aux_loss_coef
    return gates, experts, aux


def _expert_ffn(params, h):
    """h: (E, C, d) -> (E, C, d) via per-expert gated MLP."""
    g = jnp.einsum("ecd,edf->ecf", h, params["gate"])
    u = jnp.einsum("ecd,edf->ecf", h, params["up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["down"])


def _dispatch_group(x2d, gates, experts, e: int, k: int, capacity: int):
    """Single-group sort-based dispatch.

    x2d: (T, d); gates/experts: (T, k).
    Returns expert_in (e, capacity, d), and (dest, token_idx, keep_gate) for
    the combine step.  Runs entirely within one data shard under vmap.
    """
    t, d = x2d.shape
    flat_expert = experts.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(t * k) - starts[sorted_expert]
    keep = pos_in_expert < capacity

    token_idx = order // k
    dest = sorted_expert * capacity + jnp.where(keep, pos_in_expert, 0)
    dest = jnp.where(keep, dest, e * capacity)  # overflow slot (dropped)

    gathered = x2d[token_idx]
    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype).at[dest].set(gathered)
    expert_in = buf[: e * capacity].reshape(e, capacity, d)
    keep_gate = jnp.where(keep, gates.reshape(-1)[order], 0.0)
    return expert_in, dest, token_idx, keep_gate


def _combine_group(expert_out, dest, token_idx, keep_gate, t: int):
    """expert_out: (e, capacity, d) -> y (t, d)."""
    e, c, d = expert_out.shape
    flat = jnp.concatenate(
        [expert_out.reshape(e * c, d), jnp.zeros((1, d), expert_out.dtype)])
    contrib = flat[dest] * keep_gate[:, None].astype(expert_out.dtype)
    return jnp.zeros((t, d), expert_out.dtype).at[token_idx].add(contrib)


def moe_ffn(params, x, cfg: ArchConfig, *, capacity_factor=None):
    """x: (B, S, d) -> (B, S, d), aux_loss.

    Grouped sort-based capacity dispatch (GShard-style): routing + sort are
    LOCAL per batch row (the data-sharded dim), so no global argsort; the
    only cross-shard movement is the (data -> model) exchange of the
    (B, E, C, d) dispatch buffer, which GSPMD lowers to an all-to-all when
    experts are model-sharded (EP) and to nothing under TP."""
    m = cfg.moe
    b, s, d = x.shape
    k, e = m.top_k, m.num_experts
    capacity_factor = m.capacity_factor if capacity_factor is None else capacity_factor
    capacity = int(max(k, capacity_factor * s * k / e))
    capacity = -(-capacity // 8) * 8 if capacity > 8 else capacity

    x2d = x.reshape(b * s, d)
    gates, experts, aux = _router(params, x2d, m)
    gates_g = gates.reshape(b, s, k)
    experts_g = experts.reshape(b, s, k)

    expert_in, dest, token_idx, keep_gate = jax.vmap(
        lambda xg, gg, eg: _dispatch_group(xg, gg, eg, e, k, capacity)
    )(x.reshape(b, s, d), gates_g, experts_g)
    # expert_in: (B, e, capacity, d) — B over data, e over model (EP).
    # the scatter inside the vmapped dispatch blocks GSPMD propagation:
    # without the explicit hint the partitioner replicates the whole
    # (B, E, C, d) buffer (measured 40 GiB/buffer on mixtral prefill_32k)
    from repro.models.shard_hints import maybe_constrain
    expert_in = maybe_constrain(
        expert_in, (["pod_data"], ["model"], None, None))

    def ffn(h):  # h: (B, e, c, d)
        g = jnp.einsum("becd,edf->becf", h, params["gate"])
        u = jnp.einsum("becd,edf->becf", h, params["up"])
        return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, params["down"])

    # chunk the capacity dim so the (B, e, c, d_ff) intermediates stay small
    # at long sequence lengths (32k prefill: c ~ 10k -> GBs per buffer)
    ffn_chunk = 2048
    if capacity > ffn_chunk and capacity % ffn_chunk == 0:
        nch = capacity // ffn_chunk
        h_c = jnp.moveaxis(
            expert_in.reshape(b, e, nch, ffn_chunk, d), 2, 0)

        def ffn_body(_, hc):
            return None, ffn(hc)

        _, out_c = jax.lax.scan(jax.checkpoint(ffn_body), None, h_c)
        expert_out = jnp.moveaxis(out_c, 0, 2).reshape(b, e, capacity, d)
    else:
        expert_out = ffn(expert_in)
    expert_out = maybe_constrain(
        expert_out, (["pod_data"], ["model"], None, None))
    y = jax.vmap(lambda eo, de, ti, kg: _combine_group(eo, de, ti, kg, s))(
        expert_out, dest, token_idx, keep_gate)
    y2d = y.reshape(b * s, d)

    if m.num_shared_experts:
        sh = params["shared"]
        hshared = jax.nn.silu(x2d @ sh["gate"]) * (x2d @ sh["up"])
        y2d = y2d + hshared @ sh["down"]
    return y2d.reshape(b, s, d), aux


def moe_dense_reference(params, x, cfg: ArchConfig):
    """Oracle: compute all experts for all tokens, weight by (renormalised)
    top-k gates.  Matches moe_ffn exactly when no token overflows capacity."""
    m = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, experts, aux = _router(params, x2d, m)
    dense_gates = jnp.zeros((b * s, m.num_experts), jnp.float32)
    dense_gates = dense_gates.at[jnp.arange(b * s)[:, None], experts].set(gates)

    g = jnp.einsum("td,edf->tef", x2d, params["gate"])
    u = jnp.einsum("td,edf->tef", x2d, params["up"])
    out = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["down"])
    y2d = jnp.einsum("ted,te->td", out, dense_gates.astype(out.dtype))
    if m.num_shared_experts:
        sh = params["shared"]
        hshared = jax.nn.silu(x2d @ sh["gate"]) * (x2d @ sh["up"])
        y2d = y2d + hshared @ sh["down"]
    return y2d.reshape(b, s, d), aux
