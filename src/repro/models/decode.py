"""Single-token decode (serve) path with KV / SSM caches.

Cache layouts (stacked over layers, scanned):
  dense/vlm:  {"k","v": (L, B, Sc, Hkv, hd), "k_pos": (Sc,)}
  mla:        {"latent": (L, B, Sc, rank), "krope": (L, B, Sc, rope), "k_pos"}
  moe:        dense layout (+ dense_first caches for DeepSeekMoE)
  ssm:        {"conv_x","conv_B","conv_C","ssm": (L, B, ...)}
  hybrid:     mamba caches (n_mamba, ...) + attn caches (n_attn, ...)
  audio:      self cache (L, ...) + precomputed cross K/V (L, B, F, H, hd)

Ring caches (sliding-window / window+sink long-context decode) keep
``sink`` absolute slots followed by a ``window``-slot ring; ``k_pos`` stores
the absolute position held by each slot (-1 = empty).  Keys are rotated
(RoPE) at write time with their absolute position, so only masking needs
``k_pos`` at read time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import attention as attn
from repro.models import mamba2
from repro.models import moe as moe_mod
from repro.models.layers import embed, mlp, rmsnorm, unembed
from repro.models.transformer import lm_head_table

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def cache_length(cfg: ArchConfig, shape: InputShape) -> Tuple[int, int]:
    """(cache_slots, sink) for attention caches under this input shape."""
    if shape.name == "long_500k" and cfg.long_context_variant in ("window", "window_global", "ssm"):
        if cfg.sliding_window is None:
            return 0, 0
        sink = 128 if cfg.long_context_variant == "window_global" else 0
        return cfg.sliding_window + sink, sink
    return shape.seq_len, 0


def init_cache(cfg: ArchConfig, shape: InputShape, batch: int = None):
    """Zeros cache pytree for a decode step at context length shape.seq_len."""
    b = batch if batch is not None else shape.global_batch
    dtype = jnp.dtype(cfg.dtype)
    sc, sink = cache_length(cfg, shape)
    kpos = _initial_kpos(sc, sink, shape.seq_len)

    def attn_kv(n_layers, heads):
        return {
            "k": jnp.zeros((n_layers, b, sc, heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n_layers, b, sc, heads, cfg.head_dim), dtype),
        }

    if cfg.family in ("dense", "vlm"):
        if cfg.attention_type == "mla":
            m = cfg.mla
            return {
                "latent": jnp.zeros((cfg.num_layers, b, sc, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((cfg.num_layers, b, sc, m.qk_rope_head_dim), dtype),
                "k_pos": kpos,
            }
        return dict(attn_kv(cfg.num_layers, cfg.num_kv_heads), k_pos=kpos)
    if cfg.family == "moe":
        c = dict(attn_kv(cfg.num_layers - cfg.moe.first_dense_layers,
                         cfg.num_kv_heads), k_pos=kpos)
        if cfg.moe.first_dense_layers:
            c["dense_first"] = attn_kv(cfg.moe.first_dense_layers, cfg.num_kv_heads)
        return c
    if cfg.family == "ssm":
        per = mamba2.init_mamba_cache(cfg, b, dtype)
        return {"mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), per)}
    if cfg.family == "hybrid":
        from repro.models.transformer import _hybrid_layout
        n_attn, n_mamba, *_ = _hybrid_layout(cfg)
        per = mamba2.init_mamba_cache(cfg, b, dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_mamba,) + a.shape), per),
            **attn_kv(n_attn, cfg.num_kv_heads),
            "k_pos": kpos,
        }
    if cfg.family == "audio":
        c = dict(attn_kv(cfg.num_layers, cfg.num_kv_heads), k_pos=kpos)
        c["cross_k"] = jnp.zeros(
            (cfg.num_layers, b, cfg.encoder_frames, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c
    raise ValueError(cfg.family)


def _initial_kpos(sc: int, sink: int, context: int):
    """k_pos for a cache that already holds ``context`` tokens."""
    if sc == 0:
        return None
    if sc >= context:  # full cache
        return jnp.where(jnp.arange(sc) < context, jnp.arange(sc), -1).astype(jnp.int32)
    # ring: slots [0, sink) hold positions 0..sink; ring part holds the last
    # (sc - sink) positions in rotated order
    window = sc - sink
    ring_slot = jnp.arange(window)
    # position p occupies slot sink + (p - sink) % window
    newest = context - 1
    pos = newest - ((sink + (newest - sink) % window) - (sink + ring_slot)) % window
    pos_ring = jnp.where(pos >= sink, pos, -1)
    return jnp.concatenate([jnp.arange(sink), pos_ring]).astype(jnp.int32)


def _ring_slot(pos, sc: int, sink: int, context_is_ring: bool):
    if not context_is_ring:
        return pos
    window = sc - sink
    return jnp.where(pos < sink, pos, sink + (pos - sink) % window)


# ---------------------------------------------------------------------------
# Per-layer decode attention
# ---------------------------------------------------------------------------


def _decode_attend(q, k, v, k_pos, pos, *, window, sink, softcap, scale=None):
    """q: (B, 1, H, D); k/v: (B, Sc, Hkv, D); k_pos: (Sc,)."""
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window is not None:
        in_win = (pos - k_pos < window)
        if sink:
            in_win |= k_pos < sink
        valid &= in_win
    mask = valid[None, None, None, None, :]
    return attn.dot_product_attention(q, k, v, mask=mask, logit_softcap=softcap,
                                      scale=scale)


def _gqa_decode_layer(lp, x, ck, cv, k_pos, pos, slot, cfg, *, window, sink):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wq"])
    kn = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wk"])
    vn = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wv"])
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    kn = attn.apply_rope(kn, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_index_in_dim(ck, kn[:, 0], slot, axis=1)
    cv = jax.lax.dynamic_update_index_in_dim(cv, vn[:, 0], slot, axis=1)
    out = _decode_attend(q, ck, cv, k_pos, pos, window=window, sink=sink,
                         softcap=cfg.attn_logit_softcap)
    out = attn.apply_head_mask(out, cfg)
    x = x + jnp.einsum("bshe,hed->bsd", out, lp["attn"]["wo"])
    return x, ck, cv


def _mla_decode_layer(lp, x, clat, ckr, k_pos, pos, slot, cfg):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    m = cfg.mla
    ap = lp["attn"]
    cq = attn._rms(h @ ap["wq_a"], ap["q_norm_scale"], cfg.norm_eps)
    ckv = h @ ap["wkv_a"]
    lat_new, kr_new = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    lat_new = attn._rms(lat_new, ap["kv_norm_scale"], cfg.norm_eps)
    clat = jax.lax.dynamic_update_index_in_dim(clat, lat_new[:, 0], slot, axis=1)
    ckr = jax.lax.dynamic_update_index_in_dim(ckr, kr_new[:, 0], slot, axis=1)

    b, sc = clat.shape[0], clat.shape[1]
    q_positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    k_positions = jnp.broadcast_to(jnp.maximum(k_pos, 0)[None], (b, sc))
    q, k, v = attn._mla_qkv_from_latent(ap, cq, clat, ckr, q_positions,
                                        k_positions, cfg)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = _decode_attend(q, k, v, k_pos, pos, window=None, sink=0,
                         softcap=None, scale=scale)
    out = attn.apply_head_mask(out, cfg)
    x = x + jnp.einsum("bshe,hed->bsd", out, ap["wo"])
    return x, clat, ckr


# ---------------------------------------------------------------------------
# decode_step per family
# ---------------------------------------------------------------------------


def decode_step(params, cache, batch, cfg: ArchConfig, shape: InputShape):
    """One-token decode.  batch = {"token": (B, 1) int32, "pos": () int32}.

    Returns (logits (B, vocab), new_cache).
    """
    token, pos = batch["token"], batch["pos"]
    x = embed(params["embed"], token)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    sc, sink = cache_length(cfg, shape)
    is_ring = sc < shape.seq_len and sc > 0
    window = cfg.sliding_window if (cfg.sliding_window and
                                    (is_ring or cfg.family == "moe"
                                     or cfg.local_global_period > 1)) else None

    if cfg.family in ("dense", "vlm"):
        if cfg.attention_type == "mla":
            x, cache = _decode_mla_stack(params, cache, x, pos, sc, sink, is_ring, cfg)
        else:
            x, cache = _decode_dense_stack(params, cache, x, pos, sc, sink,
                                           is_ring, window, cfg)
    elif cfg.family == "moe":
        x, cache = _decode_moe_stack(params, cache, x, pos, sc, sink, is_ring,
                                     window, cfg)
    elif cfg.family == "ssm":
        x, cache = _decode_ssm_stack(params, cache, x, cfg)
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid_stack(params, cache, x, pos, sc, sink,
                                        is_ring, window, cfg)
    elif cfg.family == "audio":
        x, cache = _decode_audio_stack(params, cache, x, pos, sc, sink, cfg)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(lm_head_table(params, cfg), x[:, 0], cfg.final_logit_softcap)
    return logits, cache


def _decode_dense_stack(params, cache, x, pos, sc, sink, is_ring, window, cfg):
    slot = _ring_slot(pos, sc, sink, is_ring)
    k_pos = cache["k_pos"].at[slot].set(pos)
    period = cfg.local_global_period

    if period > 1:
        groups = cfg.num_layers // period
        ck = cache["k"].reshape((groups, period) + cache["k"].shape[1:])
        cv = cache["v"].reshape((groups, period) + cache["v"].shape[1:])

        def body(h, xs):
            gp, gk, gv = xs
            ks, vs = [], []
            for i in range(period):
                lp = jax.tree.map(lambda a: a[i], gp)
                local = i % period != period - 1
                # local layers: always sliding window.  global layers: full
                # attention, except the long_500k window+sink ring variant.
                w = cfg.sliding_window if (local or is_ring) else None
                snk = sink if (not local and is_ring) else 0
                h, nk, nv = _gqa_decode_layer(lp, h, gk[i], gv[i], k_pos, pos,
                                              slot, cfg, window=w, sink=snk)
                h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
                ks.append(nk)
                vs.append(nv)
            return h, (jnp.stack(ks), jnp.stack(vs))

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], ck, cv))
        cache = dict(cache, k=nk.reshape(cache["k"].shape),
                     v=nv.reshape(cache["v"].shape), k_pos=k_pos)
        return x, cache

    def body(h, xs):
        lp, lk, lv = xs
        h, nk, nv = _gqa_decode_layer(lp, h, lk, lv, k_pos, pos, slot, cfg,
                                      window=window, sink=sink)
        h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    return x, dict(cache, k=nk, v=nv, k_pos=k_pos)


def _decode_mla_stack(params, cache, x, pos, sc, sink, is_ring, cfg):
    slot = pos  # MLA decode is full-cache only (long_500k skipped)
    k_pos = cache["k_pos"].at[slot].set(pos)

    def body(h, xs):
        lp, lat, kr = xs
        h, nlat, nkr = _mla_decode_layer(lp, h, lat, kr, k_pos, pos, slot, cfg)
        h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (nlat, nkr)

    x, (nlat, nkr) = jax.lax.scan(
        body, x, (params["layers"], cache["latent"], cache["krope"]))
    return x, dict(cache, latent=nlat, krope=nkr, k_pos=k_pos)


def _decode_moe_stack(params, cache, x, pos, sc, sink, is_ring, window, cfg):
    slot = _ring_slot(pos, sc, sink, is_ring)
    k_pos = cache["k_pos"].at[slot].set(pos)

    if "dense_first" in params:
        df = cache["dense_first"]
        nks, nvs = [], []
        for i in range(cfg.moe.first_dense_layers):
            lp = jax.tree.map(lambda a: a[i], params["dense_first"])
            x, nk, nv = _gqa_decode_layer(lp, x, df["k"][i], df["v"][i], k_pos,
                                          pos, slot, cfg, window=window, sink=sink)
            x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            nks.append(nk)
            nvs.append(nv)
        cache = dict(cache, dense_first={"k": jnp.stack(nks), "v": jnp.stack(nvs)})

    def body(h, xs):
        lp, lk, lv = xs
        h, nk, nv = _gqa_decode_layer(lp, h, lk, lv, k_pos, pos, slot, cfg,
                                      window=window, sink=sink)
        y, _ = moe_mod.moe_ffn(lp["moe"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg)
        return h + y, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    return x, dict(cache, k=nk, v=nv, k_pos=k_pos)


def _decode_ssm_stack(params, cache, x, cfg):
    def body(h, xs):
        lp, mc = xs
        hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
        y, nmc = mamba2.mamba_decode(lp["mamba"], hn, mc, cfg)
        return h + y, nmc

    x, new_mamba = jax.lax.scan(body, x, (params["layers"], cache["mamba"]))
    return x, dict(cache, mamba=new_mamba)


def _decode_hybrid_stack(params, cache, x, pos, sc, sink, is_ring, window, cfg):
    from repro.models.transformer import _hybrid_layout
    n_attn, n_mamba, groups, per_group, tail = _hybrid_layout(cfg)
    slot = _ring_slot(pos, sc, sink, is_ring)
    k_pos = cache["k_pos"].at[slot].set(pos)
    shared = params["shared_attn"]
    w = cfg.sliding_window if is_ring else None

    mg = jax.tree.map(
        lambda a: a[: groups * per_group].reshape((groups, per_group) + a.shape[1:]),
        params["mamba_groups"])
    mc_flat = jax.tree.map(lambda a: a[: groups * per_group], cache["mamba"])
    mc = jax.tree.map(
        lambda a: a.reshape((groups, per_group) + a.shape[1:]), mc_flat)

    def group_body(h, xs):
        gp, gmc, gk, gv = xs
        h, nk, nv = _gqa_decode_layer(shared, h, gk, gv, k_pos, pos, slot, cfg,
                                      window=w, sink=sink)
        h = h + mlp(shared["mlp"], rmsnorm(shared["ln2"], h, cfg.norm_eps))

        def inner(hh, ys):
            lp, lmc = ys
            hn = rmsnorm(lp["ln"], hh, cfg.norm_eps)
            y, nmc = mamba2.mamba_decode(lp["mamba"], hn, lmc, cfg)
            return hh + y, nmc

        h, nmc = jax.lax.scan(inner, h, (gp, gmc))
        return h, (nmc, nk, nv)

    gk = cache["k"][:groups]
    gv = cache["v"][:groups]
    x, (nmc, nk, nv) = jax.lax.scan(group_body, x, (mg, mc, gk, gv))
    new_mamba = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), nmc)
    new_k, new_v = nk, nv

    if tail:
        x, tk, tv = _gqa_decode_layer(shared, x, cache["k"][groups],
                                      cache["v"][groups], k_pos, pos, slot, cfg,
                                      window=w, sink=sink)
        x = x + mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps))
        new_k = jnp.concatenate([new_k, tk[None]])
        new_v = jnp.concatenate([new_v, tv[None]])
        tails = []
        for i in range(tail):
            lp = jax.tree.map(lambda a: a[i], params["mamba_tail"])
            lmc = jax.tree.map(lambda a: a[groups * per_group + i], cache["mamba"])
            hn = rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, nmc_t = mamba2.mamba_decode(lp["mamba"], hn, lmc, cfg)
            x = x + y
            tails.append(nmc_t)
        tail_stacked = jax.tree.map(lambda *a: jnp.stack(a), *tails)
        new_mamba = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                 new_mamba, tail_stacked)

    return x, dict(cache, mamba=new_mamba, k=new_k, v=new_v, k_pos=k_pos)


def _decode_audio_stack(params, cache, x, pos, sc, sink, cfg):
    slot = pos
    k_pos = cache["k_pos"].at[slot].set(pos)

    def body(h, xs):
        lp, lk, lv, xk, xv = xs
        h, nk, nv = _gqa_decode_layer(lp, h, lk, lv, k_pos, pos, slot, cfg,
                                      window=None, sink=0)
        # cross attention against precomputed encoder K/V
        hn = rmsnorm(lp["ln_cross"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", hn, lp["cross"]["wq"])
        out = attn.dot_product_attention(q, xk, xv)
        h = h + jnp.einsum("bshe,hed->bsd", out, lp["cross"]["wo"])
        h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    return x, dict(cache, k=nk, v=nv, k_pos=k_pos)
