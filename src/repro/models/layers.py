"""Shared building blocks: norms, rotary embeddings, gated MLP, embeddings.

Plain functional JAX: each module is an ``init_*`` returning a pytree of
arrays plus an ``apply`` function.  No framework dependency — pytrees shard
cleanly under ``jax.jit`` + ``NamedSharding`` and stack cleanly for
scan-over-layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "gate": truncated_normal(kg, (d_model, d_ff), s_in, dtype),
        "up": truncated_normal(ku, (d_model, d_ff), s_in, dtype),
        "down": truncated_normal(kd, (d_ff, d_model), s_out, dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, dtype):
    return {"table": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(table, x, final_softcap=None):
    """table: (vocab, d_model) — tied or untied lm head."""
    logits = x @ table.T
    if final_softcap:
        logits = softcap(logits, final_softcap)
    return logits
