from repro.models.model import (abstract_cache, abstract_opt_state,
                                abstract_params, init_params, input_specs,
                                make_batch, make_decode_step, make_grad_fn,
                                make_prefill_step, make_train_step)

__all__ = [
    "abstract_cache", "abstract_opt_state", "abstract_params", "init_params",
    "input_specs", "make_batch", "make_decode_step", "make_grad_fn",
    "make_prefill_step", "make_train_step",
]
