"""Best-effort activation sharding hints.

``maybe_constrain(x, spec_candidates_per_dim)`` applies a
``with_sharding_constraint`` built from per-dim candidate axis lists, using
the first candidate whose mesh-axis product divides the dim.  No-op outside
a mesh context (CPU unit tests) — the model code stays mesh-agnostic.

GSPMD usually propagates shardings fine; the hints exist for the few ops
that block propagation (scatter/argsort in the MoE dispatch, kv-group
reshapes in GQA attention) where the partitioner otherwise REPLICATES the
whole computation (see EXPERIMENTS.md §Perf, optimized-sweep notes).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = ("pod_data",)  # sentinel: the (pod, data) batch axes


def _mesh():
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    return None if mesh.empty else mesh


def _resolve(dim: int, candidates, mesh) -> Optional[object]:
    """First candidate axis (or axis tuple) that divides ``dim``."""
    for cand in candidates:
        if cand == "pod_data":
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            if not axes:
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size == 0:
                return axes if len(axes) > 1 else axes[0]
            if "data" in axes and dim % mesh.shape["data"] == 0:
                return "data"
            continue
        if cand in mesh.axis_names and dim % mesh.shape[cand] == 0:
            return cand
    return None


def maybe_constrain(x, dim_candidates: Sequence[Optional[List[str]]]):
    """dim_candidates[i]: list of axis candidates for dim i (None = leave
    replicated/unspecified)."""
    mesh = _mesh()
    if mesh is None:
        return x
    parts = []
    for dim, cands in zip(x.shape, dim_candidates):
        parts.append(_resolve(dim, cands, mesh) if cands else None)
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
