"""Blockwise (flash-style) attention in pure JAX.

Never materialises the (Q, K) logit matrix: outer ``lax.scan`` over query
blocks, inner ``lax.scan`` over key/value blocks with running-softmax
statistics.  This is the XLA path used by every train/prefill forward; the
Pallas kernel in ``repro.kernels.flash_attention`` implements the same
contract with VMEM tiling for TPU.

For sliding-window attention the inner scan is replaced by a single
``dynamic_slice`` of the (window + q_block)-wide key stripe per query block —
compute is proportional to the window, not the sequence.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def _block_mask(q_pos, k_pos, *, causal, window, sink):
    """q_pos: (qb,), k_pos: (kb,) -> bool (qb, kb), True = attend."""
    qp, kp = q_pos[:, None], k_pos[None, :]
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        in_window = qp - kp < window
        if sink:
            in_window |= kp < sink
        mask &= in_window
    mask &= kp >= 0  # padding slots carry k_pos = -1
    return mask


def _attend_block(q, k, v, mask, softcap, scale, m, l, acc):
    """One (q_block, k_block) tile of running-softmax attention.

    q: (B, qb, Hkv, G, D); k/v: (B, kb, Hkv, D); mask: (qb, kb);
    m, l: (B, Hkv, G, qb); acc: (B, Hkv, G, qb, Dv).
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = corr * l + jnp.sum(p, axis=-1)
    acc_new = corr[..., None] * acc + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, q_pos=None, k_pos=None, causal=True,
                    window: Optional[int] = None, sink: int = 0,
                    logit_softcap: Optional[float] = None, scale=None,
                    q_block: int = 512, k_block: int = 512):
    """q: (B, Q, H, D); k, v: (B, K, Hkv, Dk/Dv) -> (B, Q, H, Dv)."""
    b, qlen, h, d = q.shape
    klen, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    q_block = min(q_block, qlen)
    k_block = min(k_block, klen)

    if q_pos is None:
        q_pos = jnp.arange(qlen, dtype=jnp.int32)
    if k_pos is None:
        k_pos = jnp.arange(klen, dtype=jnp.int32)

    # pad to block multiples (padding keys get k_pos = -1 => masked)
    qpad = (-qlen) % q_block
    kpad = (-klen) % k_block
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, qpad), constant_values=0)
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, kpad), constant_values=-1)

    nq, nk = q.shape[1] // q_block, k.shape[1] // k_block
    qb = q.reshape(b, nq, q_block, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nq, q_block)
    kb = k.reshape(b, nk, k_block, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, k_block, hkv, dv).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nk, k_block)

    if window is not None and klen > 2 * (window + k_block):
        out = _windowed(qb, qp, k, v, k_pos, window=window, sink=sink,
                        softcap=logit_softcap, scale=scale)
    else:
        out = _full(qb, qp, kb, vb, kp, causal=causal, window=window, sink=sink,
                    softcap=logit_softcap, scale=scale)
    # out: (nq, B, qb, Hkv, G, Dv)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_block, h, dv)
    return out[:, :qlen]


def _full(qb, qp, kb, vb, kp, *, causal, window, sink, softcap, scale):
    """Scan q blocks (outer) x kv blocks (inner), masked."""
    nq, b, q_block, hkv, g, d = qb.shape
    dv = vb.shape[-1]

    def q_step(_, xq):
        qi, qpi = xq

        def kv_step(carry, xkv):
            m, l, acc = carry
            ki, vi, kpi = xkv
            mask = _block_mask(qpi, kpi, causal=causal, window=window, sink=sink)
            return _attend_block(qi, ki, vi, mask, softcap, scale, m, l, acc), None

        m0 = jnp.full((b, hkv, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, G, qb, Dv) -> (B, qb, Hkv, G, Dv)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None, (qb, qp))
    return outs.astype(vb.dtype)


def _windowed(qb, qp, k, v, k_pos, *, window, sink, softcap, scale):
    """Sliding-window: one dynamic_slice stripe of keys per query block."""
    nq, b, q_block, hkv, g, d = qb.shape
    dv = v.shape[-1]
    stripe = window + q_block  # enough to cover [q_start - window, q_end)
    # pad front so the stripe slice never goes out of bounds
    k = jnp.pad(k, ((0, 0), (stripe, 0), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (stripe, 0), (0, 0), (0, 0)))
    k_pos = jnp.pad(k_pos, (stripe, 0), constant_values=-1)

    sink_k = k[:, stripe:stripe + sink] if sink else None
    sink_v = v[:, stripe:stripe + sink] if sink else None
    sink_pos = k_pos[stripe:stripe + sink] if sink else None

    def q_step(_, xq):
        qi, qpi, qidx = xq
        start = qidx * q_block + q_block  # == (q_end - window) + padding offset
        ki = jax.lax.dynamic_slice_in_dim(k, start, stripe, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, start, stripe, axis=1)
        kpi = jax.lax.dynamic_slice_in_dim(k_pos, start, stripe, axis=0)

        m0 = jnp.full((b, hkv, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, dv), jnp.float32)
        mask = _block_mask(qpi, kpi, causal=True, window=window, sink=0)
        m, l, acc = _attend_block(qi, ki, vi, mask, softcap, scale, m0, l0, a0)
        if sink:
            # sink keys NOT already covered by the window stripe (avoid double
            # attending for early query blocks where the stripe reaches pos 0)
            smask = ((qpi[:, None] >= sink_pos[None, :])
                     & (sink_pos[None, :] >= 0)
                     & (qpi[:, None] - sink_pos[None, :] >= window))
            m, l, acc = _attend_block(qi, sink_k, sink_v, smask, softcap, scale,
                                      m, l, acc)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)

    idx = jnp.arange(nq, dtype=jnp.int32)
    _, outs = jax.lax.scan(q_step, None, (qb, qp, idx))
    return outs.astype(v.dtype)
