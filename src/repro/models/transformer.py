"""Unified decoder stack for all assigned architectures.

Layers execute under ``jax.lax.scan`` over stacked per-layer weights (HLO size
independent of depth; essential for 60-layer archs lowered onto 512 simulated
devices) with ``jax.checkpoint`` on the block body (remat).

Heterogeneous layer patterns are handled by *period grouping*: the stack is a
scan over groups, and the (static) in-group pattern is unrolled inside the
body — e.g. Gemma2 scans 21 groups of (local, global), Zamba2 scans 6 groups
of (shared-attn, 5 x mamba).  Weight stacking matches the grouping.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2, moe as moe_mod
from repro.models import runtime
from repro.models.blockwise import flash_attention as _flash_ad
from repro.models.flash_vjp import flash_attention_vjp as _flash_vjp
from repro.models.layers import (embed, init_embed, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm, truncated_normal, unembed)


def _constrain_qkv(x):
    """Batch over (pod, data), heads over model where divisible — GSPMD
    sometimes resolves the q(sharded-heads)/kv(replicated-heads) mismatch by
    replicating the whole attention computation (§Perf optimized-sweep
    note).  No-op outside a mesh context."""
    from repro.models.shard_hints import maybe_constrain
    return maybe_constrain(x, (["pod_data"], None, ["model"], None))


def flash_attention(q, k, v, **kw):
    """Dispatch: default-AD blockwise attention (baseline) vs the flash
    custom-VJP path (perf flag; see runtime.py and EXPERIMENTS.md §Perf).

    Long sliding-window sequences always use the AD stripe path: its compute
    is O(S*W) while the custom-VJP path is masked-full O(S^2) — measured
    4.4x regression on mixtral prefill_32k when routed through the VJP path
    (§Perf, optimized-sweep note)."""
    window = kw.get("window")
    klen = k.shape[1]
    stripe_wins = window is not None and klen > 2 * (window + 512)
    if runtime.flag("flash_vjp") and not stripe_wins:
        kw.pop("q_pos", None)
        kw.pop("k_pos", None)
        return _flash_vjp(q, k, v, **kw)
    return _flash_ad(q, k, v, **kw)

# ---------------------------------------------------------------------------
# Layer init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ArchConfig, dtype):
    if cfg.attention_type == "mla":
        return attn.init_mla(key, cfg, dtype)
    return attn.init_gqa(key, cfg, dtype)


def init_attn_mlp_layer(key, cfg: ArchConfig, dtype, *, d_ff=None, cross=False):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model),
    }
    d_ff = cfg.d_ff if d_ff is None else d_ff
    if d_ff:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, d_ff, dtype)
    if cross:
        p["ln_cross"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn.init_gqa(ks[2], cfg, dtype)
    return p


def init_moe_layer(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model),
        "moe": moe_mod.init_moe(ks[1], cfg, dtype),
    }


def init_mamba_layer(key, cfg: ArchConfig, dtype):
    return {"ln": init_rmsnorm(cfg.d_model), "mamba": mamba2.init_mamba(key, cfg, dtype)}


# ---------------------------------------------------------------------------
# Attention forward (train/prefill): returns output and the layer's cache
# ---------------------------------------------------------------------------


def attn_forward(ap, x, positions, cfg: ArchConfig, *, causal=True,
                 window=None, sink=0, kv_seq=None):
    """kv_seq: cross-attention source (B, F, d) — keys/values from there."""
    src = x if kv_seq is None else kv_seq
    q = _constrain_qkv(jnp.einsum("bsd,dhe->bshe", x, ap["wq"]))
    k = _constrain_qkv(jnp.einsum("bsd,dhe->bshe", src, ap["wk"]))
    v = _constrain_qkv(jnp.einsum("bsd,dhe->bshe", src, ap["wv"]))
    if kv_seq is None:  # self-attention: rope
        q = attn.apply_rope(q, positions, cfg.rope_theta)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        out = flash_attention(q, k, v, causal=causal, window=window, sink=sink,
                              logit_softcap=cfg.attn_logit_softcap)
    else:  # cross-attention: bidirectional, no rope
        out = flash_attention(q, k, v, causal=False)
    out = attn.apply_head_mask(out, cfg)
    return jnp.einsum("bshe,hed->bsd", out, ap["wo"]), (k, v)


def _mla_flash(ap, x, positions, cfg):
    """MLA with blockwise attention on the expanded heads."""
    m = cfg.mla
    cq = attn._rms(x @ ap["wq_a"], ap["q_norm_scale"], cfg.norm_eps)
    ckv = x @ ap["wkv_a"]
    latent_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    latent_kv = attn._rms(latent_kv, ap["kv_norm_scale"], cfg.norm_eps)
    q, k, v = attn._mla_qkv_from_latent(ap, cq, latent_kv, k_rope,
                                        positions, positions, cfg)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = flash_attention(q, k, v, causal=True, scale=scale)
    out = attn.apply_head_mask(out, cfg)
    return jnp.einsum("bshe,hed->bsd", out, ap["wo"]), (latent_kv, k_rope)


def attn_mlp_layer(lp, x, positions, cfg: ArchConfig, *, window=None, sink=0,
                   enc_out=None, d_ff=True):
    """Pre-norm attention + (optional cross-attn) + pre-norm MLP."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.attention_type == "mla":
        a, cache = _mla_flash(lp["attn"], h, positions, cfg)
    else:
        a, cache = attn_forward(lp["attn"], h, positions, cfg,
                                window=window, sink=sink)
    x = x + a
    if enc_out is not None:
        h = rmsnorm(lp["ln_cross"], x, cfg.norm_eps)
        a, cross_cache = attn_forward(lp["cross"], h, positions, cfg, kv_seq=enc_out)
        x = x + a
        cache = cache + cross_cache
    if "mlp" in lp:
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x, cache


def moe_layer(lp, x, positions, cfg: ArchConfig, *, window=None):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    a, cache = attn_forward(lp["attn"], h, positions, cfg, window=window)
    x = x + a
    y, aux = moe_mod.moe_ffn(lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg)
    return x + y, cache, aux


def mamba_layer(lp, x, cfg: ArchConfig):
    y, cache = mamba2.mamba_block(lp["mamba"], rmsnorm(lp["ln"], x, cfg.norm_eps), cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# Model init (per family)
# ---------------------------------------------------------------------------


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_model(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params = {
        "embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(keys[1], cfg.vocab_size, cfg.d_model, dtype)

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_period > 1:
            p = cfg.local_global_period
            groups = cfg.num_layers // p
            params["layers"] = _stack_init(
                lambda k: _stack_init(
                    lambda k2: init_attn_mlp_layer(k2, cfg, dtype), k, p),
                keys[2], groups)
        else:
            params["layers"] = _stack_init(
                lambda k: init_attn_mlp_layer(k, cfg, dtype), keys[2], cfg.num_layers)
    elif cfg.family == "moe":
        m = cfg.moe
        n_moe = cfg.num_layers - m.first_dense_layers
        params["layers"] = _stack_init(
            lambda k: init_moe_layer(k, cfg, dtype), keys[2], n_moe)
        if m.first_dense_layers:
            params["dense_first"] = _stack_init(
                lambda k: init_attn_mlp_layer(k, cfg, dtype, d_ff=m.first_dense_d_ff),
                keys[3], m.first_dense_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(
            lambda k: init_mamba_layer(k, cfg, dtype), keys[2], cfg.num_layers)
    elif cfg.family == "hybrid":
        n_attn, n_mamba, groups, per_group, tail = _hybrid_layout(cfg)
        params["shared_attn"] = init_attn_mlp_layer(keys[2], cfg, dtype)
        params["mamba_groups"] = _stack_init(
            lambda k: init_mamba_layer(k, cfg, dtype), keys[3], groups * per_group)
        if tail:
            params["mamba_tail"] = _stack_init(
                lambda k: init_mamba_layer(k, cfg, dtype), keys[4], tail)
    elif cfg.family == "audio":
        params["encoder"] = _stack_init(
            lambda k: init_attn_mlp_layer(k, cfg, dtype), keys[2], cfg.encoder_layers)
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
        params["layers"] = _stack_init(
            lambda k: init_attn_mlp_layer(k, cfg, dtype, cross=True),
            keys[3], cfg.num_layers)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params


def _hybrid_layout(cfg: ArchConfig):
    """Zamba2 layout: layer i is shared-attn iff i % attn_every == 0."""
    kinds = ["attn" if i % cfg.attn_every == 0 else "mamba"
             for i in range(cfg.num_layers)]
    groups = cfg.num_layers // cfg.attn_every
    per_group = cfg.attn_every - 1  # mamba layers per full group
    covered = groups * cfg.attn_every
    tail_layers = kinds[covered:]  # e.g. ['attn', 'mamba'] for 38 = 6*6+2
    n_attn = sum(k == "attn" for k in kinds)
    n_mamba = sum(k == "mamba" for k in kinds)
    return n_attn, n_mamba, groups, per_group, len([k for k in tail_layers if k == "mamba"])


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(params, batch, cfg: ArchConfig):
    """Token (+ stub modality) embedding.  Returns (B, S, d) and loss mask."""
    tok_emb = embed(params["embed"], batch["tokens"])
    if cfg.scale_embed:
        tok_emb = tok_emb * jnp.asarray(cfg.d_model ** 0.5, tok_emb.dtype)
    if cfg.modality == "vision":
        # stub frontend: precomputed patch embeddings prepended to the text
        x = jnp.concatenate([batch["patch_embed"].astype(tok_emb.dtype), tok_emb],
                            axis=1)
        n_img = batch["patch_embed"].shape[1]
        loss_mask = jnp.concatenate([
            jnp.zeros((x.shape[0], n_img), bool),
            jnp.ones_like(batch["tokens"], bool)], axis=1)
        return x, loss_mask
    return tok_emb, jnp.ones_like(batch["tokens"], bool)


def _layer_window(cfg: ArchConfig, seq_len: int, local: bool):
    """Window/sink for a layer at train/prefill time."""
    if not local or cfg.sliding_window is None:
        # global layer: full attention, except the documented long-context
        # window+sink variant (gemma2 long_500k path is decode-only; prefill
        # keeps full attention for globals)
        return None, 0
    return cfg.sliding_window, 0


def forward(params, batch, cfg: ArchConfig, *, collect_cache=False):
    """Returns (hidden (B,S,d), caches, aux_loss)."""
    x, loss_mask = embed_inputs(params, batch, cfg)
    seq = x.shape[1]
    positions = jnp.arange(seq, dtype=jnp.int32)
    aux = jnp.zeros((), jnp.float32)
    caches = {}

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_period > 1:
            p = cfg.local_global_period

            def group_body(h, gp):
                kvs = []
                for i in range(p):
                    lp = jax.tree.map(lambda a: a[i], gp)
                    local = i % p != p - 1  # local first, global last in group
                    w, sink = _layer_window(cfg, seq, local)
                    h, kv = attn_mlp_layer(lp, h, positions, cfg, window=w, sink=sink)
                    kvs.append(kv)
                return h, jax.tree.map(lambda *a: jnp.stack(a), *kvs)

            x, kv = jax.lax.scan(jax.checkpoint(group_body), x, params["layers"])
        else:
            w, sink = _layer_window(cfg, seq, cfg.sliding_window is not None)

            def body(h, lp):
                h, kv = attn_mlp_layer(lp, h, positions, cfg, window=w, sink=sink)
                return h, kv

            x, kv = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        caches["attn"] = kv

    elif cfg.family == "moe":
        w, _ = _layer_window(cfg, seq, cfg.sliding_window is not None)
        dense_kvs = []
        if "dense_first" in params:
            n_dense = cfg.moe.first_dense_layers
            for i in range(n_dense):
                lp = jax.tree.map(lambda a: a[i], params["dense_first"])
                x, kv = attn_mlp_layer(lp, x, positions, cfg, window=w)
                dense_kvs.append(kv)

        def body(carry, lp):
            h, aux_acc = carry
            h, kv, aux_l = moe_layer(lp, h, positions, cfg, window=w)
            return (h, aux_acc + aux_l), kv

        (x, aux), kv = jax.lax.scan(jax.checkpoint(body), (x, aux), params["layers"])
        caches["attn"] = kv
        if dense_kvs:
            caches["dense_first"] = jax.tree.map(lambda *a: jnp.stack(a), *dense_kvs)

    elif cfg.family == "ssm":
        def body(h, lp):
            h, cache = mamba_layer(lp, h, cfg)
            return h, cache

        x, mcache = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        caches["mamba"] = mcache

    elif cfg.family == "hybrid":
        _, _, groups, per_group, tail = _hybrid_layout(cfg)
        shared = params["shared_attn"]
        w = cfg.sliding_window if seq > (cfg.sliding_window or seq) else None
        stacked = jax.tree.map(
            lambda a: a[: groups * per_group].reshape((groups, per_group) + a.shape[1:]),
            params["mamba_groups"])
        attn_kvs = []
        mamba_caches = []

        def group_body(h, gp):
            h, kv = attn_mlp_layer(shared, h, positions, cfg, window=w)

            def inner(hh, lp):
                hh, c = mamba_layer(lp, hh, cfg)
                return hh, c

            h, mc = jax.lax.scan(inner, h, gp)
            return h, (kv, mc)

        x, (kv, mc) = jax.lax.scan(jax.checkpoint(group_body), x, stacked)
        attn_kvs.append(kv)
        mamba_caches.append(jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), mc))
        if tail:
            x, kv_t = attn_mlp_layer(shared, x, positions, cfg, window=w)
            attn_kvs.append(jax.tree.map(lambda a: a[None], kv_t))
            for i in range(tail):
                lp = jax.tree.map(lambda a: a[i], params["mamba_tail"])
                x, c = mamba_layer(lp, x, cfg)
                mamba_caches.append(jax.tree.map(lambda a: a[None], c))
        caches["attn"] = jax.tree.map(lambda *a: jnp.concatenate(a), *attn_kvs)
        caches["mamba"] = jax.tree.map(lambda *a: jnp.concatenate(a), *mamba_caches)

    elif cfg.family == "audio":
        enc = batch["frames"].astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)

        def enc_body(h, lp):
            hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
            a, _ = attn_forward(lp["attn"], hn, enc_pos, cfg, causal=False)
            h = h + a
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
            return h, None

        enc, _ = jax.lax.scan(jax.checkpoint(enc_body), enc, params["encoder"])
        enc = rmsnorm(params["enc_norm"], enc, cfg.norm_eps)

        def dec_body(h, lp):
            h, kv4 = attn_mlp_layer(lp, h, positions, cfg, enc_out=enc)
            return h, kv4

        x, kv = jax.lax.scan(jax.checkpoint(dec_body), x, params["layers"])
        caches["attn"] = (kv[0], kv[1])       # self k, v
        caches["cross"] = (kv[2], kv[3])      # cross k, v
        caches["enc_out"] = enc

    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if collect_cache:
        return x, caches, aux, loss_mask
    return x, None, aux, loss_mask


# ---------------------------------------------------------------------------
# Loss: chunked cross-entropy (never materialises (T, vocab) logits)
# ---------------------------------------------------------------------------


def lm_head_table(params, cfg: ArchConfig):
    return params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["table"]


def chunked_cross_entropy(hidden, table, targets, mask, cfg: ArchConfig,
                          chunk: int = 8192):
    """hidden: (B, S, d); targets/mask: (B, S).  Mean CE over mask."""
    b, s, d = hidden.shape
    t = b * s
    h2 = hidden.reshape(t, d)
    tg = targets.reshape(t)
    mk = mask.reshape(t)
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        tg = jnp.pad(tg, (0, pad))
        mk = jnp.pad(mk, (0, pad))
    n = h2.shape[0] // chunk
    h3 = h2.reshape(n, chunk, d)
    tg3 = tg.reshape(n, chunk)
    mk3 = mk.reshape(n, chunk)

    def body(acc, xs):
        hc, tc, mc = xs
        logits = unembed(table, hc, cfg.final_logit_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction: reduces LOCALLY over the
        # vocab-sharded axis then all-reduces a (chunk,) vector —
        # take_along_axis instead all-reduced whole (chunk, vocab/16) logit
        # blocks (measured 134 GB/step on llama train_4k, §Perf H1/iter2)
        onehot = jax.nn.one_hot(tc, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("cv,cv->c", logits, onehot)
        ce = (lse - gold) * mc
        return (acc[0] + jnp.sum(ce), acc[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h3, tg3, mk3.astype(jnp.float32)))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig):
    """Next-token LM loss (+ MoE aux)."""
    hidden, _, aux, loss_mask = forward(params, batch, cfg)
    # predict token t+1 at position t (within the text segment)
    hidden = hidden[:, :-1]
    mask = loss_mask[:, 1:]
    # targets: the token stream shifted; modality prefixes are masked out
    n_prefix = hidden.shape[1] + 1 - batch["tokens"].shape[1]
    targets = jnp.pad(batch["tokens"], ((0, 0), (n_prefix, 0)))[:, 1:]
    table = lm_head_table(params, cfg)
    ce = chunked_cross_entropy(hidden, table, targets, mask, cfg)
    return ce + aux
