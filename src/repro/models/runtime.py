"""Runtime feature flags (perf-iteration toggles).

Each flag selects between a paper-faithful/baseline implementation and a
beyond-paper optimised one, so EXPERIMENTS.md §Perf can record both sides
of every hypothesis from the same code.

  flash_vjp — flash-attention custom VJP (O(S d) backward residuals)
              instead of default AD over the blockwise scan (O(S^2)).
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_FLAGS = {
    "flash_vjp": os.environ.get("REPRO_FLASH_VJP", "0") == "1",
}


def flag(name: str) -> bool:
    return _FLAGS[name]


def set_flag(name: str, value: bool) -> None:
    assert name in _FLAGS, name
    _FLAGS[name] = value


@contextmanager
def flags(**kw):
    old = {k: _FLAGS[k] for k in kw}
    _FLAGS.update(kw)
    try:
        yield
    finally:
        _FLAGS.update(old)
