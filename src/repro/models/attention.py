"""Attention variants: GQA, MLA, sliding-window, local/global, softcap.

One core ``dot_product_attention`` (pure jnp, GQA via an explicit group axis)
used by both the training/prefill path (full sequence) and the decode path
(one query token against a KV cache).  The Pallas flash-attention kernel in
``repro.kernels`` implements the same contract for the TPU fast path
(``attn_impl='pallas'``); the jnp path is what the CPU dry-run lowers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, softcap, truncated_normal

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def causal_mask(q_pos, k_pos):
    """q_pos: (Q,), k_pos: (K,) -> bool (Q, K); True = attend."""
    return q_pos[:, None] >= k_pos[None, :]


def window_mask(q_pos, k_pos, window: int):
    return (q_pos[:, None] >= k_pos[None, :]) & (q_pos[:, None] - k_pos[None, :] < window)


def window_sink_mask(q_pos, k_pos, window: int, sink: int):
    """Rolling window plus always-attended sink prefix (StreamingLLM-style)."""
    return window_mask(q_pos, k_pos, window) | (
        (k_pos[None, :] < sink) & (q_pos[:, None] >= k_pos[None, :])
    )


# ---------------------------------------------------------------------------
# Core attention
# ---------------------------------------------------------------------------


def dot_product_attention(q, k, v, mask=None, logit_softcap=None, scale=None):
    """q: (B, Q, Hq, D), k/v: (B, K, Hkv, D[v]); GQA via head grouping.

    mask: bool broadcastable to (B, 1, 1, Q, K) with True = attend.
    """
    b, qlen, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, qlen, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if logit_softcap:
        logits = softcap(logits, logit_softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, qlen, hq, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention block (llama/gemma/mixtral/yi/internvl family)
# ---------------------------------------------------------------------------


def q_head_layout(cfg: ArchConfig):
    """(padded_head_count, group_padded, group_real) for the q-head axis.

    With ``cfg.padded_heads`` set, q heads are stored kv-major with dead
    slots at the end of each group: slot = kv * group_pad + g, real iff
    g < group_real.  The head mask keeps the function identical to the
    unpadded architecture (dead slots get zero output and zero gradient).
    """
    h = cfg.num_heads
    if not cfg.padded_heads or cfg.padded_heads == h:
        return h, None
    hp = cfg.padded_heads
    if cfg.attention_type == "mla" or cfg.num_kv_heads in (0, h):
        mask = jnp.arange(hp) < h
    else:
        gp = hp // cfg.num_kv_heads
        gr = h // cfg.num_kv_heads
        mask = (jnp.arange(hp) % gp) < gr
    return hp, mask


def init_gqa(key, cfg: ArchConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    hp, _ = q_head_layout(cfg)
    s = d ** -0.5
    return {
        "wq": truncated_normal(kq, (d, hp, hd), s, dtype),
        "wk": truncated_normal(kk, (d, cfg.num_kv_heads, hd), s, dtype),
        "wv": truncated_normal(kv, (d, cfg.num_kv_heads, hd), s, dtype),
        "wo": truncated_normal(ko, (hp, hd, d), (cfg.num_heads * hd) ** -0.5, dtype),
    }


def gqa_project_qkv(params, x, positions, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(params, x, positions, cfg: ArchConfig, *, mask):
    """Full-sequence (train/prefill) GQA attention."""
    q, k, v = gqa_project_qkv(params, x, positions, cfg)
    out = dot_product_attention(q, k, v, mask=mask,
                                logit_softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), (k, v)


def gqa_decode(params, x, cache_k, cache_v, pos, cfg: ArchConfig, *,
               window: Optional[int] = None, sink: int = 0, ring_index=None):
    """One-token decode against a KV cache.

    cache_k/v: (B, S, Hkv, D).  ``pos``: scalar current position.
    For rolling-window caches, ``ring_index`` is the slot to overwrite and
    key positions are reconstructed from the stored position buffer by the
    caller; here we take an explicit ``k_pos`` vector instead.
    """
    b, s = cache_k.shape[0], cache_k.shape[1]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k_new = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v_new = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    slot = ring_index if ring_index is not None else pos
    cache_k = cache_k.at[:, slot].set(k_new[:, 0])
    cache_v = cache_v.at[:, slot].set(v_new[:, 0])

    # valid-key mask: slots written so far (ring caches are always full by
    # construction of the dry-run decode shapes)
    k_idx = jnp.arange(s)
    if ring_index is not None:
        valid = jnp.ones((s,), bool)  # ring cache: every slot holds a live key
    else:
        valid = k_idx <= pos
    mask = valid[None, None, None, None, :]
    out = dot_product_attention(q, cache_k, cache_v, mask=mask,
                                logit_softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def apply_head_mask(out, cfg: ArchConfig):
    """Zero the dead padded q-head slots (out: (..., H_pad, Dv))."""
    _, mask = q_head_layout(cfg)
    if mask is None:
        return out
    return out * mask[..., :, None].astype(out.dtype)


def init_mla(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    keys = jax.random.split(key, 6)
    d, _ = cfg.d_model, cfg.num_heads
    h, _mask = q_head_layout(cfg)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = d ** -0.5
    return {
        "wq_a": truncated_normal(keys[0], (d, m.q_lora_rank), s, dtype),
        "wq_b": truncated_normal(keys[1], (m.q_lora_rank, h, qk_head),
                                 m.q_lora_rank ** -0.5, dtype),
        "wkv_a": truncated_normal(keys[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), s, dtype),
        "wkv_b": truncated_normal(
            keys[3], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
            m.kv_lora_rank ** -0.5, dtype),
        "wo": truncated_normal(keys[4], (h, m.v_head_dim, d), (h * m.v_head_dim) ** -0.5, dtype),
        "q_norm_scale": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "kv_norm_scale": jnp.zeros((m.kv_lora_rank,), jnp.float32),
    }


def _mla_qkv_from_latent(params, cq_norm, latent_kv, k_rope, q_positions,
                         k_positions, cfg: ArchConfig):
    """Expand per-head q, k, v from the (normalised) latents."""
    m = cfg.mla
    q = jnp.einsum("bsr,rhe->bshe", cq_norm, params["wq_b"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, q_positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv = jnp.einsum("bsr,rhe->bshe", latent_kv, params["wkv_b"])
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], k_positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (m.qk_rope_head_dim,))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q, k, v


def _rms(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


def mla_attention(params, x, positions, cfg: ArchConfig, *, mask):
    m = cfg.mla
    cq = _rms(x @ params["wq_a"], params["q_norm_scale"], cfg.norm_eps)
    ckv = x @ params["wkv_a"]
    latent_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    latent_kv = _rms(latent_kv, params["kv_norm_scale"], cfg.norm_eps)
    q, k, v = _mla_qkv_from_latent(params, cq, latent_kv, k_rope,
                                   positions, positions, cfg)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = dot_product_attention(q, k, v, mask=mask, scale=scale)
    out = apply_head_mask(out, cfg)
    attn_out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    # MLA cache = compressed latent + shared rope key (this is the point of MLA)
    return attn_out, (latent_kv, k_rope)


def mla_decode(params, x, cache_latent, cache_krope, pos, cfg: ArchConfig):
    """cache_latent: (B, S, kv_lora_rank); cache_krope: (B, S, rope_dim)."""
    m = cfg.mla
    b, s = cache_latent.shape[0], cache_latent.shape[1]
    cq = _rms(x @ params["wq_a"], params["q_norm_scale"], cfg.norm_eps)
    ckv = x @ params["wkv_a"]
    latent_new, krope_new = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    latent_new = _rms(latent_new, params["kv_norm_scale"], cfg.norm_eps)
    cache_latent = cache_latent.at[:, pos].set(latent_new[:, 0])
    cache_krope = cache_krope.at[:, pos].set(krope_new[:, 0])

    q_positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    k_positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k, v = _mla_qkv_from_latent(params, cq, cache_latent, cache_krope,
                                   q_positions, k_positions, cfg)
    valid = (jnp.arange(s) <= pos)[None, None, None, None, :]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = dot_product_attention(q, k, v, mask=valid, scale=scale)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), cache_latent, cache_krope
