"""Flash attention with a custom VJP (pure JAX).

The default AD of the blockwise forward saves every (q_block x kv_block)
probability tile as a scan residual — O(S^2) storage/traffic in the
backward, which the baseline roofline showed dominates training memory
time.  The flash backward instead saves only (q, k, v, out, lse) — O(S d) —
and recomputes probability tiles blockwise, exactly like the TPU kernel
would (Dao et al. 2022, adapted to blockwise JAX so XLA keeps tiles
register/VMEM-resident on TPU).

Supports causal, sliding-window(+sink) and softcap variants — everything the
10 assigned architectures use.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.blockwise import _block_mask

NEG_INF = -2.0 ** 30


def _positions(nq, qb, qlen, nk, kb, klen):
    """Block position arrays from STATIC shape info (padding slots -1 for
    keys).  Built inside the custom-VJP fwd/bwd rules so the rules never
    close over traced arrays (closing over tracers in a custom_vjp bwd is
    an UnexpectedTracerError)."""
    q_pos = jnp.arange(nq * qb, dtype=jnp.int32)
    q_pos = jnp.where(q_pos < qlen, q_pos, 0).reshape(nq, qb)
    k_pos = jnp.arange(nk * kb, dtype=jnp.int32)
    k_pos = jnp.where(k_pos < klen, k_pos, -1).reshape(nk, kb)
    return q_pos, k_pos


def _fwd_blocks(q, k, v, qp, kp, *, causal, window, sink, softcap, scale):
    """q: (nq, B, qb, Hkv, G, D); k/v: (nk, B, kb, Hkv, D).
    Returns out (nq, B, qb, Hkv, G, Dv) and lse (nq, B, Hkv, G, qb)."""
    nq, b, qb, hkv, g, d = q.shape
    dv = v.shape[-1]

    def q_step(_, xq):
        qi, qpi = xq

        def kv_step(carry, xkv):
            m, l, acc = carry
            ki, vi, kpi = xkv
            mask = _block_mask(qpi, kpi, causal=causal, window=window, sink=sink)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32) * scale,
                           ki.astype(jnp.float32))
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qb, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k, v, kp))
        out = (acc / jnp.maximum(l, 1e-30)[..., None])
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4).astype(v.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (q, qp))
    return outs, lses  # (nq, B, qb, Hkv, G, Dv), (nq, B, Hkv, G, qb)


def _bwd_blocks(res, do, *, causal, window, sink, softcap, scale,
                qlen, klen):
    q, k, v, out, lse = res
    nq_, _, qb_, _, _, _ = q.shape
    nk_, _, kb_, _, _ = k.shape
    qp, kp = _positions(nq_, qb_, qlen, nk_, kb_, klen)
    nq, b, qb, hkv, g, d = q.shape
    nk, _, kb, _, dv = v.shape

    # delta_i = rowsum(dO * O) per query
    delta = jnp.einsum("cbqhgd,cbqhgd->cbhgq", do.astype(jnp.float32),
                       out.astype(jnp.float32))

    def q_step(carry, xq):
        dk_acc, dv_acc = carry                     # (nk, B, kb, Hkv, D[v]) f32
        qi, doi, lsei, di, qpi = xq
        doi = doi.astype(jnp.float32)

        def kv_step(inner, xkv):
            dq_acc = inner                          # (B, qb, Hkv, G, D)
            ki, vi, kpi, idx = xkv
            mask = _block_mask(qpi, kpi, causal=causal, window=window, sink=sink)
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk",
                               qi.astype(jnp.float32) * scale,
                               ki.astype(jnp.float32))
            if softcap:
                tanh_term = jnp.tanh(s_raw / softcap)
                s = softcap * tanh_term
            else:
                s = s_raw
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])        # (B,Hkv,G,qb,kb)
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p,
                                doi)                 # sum over G in einsum
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doi, vi.astype(jnp.float32))
            ds = p * (dp - di[..., None])
            if softcap:
                ds = ds * (1.0 - tanh_term ** 2)
            ds = jnp.where(mask[None, None, None], ds, 0.0)
            dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                ki.astype(jnp.float32)) * scale
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                qi.astype(jnp.float32)) * scale
            return dq_acc + dq_blk, (idx, dk_blk, dv_blk)

        dq0 = jnp.zeros((b, qb, hkv, g, d), jnp.float32)
        idxs = jnp.arange(nk)
        dqi, (idx, dks, dvs) = jax.lax.scan(kv_step, dq0, (k, v, kp, idxs))
        dk_acc = dk_acc + dks
        dv_acc = dv_acc + dvs
        return (dk_acc, dv_acc), dqi

    dk0 = jnp.zeros((nk, b, kb, hkv, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, kb, hkv, dv), jnp.float32)
    (dk, dv_), dq = jax.lax.scan(q_step, (dk0, dv0), (q, do, lse, delta, qp))
    return dq, dk, dv_


def flash_attention_vjp(q, k, v, *, causal=True, window=None, sink=0,
                        logit_softcap=None, scale=None,
                        q_block: int = 512, k_block: int = 512):
    """Same contract as blockwise.flash_attention, flash backward.

    q: (B, Q, H, D); k, v: (B, K, Hkv, D) -> (B, Q, H, Dv).
    """
    b, qlen, h, d = q.shape
    klen, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale_v = float(scale) if scale is not None else d ** -0.5
    qb = min(q_block, qlen)
    kb = min(k_block, klen)

    qpad = (-qlen) % qb
    kpad = (-klen) % kb
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // qb, k.shape[1] // kb

    qs = q.reshape(b, nq, qb, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kb, hkv, dv).transpose(1, 0, 2, 3, 4)

    flags = dict(causal=causal, window=window, sink=sink,
                 softcap=logit_softcap, scale=scale_v)

    @jax.custom_vjp
    def _attn(qs, ks, vs):
        qp, kp = _positions(nq, qb, qlen, nk, kb, klen)
        out, _ = _fwd_blocks(qs, ks, vs, qp, kp, **flags)
        return out

    def _attn_fwd(qs, ks, vs):
        qp, kp = _positions(nq, qb, qlen, nk, kb, klen)
        out, lse = _fwd_blocks(qs, ks, vs, qp, kp, **flags)
        return out, (qs, ks, vs, out, lse)

    def _attn_bwd(res, do):
        dq, dk, dv_ = _bwd_blocks(res, do, qlen=qlen, klen=klen, **flags)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv_.astype(v.dtype))

    _attn.defvjp(_attn_fwd, _attn_bwd)

    out = _attn(qs, ks, vs)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * qb, h, dv)
    return out[:, :qlen]
