"""Mamba2 block — SSD (state-space duality) formulation [arXiv:2405.21060].

Chunked SSD: within-chunk attention-like term + inter-chunk state recurrence
(``jax.lax.scan`` over chunks — linear in sequence length, O(1) decode state).
``repro.kernels.ssd_scan`` provides the Pallas TPU kernel for the intra-chunk
term; this module's jnp implementation is the oracle and the dry-run path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rmsnorm, truncated_normal


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ArchConfig, dtype):
    """Projections are kept separate (z/x/B/C/dt and per-stream convs) so each
    tensor shards cleanly on the ``model`` axis without crossing concat
    boundaries (see DESIGN.md §4)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.state_dim
    keys = jax.random.split(key, 9)
    sc = d ** -0.5
    return {
        "z_proj": truncated_normal(keys[0], (d, di), sc, dtype),
        "x_proj": truncated_normal(keys[1], (d, di), sc, dtype),
        "B_proj": truncated_normal(keys[2], (d, gn), sc, dtype),
        "C_proj": truncated_normal(keys[3], (d, gn), sc, dtype),
        "dt_proj": truncated_normal(keys[4], (d, nh), sc, dtype),
        "conv_x_w": truncated_normal(keys[5], (s.conv_width, di), 0.1, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_w": truncated_normal(keys[6], (s.conv_width, gn), 0.1, dtype),
        "conv_B_b": jnp.zeros((gn,), dtype),
        "conv_C_w": truncated_normal(keys[7], (s.conv_width, gn), 0.1, dtype),
        "conv_C_b": jnp.zeros((gn,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": truncated_normal(keys[8], (di, d), di ** -0.5, dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD core (jnp oracle; Pallas kernel mirrors this contract)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD linear-attention dual form, chunked.

    x: (b, l, h, p)   inputs (dt weighting happens here: xbar = x * dt)
    dt: (b, l, h)     positive step sizes
    A: (h,)           negative decay rates
    B, C: (b, l, g, n) input/output projections (g groups broadcast to heads)
    Returns y: (b, l, h, p), final_state: (b, h, p, n).

    Implemented as a single ``lax.scan`` over chunks (the inter-chunk state
    recurrence is sequential anyway) with a rematerialised body, so only ONE
    chunk's quadratic (chunk x chunk x heads) intermediates are ever alive —
    the all-chunks formulation materialised (b, nc, chunk, chunk, h) decay
    tensors, tens of GB at production shapes.  Mirrors the Pallas kernel's
    structure (repro.kernels.ssd_scan).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    hpg = h // g

    dA = dt * A[None, None, :]                    # (b, l, h) log decay
    xbar = x * dt[..., None]

    def chunked(t, extra):  # (b, l, ...) -> (nc, b, chunk, ...)
        return jnp.moveaxis(t.reshape((b, nc, chunk) + extra), 1, 0)

    xs = (chunked(xbar, (h, p)), chunked(dA, (h,)),
          chunked(B, (g, n)), chunked(C, (g, n)))

    qpos = jnp.arange(chunk)
    causal = qpos[:, None] >= qpos[None, :]

    def body(state, inp):
        xbar_c, dA_c, B_c, C_c = inp              # (b, chunk, ...)
        B_h = jnp.repeat(B_c, hpg, axis=2)        # (b, chunk, h, n)
        C_h = jnp.repeat(C_c, hpg, axis=2)
        cum = jnp.cumsum(dA_c, axis=1)            # (b, chunk, h)
        total = cum[:, -1]                        # (b, h)

        # intra-chunk: M[t, s] = exp(cum_t - cum_s) (C_t . B_s), s <= t
        decay = cum[:, :, None, :] - cum[:, None, :, :]   # (b, t, s, h)
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        CB = jnp.einsum("bthn,bshn->btsh", C_h, B_h)
        y_intra = jnp.einsum("btsh,bshp->bthp", CB * jnp.exp(decay), xbar_c)

        # inter-chunk: y_inter[t] = exp(cum_t) * C_t . state
        y_inter = jnp.einsum("bth,bthn,bhpn->bthp", jnp.exp(cum), C_h, state)

        # state update
        w = jnp.exp(total[:, None, :] - cum)      # (b, chunk, h)
        new_state = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqh,bqhn,bqhp->bhpn", w, B_h, xbar_c)
        return new_state, (y_intra + y_inter).astype(x.dtype)

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(jax.checkpoint(body), init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, p)
    return y, final


def ssd_reference(x, dt, A, B, C):
    """O(L^2)-free sequential oracle: plain recurrence (for tests)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    B_h = jnp.repeat(B, hpg, axis=2)
    C_h = jnp.repeat(C, hpg, axis=2)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        a = jnp.exp(dtt * A[None, :])  # (b,h)
        state = state * a[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", Bt, (xt * dtt[..., None]).astype(state.dtype))
        y = jnp.einsum("bhn,bhpn->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_h, 1, 0), jnp.moveaxis(C_h, 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final


# ---------------------------------------------------------------------------
# Full block: train / prefill
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b


def mamba_block(params, x, cfg: ArchConfig, *, use_kernel: bool = False):
    """x: (B, L, d_model) -> (B, L, d_model), cache (ssm state + conv tails)."""
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)
    w = s.conv_width

    z = x @ params["z_proj"]
    xs_raw = x @ params["x_proj"]
    B_raw = x @ params["B_proj"]
    C_raw = x @ params["C_proj"]
    dt = x @ params["dt_proj"]
    xs = jax.nn.silu(_causal_conv(xs_raw, params["conv_x_w"], params["conv_x_b"]))
    B = jax.nn.silu(_causal_conv(B_raw, params["conv_B_w"], params["conv_B_b"]))
    C = jax.nn.silu(_causal_conv(C_raw, params["conv_C_w"], params["conv_C_b"]))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(xs.shape[0], xs.shape[1], nh, s.head_dim)
    Bg = B.reshape(B.shape[0], B.shape[1], s.n_groups, s.state_dim)
    Cg = C.reshape(C.shape[0], C.shape[1], s.n_groups, s.state_dim)

    # pad to a chunk multiple; padded steps use dt = 0 (identity transition,
    # zero input) so they leave the state untouched
    l0 = xh.shape[1]
    chunk = min(s.chunk_size, l0)
    pad = (-l0) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bg = jnp.pad(Bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cg = jnp.pad(Cg, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if use_kernel:
        from repro.kernels.ops import ssd_scan as ssd_impl
        y, final = ssd_impl(xh, dt, A, Bg, Cg, chunk=chunk)
    else:
        y, final = ssd_chunked(xh, dt, A, Bg, Cg, chunk=chunk)
    if pad:
        y = y[:, :l0]
        xh = xh[:, :l0]
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(y.shape[0], y.shape[1], s.d_inner(cfg.d_model)).astype(x.dtype)

    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    cache = {
        "conv_x": xs_raw[:, -(w - 1):], "conv_B": B_raw[:, -(w - 1):],
        "conv_C": C_raw[:, -(w - 1):], "ssm": final,
    }
    return y @ params["out_proj"], cache


# ---------------------------------------------------------------------------
# Decode: O(1) per-step state update
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.state_dim
    nh = s.n_heads(cfg.d_model)
    return {
        "conv_x": jnp.zeros((batch, s.conv_width - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.conv_width - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.conv_width - 1, gn), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
    }


def _conv_step(cache_buf, xt, w, b):
    """cache_buf: (B, W-1, C); xt: (B, 1, C) -> (B, C), new buf."""
    conv_in = jnp.concatenate([cache_buf, xt], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", conv_in, w) + b
    return jax.nn.silu(out), conv_in[:, 1:]


def mamba_decode(params, x, cache, cfg: ArchConfig):
    """x: (B, 1, d_model); O(1)-state single-token step."""
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)

    z = x @ params["z_proj"]
    xs_t = x @ params["x_proj"]
    B_t = x @ params["B_proj"]
    C_t = x @ params["C_proj"]
    dt = x @ params["dt_proj"]

    xs, new_conv_x = _conv_step(cache["conv_x"], xs_t, params["conv_x_w"], params["conv_x_b"])
    B, new_conv_B = _conv_step(cache["conv_B"], B_t, params["conv_B_w"], params["conv_B_b"])
    C, new_conv_C = _conv_step(cache["conv_C"], C_t, params["conv_C_w"], params["conv_C_b"])

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(-1, nh, s.head_dim)
    Bg = jnp.repeat(B.reshape(-1, s.n_groups, s.state_dim), nh // s.n_groups, axis=1)
    Cg = jnp.repeat(C.reshape(-1, s.n_groups, s.state_dim), nh // s.n_groups, axis=1)

    a = jnp.exp(dt * A[None, :])  # (B, H)
    ssm = cache["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhpn", Bg, xh * dt[..., None])
    y = jnp.einsum("bhn,bhpn->bhp", Cg, ssm)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(-1, 1, s.d_inner(cfg.d_model)).astype(x.dtype)

    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    new_cache = {"conv_x": new_conv_x, "conv_B": new_conv_B,
                 "conv_C": new_conv_C, "ssm": ssm}
    return y @ params["out_proj"], new_cache
