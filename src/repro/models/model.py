"""Model facade: step functions + input specs for every (arch x shape).

These are the exact callables the launcher lowers/compiles:
  * train:   ``make_train_step(cfg, optimizer)``
  * prefill: ``make_prefill_step(cfg)``
  * decode:  ``make_decode_step(cfg, shape)``

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
multi-pod dry-run lowers against these.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import decode as decode_mod
from repro.models import transformer
from repro.optim.optimizers import Optimizer, apply_updates


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    specs = {}
    if cfg.modality == "vision":
        specs["patch_embed"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), f32)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32)
    elif cfg.modality == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), f32)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs


def make_batch(cfg: ArchConfig, shape: InputShape, key) -> dict:
    """Materialised random batch matching input_specs (for smoke tests)."""
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        if spec.dtype == jnp.int32:
            if name == "pos":
                out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[name] = jax.random.randint(key, spec.shape, 0,
                                               max(cfg.vocab_size, 2), jnp.int32)
        else:
            out[name] = jax.random.normal(key, spec.shape, spec.dtype)
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, *, grad_accum: int = 1,
                    microbatch_shardings=None, grad_shardings=None):
    """grad_accum > 1 splits the per-device batch into microbatches and
    accumulates grads under a scan — the standard activation-memory knob
    (divides peak activation size by grad_accum at zero collective cost).

    microbatch_shardings: optional pytree of NamedSharding for the reshaped
    (accum, batch/accum, ...) batch.  REQUIRED on a real mesh: without the
    constraint GSPMD assigns the data axis to the scan (accum) dim and every
    device computes the full microbatch (§Perf H3/iter2: 16x tile traffic
    on yi-34b)."""

    def grads_of(params, batch):
        return jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)

    def train_step(params, opt_state, step, batch):
        if grad_accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)
            if microbatch_shardings is not None:
                micro = jax.lax.with_sharding_constraint(
                    micro, microbatch_shardings)

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss_i, g_i = grads_of(params, mb)
                if grad_shardings is not None:
                    # ZeRO-style: reduce-scatter each microbatch's grads into
                    # the (data, model)-sharded accumulator instead of keeping
                    # a replicated-over-data grad buffer (§Perf H3/iter3)
                    g_i = jax.lax.with_sharding_constraint(g_i, grad_shardings)
                return (loss_acc + loss_i,
                        jax.tree.map(jnp.add, g_acc, g_i)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            if grad_shardings is not None:
                zeros = jax.lax.with_sharding_constraint(zeros, grad_shardings)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            scale = 1.0 / grad_accum
            loss = loss * scale
            grads = jax.tree.map(lambda g: g * jnp.asarray(scale, g.dtype), grads)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return train_step


def make_grad_fn(cfg: ArchConfig):
    """Bare loss+grad (the paper's streaming trainer applies its own SGD)."""
    return jax.value_and_grad(partial(transformer.loss_fn, cfg=cfg))


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        hidden, caches, _aux, _mask = transformer.forward(
            params, batch, cfg, collect_cache=True)
        table = transformer.lm_head_table(params, cfg)
        last = hidden[:, -1]
        logits = last @ table.T
        if cfg.final_logit_softcap:
            logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ArchConfig, shape: InputShape):
    def serve_step(params, cache, batch):
        return decode_mod.decode_step(params, cache, batch, cfg, shape)

    return serve_step


def init_params(cfg: ArchConfig, seed: int = 0):
    return transformer.init_model(jax.random.PRNGKey(seed), cfg)


def abstract_params(cfg: ArchConfig, seed: int = 0):
    """ShapeDtypeStruct pytree of the params (no allocation) for dry-runs."""
    return jax.eval_shape(lambda: transformer.init_model(jax.random.PRNGKey(seed), cfg))


def abstract_cache(cfg: ArchConfig, shape: InputShape):
    return jax.eval_shape(lambda: decode_mod.init_cache(cfg, shape))


def abstract_opt_state(optimizer: Optimizer, params_abs):
    return jax.eval_shape(optimizer.init, params_abs)
