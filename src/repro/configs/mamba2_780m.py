"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_780M = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,           # attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    attention_type="none",
    block_kind="mamba",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    long_context_variant="ssm",  # O(1) decode state: runs long_500k
    tie_embeddings=True,
    grad_accum=2,
))
