"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B]."""
from repro.configs.base import ArchConfig, register

LLAMA3_2_1B = register(ArchConfig(
    name="llama3.2-1b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    long_context_variant="full",  # long_500k SKIP (pure full attention)
    grad_accum=2,
))
