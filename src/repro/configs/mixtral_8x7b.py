"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ArchConfig, MoEConfig, register

MIXTRAL_8X7B = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14_336),
    rope_theta=1_000_000.0,
    # SWA rolling KV cache => sub-quadratic decode: runs long_500k
    long_context_variant="window",
    grad_accum=16,
))
