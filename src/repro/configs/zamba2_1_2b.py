"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig, SSMConfig, register

ZAMBA2_1_2B = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,   # shared attention block is MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    block_kind="hybrid",
    attn_every=6,      # one shared (tied-weight) attention block per 6 mamba blocks
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    # hybrid decode: SSM state + shared-attn rolling window -> runs long_500k
    long_context_variant="ssm",
    sliding_window=4096,  # shared attention uses a rolling window in long decode
    tie_embeddings=True,
    grad_accum=8,
))
