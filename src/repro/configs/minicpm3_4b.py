"""minicpm3-4b [dense] — MLA [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import ArchConfig, MLAConfig, register

MINICPM3_4B = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attention_type="mla",
    padded_heads=48,   # 40 -> 48 so heads divide the 16-way model axis (§Perf H2)
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    long_context_variant="full",  # long_500k SKIP (MLA compresses the cache but
                                  # the softmax is still full-length)
    grad_accum=16,
))
