"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
input_specs() supplies precomputed frame embeddings (batch, frames, d_model).
"""
from repro.configs.base import ArchConfig, register

WHISPER_TINY = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    is_encoder_decoder=True,
    encoder_frames=1500,
    modality="audio",
    tie_embeddings=True,
    long_context_variant="full",  # long_500k SKIP (decoder ctx is arch-capped)
    grad_accum=8,
))
