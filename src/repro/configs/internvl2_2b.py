"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

The ViT + MLP projector frontend is a STUB per the assignment: input_specs()
supplies precomputed patch embeddings of shape (batch, num_patches, d_model).
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_2B = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    modality="vision",
    num_patches=256,  # InternVL2 pixel-shuffled ViT tokens per tile
    rope_theta=1_000_000.0,
    long_context_variant="full",  # long_500k SKIP
    grad_accum=2,
))
