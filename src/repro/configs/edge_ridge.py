"""edge-ridge — the paper's own experiment config (§5).

Ridge regression on an 8-feature housing-style dataset, N=18576, trained at the
edge under the pipelined streaming protocol.  This is not one of the 10 assigned
transformer architectures; it is the faithful-reproduction target.
"""
from dataclasses import dataclass

from repro.configs.base import ArchConfig, register


@dataclass(frozen=True)
class EdgeRidgeParams:
    n_features: int = 8
    n_samples: int = 18_576
    lam: float = 0.05          # ridge coefficient (paper: lambda = 0.05)
    alpha: float = 1e-4        # SGD stepsize (paper Fig. 3/4)
    tau_p: float = 1.0         # one SGD update per sample-transmission time
    T_factor: float = 1.5      # T = 1.5 * N (paper Fig. 3)
    # paper's reported constants for the Corollary-1 bound
    L: float = 1.908
    c: float = 0.061
    M: float = 1.0
    M_G: float = 1.0


EDGE_RIDGE_PARAMS = EdgeRidgeParams()

EDGE_RIDGE = register(ArchConfig(
    name="edge-ridge",
    family="paper",
    source="Skatchkovsky & Simeone 2019, Sec. 5",
    num_layers=0,
    d_model=8,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    attention_type="none",
    dtype="float32",
))
