"""Architecture & input-shape config system.

Every assigned architecture is a frozen :class:`ArchConfig` registered under its
public id (``--arch <id>``).  ``reduced()`` derives the CPU smoke-test variant
(2 layers, d_model<=512, <=4 experts) from the same family definition, so smoke
tests exercise the identical code path as the full config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 1408
    num_shared_experts: int = 0
    # layers [0, first_dense_layers) use a dense FFN of size first_dense_d_ff
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0
    router_aux_loss_coef: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | ssm | moe | vlm | audio | hybrid
    source: str  # citation bracket from the assignment

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None  # default: d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1024

    # attention variants -----------------------------------------------------
    attention_type: str = "gqa"  # gqa | mla | none
    sliding_window: Optional[int] = None  # SWA window (mixtral / gemma2 local)
    local_global_period: int = 0  # gemma2: layer i is local iff i % period != period-1
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    # Perf (§Perf H2/H3): pad the q-head axis to this count with dead
    # (masked, zero-gradient) head slots so heads divide the 16-way model
    # axis.  Function and trained parameters are EXACTLY the unpadded
    # architecture; padding is a sharding-layout trick.  GQA archs pad
    # per-group (padded_heads must be num_kv_heads * ceil-grouped).
    padded_heads: Optional[int] = None
    rope_theta: float = 10_000.0
    # long-context decode strategy: "full" | "window" | "window_global" | "ssm"
    long_context_variant: str = "full"

    # block layout -----------------------------------------------------------
    # "attn" = attention+MLP block, "mamba" = mamba2 block.
    # hybrid archs interleave: shared attention every `attn_every` mamba blocks.
    block_kind: str = "attn"  # attn | mamba | hybrid
    attn_every: int = 0  # hybrid: 1 shared attn block per `attn_every` mamba blocks

    # sub-configs --------------------------------------------------------------
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None

    # encoder/decoder (whisper) -----------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1500  # stub frontend output length

    # modality stub frontends ---------------------------------------------------
    modality: str = "text"  # text | vision | audio
    num_patches: int = 0  # vlm: patch-embedding count prepended to the text

    norm_eps: float = 1e-5
    grad_accum: int = 1  # microbatch accumulation steps for train_4k
    tie_embeddings: bool = False
    scale_embed: bool = False  # gemma-style sqrt(d_model) embedding scale
    dtype: str = "bfloat16"

    # paper-core schedule defaults (normalised units, see core/protocol.py)
    tau_p: float = 1.0

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # -- derived -----------------------------------------------------------
    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def supports_shape(self, shape: InputShape) -> Tuple[bool, str]:
        """Whether this arch runs the given input shape (with skip reason)."""
        if shape.name == "long_500k":
            if self.long_context_variant == "full":
                return False, (
                    "pure full-attention arch: 500k decode requires a sub-quadratic "
                    "variant we do not fake (see DESIGN.md §6)"
                )
        return True, ""

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    if cfg.attention_type == "mla":
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk_head
        p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        p += cfg.num_heads * m.v_head_dim * d
        return p
    hd = cfg.head_dim
    p = d * cfg.num_heads * hd  # q
    p += 2 * d * cfg.num_kv_heads * hd  # k, v
    p += cfg.num_heads * hd * d  # o
    return p


def _mlp_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # gated (SwiGLU-style): gate, up, down


def _mamba_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    d, di = cfg.d_model, s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.state_dim
    p = d * (2 * di + 2 * s.n_groups * s.state_dim + nh)  # in_proj(z,x,B,C,dt)
    p += conv_dim * s.conv_width  # depthwise conv
    p += 2 * nh  # A_log, D
    p += di * d  # out_proj
    return p


def _layer_kinds(cfg: ArchConfig) -> list:
    """Per-layer kind list: 'attn' / 'mamba' / 'moe' / 'dense_first'."""
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.block_kind == "mamba":
            kinds.append("mamba")
        elif cfg.block_kind == "hybrid":
            # one shared attention block per attn_every mamba blocks
            kinds.append("hybrid_attn" if (i % cfg.attn_every == 0) else "mamba")
        elif cfg.moe is not None:
            kinds.append("dense_first" if i < cfg.moe.first_dense_layers else "moe")
        else:
            kinds.append("attn")
    return kinds


def _param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    for kind in _layer_kinds(cfg):
        if kind == "mamba":
            total += _mamba_params(cfg) + d  # + norm
        elif kind == "hybrid_attn":
            total += _attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d
        elif kind == "moe":
            m = cfg.moe
            total += _attn_params(cfg) + 2 * d
            total += d * m.num_experts  # router
            n_routed = m.top_k if active_only else m.num_experts
            total += n_routed * _mlp_params(d, m.d_ff_expert)
            total += m.num_shared_experts * _mlp_params(d, m.d_ff_expert)
        elif kind == "dense_first":
            total += _attn_params(cfg) + _mlp_params(d, cfg.moe.first_dense_d_ff) + 2 * d
        else:
            total += _attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d
    if cfg.is_encoder_decoder:
        # encoder self-attn+mlp layers + decoder cross-attn additions
        enc = cfg.encoder_layers * (_attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d)
        cross = cfg.num_layers * (_attn_params(cfg) + d)
        total += enc + cross
    return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> list:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "llama3_2_1b",
    "mamba2_780m",
    "internvl2_2b",
    "deepseek_moe_16b",
    "gemma2_9b",
    "whisper_tiny",
    "zamba2_1_2b",
    "minicpm3_4b",
    "mixtral_8x7b",
    "yi_34b",
    "edge_ridge",
]


def _load_all() -> None:
    import importlib

    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# Reduced (smoke) variants
# ---------------------------------------------------------------------------


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, tiny vocab."""
    num_layers = 2
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4)
    num_kv_heads = max(1, min(cfg.num_kv_heads, num_heads)) if num_heads else 0
    # keep the GQA ratio representative where possible
    if 0 < cfg.num_kv_heads < cfg.num_heads:
        num_kv_heads = max(1, num_heads // 2)
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=max(16, d_model // num_heads) if num_heads else 32,
        d_ff=min(cfg.d_ff, 512) or 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        local_global_period=min(cfg.local_global_period, 2) if cfg.local_global_period else 0,
        padded_heads=None,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_frames=min(cfg.encoder_frames, 32),
        num_patches=min(cfg.num_patches, 16) if cfg.num_patches else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        dtype="float32",
    )
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=16, v_head_dim=16,
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16), head_dim=32, chunk_size=16
        )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            capacity_factor=8.0,  # dropless at smoke scale (parity tests)
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            first_dense_d_ff=min(cfg.moe.first_dense_d_ff, 256),
        )
    return replace(cfg, **updates)
