"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]."""
from repro.configs.base import ArchConfig, MoEConfig, register

DEEPSEEK_MOE_16B = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,   # MHA
    head_dim=128,
    d_ff=1408,         # per-expert FFN dim (fine-grained)
    vocab_size=102_400,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        first_dense_layers=1,
        first_dense_d_ff=10_944,
    ),
    long_context_variant="full",  # long_500k SKIP
    grad_accum=8,
))
