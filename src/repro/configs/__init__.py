from repro.configs.base import (
    ArchConfig,
    InputShape,
    INPUT_SHAPES,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_archs,
    reduced,
    register,
)

ASSIGNED_ARCHS = [
    "llama3.2-1b",
    "mamba2-780m",
    "internvl2-2b",
    "deepseek-moe-16b",
    "gemma2-9b",
    "whisper-tiny",
    "zamba2-1.2b",
    "minicpm3-4b",
    "mixtral-8x7b",
    "yi-34b",
]

__all__ = [
    "ArchConfig", "InputShape", "INPUT_SHAPES", "MLAConfig", "MoEConfig",
    "SSMConfig", "get_config", "list_archs", "reduced", "register",
    "ASSIGNED_ARCHS",
]
