"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118]."""
from repro.configs.base import ArchConfig, register

GEMMA2_9B = register(ArchConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    sliding_window=4096,
    local_global_period=2,      # local, global, local, global, ...
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    # long_500k RUNS with the documented window+sink variant for global layers
    long_context_variant="window_global",
    grad_accum=8,
))
