"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, register

YI_34B = register(ArchConfig(
    name="yi-34b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    padded_heads=64,   # 8 kv groups of 7 -> padded to 8 (see §Perf H3)
    long_context_variant="full",  # long_500k SKIP
    grad_accum=16,
))
