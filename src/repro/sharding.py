"""Sharding rules: params, optimizer state, batches, decode caches.

Path-based GSPMD rules (tensor parallel over ``model``, batch over
``pod``+``data``, ZeRO-style data-axis sharding for optimizer moments).

Every rule is a *priority list* of candidate PartitionSpecs; the first one
whose assignments exactly divide the tensor dims (a jit in_shardings
requirement) wins.  This is how e.g. Yi-34B's 56 q-heads (not divisible by
the 16-way model axis) fall back to row-parallel (d_model) sharding, and odd
vocab sizes (Whisper 51865) fall back to embedding-dim sharding.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape


# ---------------------------------------------------------------------------
# Candidate tables (trailing-dim specs of the unstacked tensor)
# ---------------------------------------------------------------------------

_ATTN_RULES = {
    # col-parallel on heads; fall back to row-parallel on d_model
    "wq": [P(None, "model", None), P("model", None, None)],
    # kv projections are small (kv_heads x head_dim): when kv_heads doesn't
    # divide the model axis, REPLICATE rather than row-parallel — deferred
    # partial-sum reduction otherwise lands inside the attention tiles
    # (measured: 550 GB/step of f32 tile all-reduces on llama train_4k,
    # see EXPERIMENTS.md §Perf H1/iter2)
    "wk": [P(None, "model", None), P(None, None, None)],
    "wv": [P(None, "model", None), P(None, None, None)],
    "wo": [P("model", None, None), P(None, None, "model")],
    # MLA
    "wq_a": [P(None, "model")],
    "wq_b": [P(None, "model", None), P("model", None, None)],
    "wkv_a": [P("model", None), P()],
    "wkv_b": [P(None, "model", None), P("model", None, None)],
    "q_norm_scale": [P(None)],
    "kv_norm_scale": [P(None)],
}

_MLP_RULES = {
    "gate": [P(None, "model"), P("model", None)],
    "up": [P(None, "model"), P("model", None)],
    "down": [P("model", None), P(None, "model")],
}

_MAMBA_RULES = {
    "z_proj": [P(None, "model"), P("model", None)],
    "x_proj": [P(None, "model"), P("model", None)],
    "B_proj": [P("model", None), P()],
    "C_proj": [P("model", None), P()],
    "dt_proj": [P(None, "model"), P("model", None)],
    "conv_x_w": [P(None, "model")],
    "conv_x_b": [P("model")],
    "conv_B_w": [P()],
    "conv_B_b": [P()],
    "conv_C_w": [P()],
    "conv_C_b": [P()],
    "dt_bias": [P("model")],
    "A_log": [P("model")],
    "D": [P("model")],
    "norm_scale": [P("model")],
    "out_proj": [P("model", None), P(None, None)],
}


def _moe_expert_parallel(cfg: ArchConfig) -> bool:
    """EP when experts >= model-axis width (DeepSeekMoE 64e); TP otherwise."""
    return cfg.moe is not None and cfg.moe.num_experts >= 16


def _moe_rules(cfg: ArchConfig):
    if _moe_expert_parallel(cfg):
        return {
            "router": [P(None, None)],
            "gate": [P("model", None, None)],
            "up": [P("model", None, None)],
            "down": [P("model", None, None)],
        }
    return {
        "router": [P(None, None)],
        "gate": [P(None, None, "model")],
        "up": [P(None, None, "model")],
        "down": [P(None, "model", None)],
    }


# ---------------------------------------------------------------------------
# Fitting: first candidate whose assignments divide the dims
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(spec: P, shape, mesh: Mesh) -> bool:
    parts = tuple(spec) + (None,) * (len(shape) - len(spec))
    if len(parts) > len(shape):
        return False
    return all(dim % _axis_size(mesh, ax) == 0 for dim, ax in zip(shape, parts))


def _fit(candidates: Sequence[P], shape, mesh: Mesh, n_lead: int = 0) -> P:
    """First candidate (with n_lead leading None padding) that divides."""
    for cand in list(candidates) + [P()]:
        spec = P(*([None] * n_lead + list(cand)))
        if _fits(spec, shape, mesh):
            return spec
    return P()


def _names(path) -> List[str]:
    return [str(getattr(p, "key", getattr(p, "name", p))) for p in path]


def _candidates_for(path, cfg: ArchConfig):
    names = _names(path)
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""

    if leaf == "table":
        return [P("model", None), P(None, "model")]
    if parent == "moe":
        return _moe_rules(cfg).get(leaf, [P()])
    if parent == "shared":
        return _MLP_RULES.get(leaf, [P()])
    if parent == "mamba":
        return _MAMBA_RULES.get(leaf, [P()])
    if parent in ("attn", "cross") and leaf in _ATTN_RULES:
        return _ATTN_RULES[leaf]
    if parent == "mlp" and leaf in _MLP_RULES:
        return _MLP_RULES[leaf]
    if leaf == "scale":
        return [P(None)]
    return [P()]


def param_specs(cfg: ArchConfig, params, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params`` (arrays or structs)."""

    def rule(path, leaf):
        cands = _candidates_for(path, cfg)
        width = max(len(c) for c in cands)
        n_lead = max(len(leaf.shape) - width, 0)
        return _fit(cands, leaf.shape, mesh, n_lead)

    return jax.tree_util.tree_map_with_path(rule, params)


def zero_sharded_specs(cfg: ArchConfig, params, mesh: Mesh,
                       data_axes=("data",)):
    """Param spec + shard the largest unsharded divisible dim over the data
    axes (ZeRO-1 optimizer-moment sharding)."""
    base = param_specs(cfg, params, mesh)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    daxes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def widen(spec: P, leaf):
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        best, best_dim = None, 1
        for i, (s, ax) in enumerate(zip(shape, parts)):
            if ax is None and s % dsize == 0 and s > best_dim:
                best, best_dim = i, s
        if best is None:
            return spec
        parts[best] = daxes
        return P(*parts)

    return jax.tree.map(widen, base, params)


def opt_state_specs(cfg: ArchConfig, opt_state, params, mesh: Mesh):
    """Moments get ZeRO data-sharding; anything else mirrors params."""
    zspecs = zero_sharded_specs(cfg, params, mesh)
    out = {}
    for k, v in opt_state.items():
        out[k] = zspecs if k in ("m", "v") else jax.tree.map(lambda _: P(), v)
    return out


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _bspec(mesh: Mesh, batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = batch_axes(mesh)
    full = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % full == 0:
        return axes if len(axes) > 1 else axes[0]
    if "data" in axes and batch % mesh.shape["data"] == 0:
        return "data"
    if "pod" in axes and batch % mesh.shape["pod"] == 0:
        return "pod"
    return None


def batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    b_ax = _bspec(mesh, shape.global_batch)

    if shape.kind == "decode":
        return {"token": P(b_ax, None), "pos": P()}

    specs = {}
    if b_ax is None:
        # long-context: shard sequence over data instead of batch
        specs["tokens"] = P(None, "data")
    else:
        specs["tokens"] = P(b_ax, None)
    if cfg.modality == "vision":
        specs["patch_embed"] = P(b_ax, None, None)
    if cfg.modality == "audio":
        specs["frames"] = P(b_ax, None, None)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh, cache):
    """Decode caches: batch over pod+data, kv-heads over model, with
    fallbacks (seq over model; seq over data for batch-1 long context)."""
    b_ax = _bspec(mesh, shape.global_batch)

    def spec_for(path, leaf):
        leaf_name = _names(path)[-1]
        shp = leaf.shape
        if leaf_name == "k_pos":
            return P(None)
        if leaf_name in ("k", "v", "cross_k", "cross_v"):
            cands = [
                P(None, b_ax, None, "model", None),   # heads TP
                P(None, b_ax, "model", None, None),   # seq TP (kv heads < 16)
                P(None, b_ax, None, None, None),
                P(None, None, "data", "model", None), # batch-1 long context
                P(None, None, "data", None, None),
            ]
        elif leaf_name in ("latent", "krope"):
            cands = [P(None, b_ax, "model", None), P(None, b_ax, None, None),
                     P(None, None, "data", None)]
        elif leaf_name == "ssm":  # (L, B, H, hd, n)
            cands = [P(None, b_ax, "model", None, None),
                     P(None, None, "model", None, None),
                     P(None, b_ax, None, None, None)]
        elif leaf_name == "conv_x":  # (L, B, W-1, di)
            cands = [P(None, b_ax, None, "model"), P(None, None, None, "model"),
                     P(None, b_ax, None, None)]
        elif leaf_name.startswith("conv_"):
            cands = [P(None, b_ax, None, None), P()]
        else:
            cands = [P()]
        for c in cands:
            if len(c) <= len(shp) and _fits(c, shp, mesh):
                return c
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
