from repro.optim.optimizers import (adamw, make_optimizer, sgd, sgd_momentum)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = ["sgd", "sgd_momentum", "adamw", "make_optimizer",
           "constant", "cosine_decay", "linear_warmup_cosine"]
