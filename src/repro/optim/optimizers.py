"""Minimal optax-style optimizers (no external dependency).

Each optimizer is an (init, update) pair over pytrees:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

SGD is the paper-faithful optimizer (eq. 2: w <- w - alpha * grad); AdamW is
the production default for the LLM training path.  Moment tensors are stored
in float32 regardless of param dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _schedule(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


def sgd(lr) -> Optimizer:
    """Plain SGD — exactly the paper's update (2)."""
    lr = _schedule(lr)

    def init(params):
        return {}

    def update(grads, state, params, step):
        a = lr(step)
        return jax.tree.map(lambda g: -a * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def sgd_momentum(lr, momentum: float = 0.9) -> Optimizer:
    lr = _schedule(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        m = jax.tree.map(lambda mm, g: momentum * mm + g.astype(jnp.float32),
                         state["m"], grads)
        a = lr(step)
        return jax.tree.map(lambda mm: -a * mm, m), {"m": m}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    lr = _schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mhat_scale = 1.0 / (1.0 - b1 ** step_f)
        vhat_scale = 1.0 / (1.0 - b2 ** step_f)
        a = lr(step)

        def upd(mm, vv, p):
            u = (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps)
            return -a * (u + weight_decay * p.astype(jnp.float32))

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "sgd_momentum":
        return sgd_momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(name)
