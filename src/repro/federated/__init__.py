"""Federated round planning: joint device selection + per-participant
``(rate, n_c)`` operating points under a shared round deadline.

The device-count axis on top of the fleet engine: one jitted call
evaluates every candidate device's feasibility-masked joint grid and
solves participation with a sort-and-prefix-scan (see
:mod:`repro.federated.round_kernels` for the model), validated
end-to-end by :class:`FederatedSimulator`'s sharded local-SGD rounds
with deadline-gated aggregation.
"""
from repro.federated.round import (FEDERATED_TOKEN, RoundPlan, RoundPlanner,
                                   RoundRecord, plan_round_bruteforce,
                                   plan_round_reference, population_key)
from repro.federated.round_kernels import round_solve
from repro.federated.simulator import (FederatedRoundReport,
                                       FederatedSimulator,
                                       ParticipantResult)

__all__ = [
    "FEDERATED_TOKEN", "RoundPlan", "RoundPlanner", "RoundRecord",
    "plan_round_bruteforce", "plan_round_reference", "population_key",
    "round_solve", "FederatedRoundReport", "FederatedSimulator",
    "ParticipantResult",
]
