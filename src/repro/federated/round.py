"""Federated round planning: WHO participates, and at WHAT operating point.

The fleet engine plans every scenario independently; a federated round
couples them.  Given a population of candidate devices (plain
:class:`~repro.core.scenario.Scenario` objects — mixed link families
welcome, Gilbert-Elliott burst chains are the natural stragglers), a
round must pick a participant set and give each participant a
``(rate, n_c)`` operating point such that every participant's local run
finishes by the shared round deadline ``T`` (Corollary 1's
full-delivery regime), and the AGGREGATED loss bound

    ``F(K) = (1/K) sum_{i in topK} b_i - sigma (1 - 1/K)``

is minimal — see :mod:`repro.federated.round_kernels` for the model and
the jitted solve.  :class:`RoundPlanner` is the host wrapper: pad the
population (pow2 or an explicit serving bucket — pad lanes carry a
``valid=False`` flag so they can never join the round), run the one
jitted call, unpad, and return a :class:`RoundPlan`.

``plan_round_reference`` is the scalar-ish numpy oracle (per-device
numpy grids + stable sort + prefix scans) and ``plan_round_bruteforce``
the exponential subset enumeration for small populations; the federated
tests pin the planner to both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.experimental import enable_x64

from repro.core.bounds import BoundConstants, corollary1_bound
from repro.core.objectives import BoundObjective
from repro.core.planner import fleet_grid
from repro.core.scenario import Scenario
from repro.federated.round_kernels import round_solve
from repro.fleet.batch import ScenarioBatch
from repro.fleet.cache import quantise, scenario_key
from repro.fleet.objective_kernels import _maybe_shard
from repro.fleet.planner import _pad_batch
from repro.fleet.tracing import trace_delta
from repro.obs.runtime import record_solve

#: The objective token federated cache entries are scoped under — plays
#: the role ``Objective.cache_token()`` plays for per-device plans, so a
#: federated entry can never alias a single-device plan (see the
#: PlanCache isolation tests).
FEDERATED_TOKEN: Tuple[str, ...] = ("federated_corollary1",)


def population_key(population: Sequence[Scenario], deadline: float,
                   sig_digits: int = 3) -> Tuple:
    """Hashable quantised signature of a ROUND request: the request kind,
    the population size, the quantised round deadline and every member's
    :func:`~repro.fleet.cache.scenario_key` in population order.  Device
    order matters (it is the argmin tie-breaker), so no canonicalisation:
    two requests share an entry only if they are the same population."""
    return ("federated_round", len(population),
            quantise(float(deadline), sig_digits),
            tuple(scenario_key(sc, sig_digits) for sc in population))


@dataclass(frozen=True)
class RoundRecord:
    """Lightweight per-round result — what the cache stores and the
    serving layer streams back.  Per-participant tuples are ordered by
    ascending device index (the ``participants`` order)."""

    participants: Tuple[int, ...]
    n_participants: int
    deadline: float
    round_time: float
    objective_value: float
    n_eligible: int
    feasible: bool
    n_c: Tuple[int, ...]
    rate: Tuple[float, ...]
    objective: str = "federated_corollary1"


@dataclass(frozen=True)
class RoundPlan:
    """Struct-of-arrays round plan over the REAL population (pad lanes
    already stripped).  ``order`` is the full eligibility-then-bound sort
    of the population; the participant set is its first ``k_best``
    entries.  Per-device arrays cover every candidate — non-participants
    keep their best-feasible operating point (or ``inf``/garbage lanes
    when ineligible, flagged by ``eligible``) so callers can inspect the
    margin of devices that just missed the cut."""

    deadline: float
    order: np.ndarray            # (S,) int64  devices by ascending bound
    k_best: int                  # chosen participant count (0: infeasible)
    objective_value: float       # F(k_best); +inf when infeasible
    objective_curve: np.ndarray  # (S,) float64 F(K) for K = 1..S
    round_time: float            # straggler completion; +inf if infeasible
    n_eligible: int
    n_c: np.ndarray              # (S,) int64   per-device block size
    rate: np.ndarray             # (S,) float64 per-device rate
    bound_value: np.ndarray      # (S,) float64 best-feasible Corollary-1
    p_err: np.ndarray            # (S,) float64 loss prob at chosen rate
    n_o_eff: np.ndarray          # (S,) float64 effective overhead
    completion_time: np.ndarray  # (S,) float64 at the chosen point
    eligible: np.ndarray         # (S,) bool    has any feasible point

    def __len__(self) -> int:
        return int(self.order.shape[0])

    @property
    def feasible(self) -> bool:
        return self.k_best >= 1

    @property
    def participants(self) -> np.ndarray:
        """Chosen device indices, ascending."""
        return np.sort(self.order[:self.k_best])

    def record(self) -> RoundRecord:
        part = self.participants
        return RoundRecord(
            participants=tuple(int(i) for i in part),
            n_participants=int(self.k_best),
            deadline=float(self.deadline),
            round_time=float(self.round_time),
            objective_value=float(self.objective_value),
            n_eligible=int(self.n_eligible),
            feasible=self.feasible,
            n_c=tuple(int(self.n_c[i]) for i in part),
            rate=tuple(float(self.rate[i]) for i in part))


@dataclass(frozen=True)
class RoundPlanner:
    """One-jitted-call federated round planner over a population.

    ``grid_size`` is the per-device ``n_c`` grid width G (log-spaced
    1..N per device via :func:`~repro.core.planner.fleet_grid`, exactly
    the fleet planner's rule); ``shard`` lays the population out over the
    local "fleet" mesh like every fleet kernel.  The compiled shape is
    ``(S_pad, R, G)`` — pad populations to serving buckets with
    ``pad_to`` and :meth:`warm` each bucket to keep the zero
    post-warmup-traces guarantee.
    """

    grid_size: int = 64
    shard: bool = True

    @staticmethod
    def resolve_deadline(population: Sequence[Scenario]) -> float:
        """Default round deadline: the population's tightest per-device
        deadline (every member's own ``T`` honours it)."""
        return float(min(sc.T for sc in population))

    def cache_context(self, consts: BoundConstants) -> tuple:
        """Cache-key prefix round entries are scoped under (the federated
        analogue of ``FleetPlanner.cache_context``)."""
        return ("federated", consts, self.grid_size)

    def plan_round(self, population: Sequence[Scenario],
                   consts: BoundConstants, *,
                   deadline: Optional[float] = None,
                   pad_to: Optional[int] = None) -> RoundPlan:
        """Solve one federated round over the population."""
        population = list(population)
        if not population:
            raise ValueError("population must be non-empty")
        if deadline is None:
            deadline = self.resolve_deadline(population)
        S_real = len(population)
        batch = ScenarioBatch.from_scenarios(_pad_batch(population, pad_to))
        return self.plan_round_batch(batch, consts, deadline=deadline,
                                     n_real=S_real)

    def plan_round_batch(self, batch: ScenarioBatch,
                         consts: BoundConstants, *,
                         deadline: Optional[float] = None,
                         n_real: Optional[int] = None,
                         grid: Optional[np.ndarray] = None) -> RoundPlan:
        """Solve a round over a PREBUILT (already padded) batch.

        The zero-conversion entry point: callers that already hold a
        :class:`~repro.fleet.batch.ScenarioBatch` at a warmed pad shape
        (a serving layer, or ``bench_federated``'s timed loop — the same
        prebuilt-batch contract ``FleetPlanner.plan_batch`` times) skip
        the per-call ``Scenario`` -> arrays conversion.  The first
        ``n_real`` lanes are the real population (default: all of them);
        trailing lanes are padding and can never join the round.
        ``grid`` overrides the per-device ``n_c`` grid (must be
        ``(S, G)``; default :func:`~repro.core.planner.fleet_grid` at
        ``grid_size``); ``deadline`` defaults to the tightest real
        per-device ``T`` in the batch.
        """
        consts.validate()
        S = len(batch)
        n_real = S if n_real is None else int(n_real)
        if not 1 <= n_real <= S:
            raise ValueError(
                f"n_real={n_real} outside 1..{S} (batch size)")
        if deadline is None:
            deadline = float(np.min(batch.T[:n_real]))
        deadline = float(deadline)
        if deadline <= 0.0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        if grid is None:
            grid = fleet_grid(batch.N, self.grid_size)
        grid = np.ascontiguousarray(grid)
        if grid.ndim != 2 or grid.shape[0] != S:
            raise ValueError(
                f"grid has shape {grid.shape}, want ({S}, G)")
        S_real = n_real
        valid = np.zeros(S, bool)
        valid[:S_real] = True
        arrays = {
            "N": np.asarray(batch.N, np.int64),
            "union_no": batch.union_overhead,
            "tau_p": np.asarray(batch.tau_p, np.float64),
            "rates": np.asarray(batch.rates, np.float64),
            "rate_mask": batch.rate_mask,
            "grid": grid,
            "link_model_id": np.asarray(batch.link_model_id, np.int32),
            "link_params": np.asarray(batch.link_params, np.float64),
            "valid": valid,
        }
        fn = round_solve()
        with enable_x64():
            if self.shard:
                arrays = _maybe_shard(arrays, S)
            t0 = time.perf_counter()
            out = fn(T=np.float64(deadline),
                     sigma=np.float64(consts.variance_floor),
                     e0=np.float64(consts.init_gap),
                     contraction=np.float64(consts.contraction), **arrays)
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            res = {k: np.asarray(v) for k, v in out.items()}
            record_solve(t1 - t0, time.perf_counter() - t1)

        # unpad: pad lanes are never eligible, so the eligible prefix of
        # the sort consists of real devices only — dropping pad indices
        # from `order` keeps the participant prefix intact
        order = res["order"]
        order_real = np.asarray(order[order < S_real], np.int64)
        n_eligible = int(res["n_eligible"])
        feasible = n_eligible >= 1
        return RoundPlan(
            deadline=deadline,
            order=order_real,
            k_best=int(res["k_best"]) if feasible else 0,
            objective_value=float(res["objective_value"]) if feasible
            else np.inf,
            objective_curve=res["objective_curve"][:S_real],
            round_time=float(res["round_time"]) if feasible else np.inf,
            n_eligible=n_eligible,
            n_c=res["n_c"][:S_real],
            rate=res["rate"][:S_real],
            bound_value=res["bound_value"][:S_real],
            p_err=res["p_err"][:S_real],
            n_o_eff=res["n_o_eff"][:S_real],
            completion_time=res["completion_time"][:S_real],
            eligible=res["eligible"][:S_real])

    def warm(self, population: Sequence[Scenario], consts: BoundConstants,
             pad_to: Optional[int] = None) -> int:
        """AOT warmup: compile the round solve at this population's padded
        shape and return the number of fresh traces it cost.  Results are
        discarded; one call per serving population bucket gives the round
        path the zero-traces-after-warmup guarantee."""
        with trace_delta() as traces:
            self.plan_round(list(population), consts, pad_to=pad_to)
        return traces.total


# ---------------------------------------------------------------------------
# numpy references
# ---------------------------------------------------------------------------


def _best_feasible_numpy(sc: Scenario, consts: BoundConstants,
                         deadline: float, grid_size: int):
    """One device's feasibility-masked joint grid + rate-major argmin,
    in numpy, mirroring the kernel's inner sweep op-for-op."""
    obj = BoundObjective()
    grid = fleet_grid(sc.N, grid_size)                         # (G,)
    rates = np.asarray(sc.link.rates, np.float64)              # (R,)
    g = grid[None, :].astype(np.float64)
    n_o_eff = obj.effective_overhead(sc, g, rates[:, None])    # (R, G)
    vals = corollary1_bound(np.broadcast_to(g, n_o_eff.shape),
                            N=sc.N, T=deadline, n_o=n_o_eff,
                            tau_p=sc.tau_p, consts=consts)
    completion = np.ceil(float(sc.N) / g) * (g + n_o_eff)
    masked = np.where(completion <= deadline, vals, np.inf)
    flat = int(np.argmin(masked))          # C-order == rate-major
    ri, gi = divmod(flat, grid.shape[0])
    return {
        "bound": float(masked[ri, gi]),
        "completion": float(completion[ri, gi]),
        "n_c": int(grid[gi]), "rate": float(rates[ri]),
        "n_o_eff": float(n_o_eff[ri, gi]),
    }


def _participation_curve(best_b: np.ndarray, best_t: np.ndarray,
                         sigma: float):
    """Stable sort + prefix scans over per-device bests — the numpy
    mirror of the kernel's participation axis."""
    S = best_b.shape[0]
    eligible = np.isfinite(best_b)
    sort_key = np.where(eligible, best_b, np.inf)
    order = np.argsort(sort_key, kind="stable")
    K = np.arange(1, S + 1, dtype=np.float64)
    curve = np.cumsum(sort_key[order]) / K - sigma * (1.0 - 1.0 / K)
    n_eligible = int(eligible.sum())
    curve = np.where(np.arange(1, S + 1) <= n_eligible, curve, np.inf)
    t_sorted = np.where(eligible, best_t, -np.inf)[order]
    return order, curve, np.maximum.accumulate(t_sorted), n_eligible


def plan_round_reference(population: Sequence[Scenario],
                         consts: BoundConstants, *,
                         deadline: Optional[float] = None,
                         grid_size: int = 64) -> RoundPlan:
    """The numpy oracle: per-device scalar grid evaluations (a Python
    loop over the population — this IS the baseline ``bench_federated``
    measures the jitted planner against) followed by the same stable
    sort + prefix scans.  Argmin-identical to :meth:`RoundPlanner.
    plan_round` wherever the backend libm agrees (the federated parity
    tests assert participant sets and operating points exactly)."""
    consts.validate()
    population = list(population)
    if not population:
        raise ValueError("population must be non-empty")
    if deadline is None:
        deadline = RoundPlanner.resolve_deadline(population)
    deadline = float(deadline)
    S = len(population)
    best = [_best_feasible_numpy(sc, consts, deadline, grid_size)
            for sc in population]
    best_b = np.asarray([d["bound"] for d in best])
    best_t = np.asarray([d["completion"] for d in best])
    sigma = float(consts.variance_floor)
    order, curve, cmax, n_eligible = _participation_curve(best_b, best_t,
                                                          sigma)
    feasible = n_eligible >= 1
    k_best = int(np.argmin(curve)) + 1 if feasible else 0
    return RoundPlan(
        deadline=deadline, order=np.asarray(order, np.int64),
        k_best=k_best,
        objective_value=float(curve[k_best - 1]) if feasible else np.inf,
        objective_curve=curve,
        round_time=float(cmax[k_best - 1]) if feasible else np.inf,
        n_eligible=n_eligible,
        n_c=np.asarray([d["n_c"] for d in best], np.int64),
        rate=np.asarray([d["rate"] for d in best]),
        bound_value=best_b,
        p_err=np.asarray([0.0] * S),  # not replicated by the oracle
        n_o_eff=np.asarray([d["n_o_eff"] for d in best]),
        completion_time=best_t,
        eligible=np.isfinite(best_b))


def plan_round_bruteforce(population: Sequence[Scenario],
                          consts: BoundConstants, *,
                          deadline: Optional[float] = None,
                          grid_size: int = 64) -> RoundRecord:
    """Exponential ground truth for SMALL populations: scalar double loop
    over every device's ``(rate, n_c)`` points, then every nonempty
    subset of eligible devices scored by the aggregation objective (sums
    accumulated in global sorted order so float rounding matches the
    prefix-scan path).  Ties prefer smaller F, then smaller K, then the
    lexicographically smallest participant tuple."""
    consts.validate()
    population = list(population)
    S = len(population)
    if S > 16:
        raise ValueError(f"brute force caps at 16 devices, got {S}")
    if deadline is None:
        deadline = RoundPlanner.resolve_deadline(population)
    deadline = float(deadline)
    obj = BoundObjective()
    sigma = float(consts.variance_floor)

    best: List[dict] = []
    for sc in population:
        grid = fleet_grid(sc.N, grid_size)
        dev = {"bound": np.inf, "completion": np.inf, "n_c": 0,
               "rate": 0.0}
        for rate in sc.link.rates:          # rate-major: first rate wins
            for n_c in grid:                # then first grid point
                n_o_eff = float(obj.effective_overhead(
                    sc, np.float64(n_c), float(rate)))
                t = np.ceil(sc.N / np.float64(n_c)) * (
                    np.float64(n_c) + n_o_eff)
                if t > deadline:
                    continue
                b = float(corollary1_bound(
                    np.float64(n_c), N=sc.N, T=deadline, n_o=n_o_eff,
                    tau_p=sc.tau_p, consts=consts))
                if b < dev["bound"]:
                    dev = {"bound": b, "completion": float(t),
                           "n_c": int(n_c), "rate": float(rate)}
        best.append(dev)

    eligible = [i for i in range(S) if np.isfinite(best[i]["bound"])]
    if not eligible:
        return RoundRecord(participants=(), n_participants=0,
                           deadline=deadline, round_time=np.inf,
                           objective_value=np.inf, n_eligible=0,
                           feasible=False, n_c=(), rate=())
    # global sorted order (by bound, ties by index) fixes the float
    # accumulation order for EVERY subset, so subset sums of the same
    # members always round identically
    rank = {i: r for r, i in enumerate(
        sorted(eligible, key=lambda i: (best[i]["bound"], i)))}

    from itertools import combinations
    champion = None
    for K in range(1, len(eligible) + 1):
        for subset in combinations(eligible, K):
            total = 0.0
            for i in sorted(subset, key=rank.__getitem__):
                total += best[i]["bound"]
            F = total / K - sigma * (1.0 - 1.0 / K)
            cand = (F, K, tuple(sorted(subset)))
            if champion is None or cand < champion:
                champion = cand
    F, K, subset = champion
    return RoundRecord(
        participants=subset, n_participants=K, deadline=deadline,
        round_time=max(best[i]["completion"] for i in subset),
        objective_value=F, n_eligible=len(eligible), feasible=True,
        n_c=tuple(best[i]["n_c"] for i in subset),
        rate=tuple(best[i]["rate"] for i in subset))
