"""End-to-end validation of a planned federated round.

:class:`FederatedSimulator` extends the PR-1 :class:`~repro.core.
scenario.Simulator`: given the population, a :class:`~repro.federated.
round.RoundPlan` and a :class:`~repro.core.scenario.RidgeTask`, it hands
each PARTICIPANT a disjoint remainder-exact shard of the task's data
(:func:`repro.core.multidevice.split_samples`), runs each participant's
local pipelined SGD at its planned ``(rate, n_c)`` operating point —
i.e. its planned effective overhead — until the round deadline, and
aggregates by DEADLINE-GATED model averaging: a straggler whose link
fails to deliver its full shard by ``T`` (the realised run, not the
plan, decides) is dropped from the average, exactly the semantics the
planner's feasibility mask assumed.  The report carries both the
per-participant runs and the aggregated model's loss on the FULL
dataset, so a planned round can be checked end-to-end: every planned
participant should complete, and the aggregate loss should track the
planned bound's ordering.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.multidevice import split_samples
from repro.core.pipeline import ridge_loss_full, run_pipelined_sgd
from repro.core.scenario import RidgeTask, Scenario, Simulator
from repro.federated.round import RoundPlan


@dataclass(frozen=True)
class ParticipantResult:
    """One participant's local run inside a round."""

    device: int                 # index into the population
    shard_size: int
    n_c: int
    rate: float
    delivered: int              # samples the realised run delivered by T
    completed: bool             # delivered its FULL shard by the deadline
    final_loss: float           # local ridge loss of its own final model
    w_final: np.ndarray


@dataclass(frozen=True)
class FederatedRoundReport:
    """Deadline-gated aggregation of one simulated round."""

    deadline: float
    participants: Tuple[ParticipantResult, ...]
    n_completed: int
    aggregated_loss: float      # full-dataset loss of the averaged model
    w_round: Optional[np.ndarray]
    plan: RoundPlan = field(repr=False, default=None)

    @property
    def completion_rate(self) -> float:
        if not self.participants:
            return 0.0
        return self.n_completed / len(self.participants)


class FederatedSimulator(Simulator):
    """``run_round(population, plan, task) -> FederatedRoundReport``.

    Inherits the single-scenario ``run`` (a federated deployment still
    simulates individual links with it); ``run_round`` adds the sharded
    multi-participant round with deadline-gated averaging.
    """

    def run_round(self, population: Sequence[Scenario], plan: RoundPlan,
                  task: RidgeTask, seed: int = 0) -> FederatedRoundReport:
        population = list(population)
        if len(population) != len(plan):
            raise ValueError(
                f"plan covers {len(plan)} devices but population has "
                f"{len(population)}")
        participants = [int(i) for i in plan.participants]
        if not participants:
            return FederatedRoundReport(
                deadline=plan.deadline, participants=(), n_completed=0,
                aggregated_loss=float("inf"), w_round=None, plan=plan)

        X = np.asarray(task.X, np.float64)
        y = np.asarray(task.y, np.float64)
        shards = split_samples(X.shape[0], len(participants))
        offsets = np.concatenate([[0], np.cumsum(shards)])

        results: List[ParticipantResult] = []
        for k, dev in enumerate(participants):
            sc = population[dev]
            Xk = X[offsets[k]:offsets[k + 1]]
            yk = y[offsets[k]:offsets[k + 1]]
            n_k = int(shards[k])
            # the planned block size was sized against the device's OWN
            # dataset N; the task shard may be smaller — clamp, and price
            # the link-induced effective overhead at the REALISED block
            # size (it scales with n_c through the ARQ inflation, so
            # reusing the planned value after a clamp could even go more
            # negative than the block is long)
            n_c_k = max(1, min(int(plan.n_c[dev]), n_k))
            n_o_k = float(sc.effective_overhead(np.float64(n_c_k),
                                                float(plan.rate[dev])))
            res = run_pipelined_sgd(
                Xk, yk, n_c=n_c_k, n_o=n_o_k,
                T=plan.deadline, tau_p=float(sc.tau_p), alpha=task.alpha,
                lam=task.lam, seed=seed + k,
                record_every=task.record_every)
            results.append(ParticipantResult(
                device=dev, shard_size=n_k, n_c=n_c_k,
                rate=float(plan.rate[dev]), delivered=int(res.delivered),
                completed=int(res.delivered) >= n_k,
                final_loss=float(res.final_loss),
                w_final=np.asarray(res.w_final, np.float64)))

        done = [r for r in results if r.completed]
        if done:
            w_round = np.mean([r.w_final for r in done], axis=0)
            agg = float(ridge_loss_full(w_round, X, y, task.lam))
        else:
            w_round, agg = None, float("inf")
        return FederatedRoundReport(
            deadline=plan.deadline, participants=tuple(results),
            n_completed=len(done), aggregated_loss=agg,
            w_round=w_round, plan=plan)
