"""The jitted federated-round kernel: per-device grids + participation scan.

One ``jax.jit`` call solves the WHOLE round: it reuses the fleet
engine's per-scenario joint ``(rate, n_c)`` grid evaluation (the same
link dispatch and Corollary-1 value function the registered
``corollary1`` objective kernel runs — see
:mod:`repro.fleet.objective_kernels`), masks the grid to the
DEADLINE-FEASIBLE points, reduces each device to its best feasible
operating point, and then solves the participation axis with a
sort-and-prefix-scan:

  1. **Inner sweep** — for every candidate device, every ``(rate, n_c)``
     point gets its Corollary-1 bound at the round deadline ``T`` and
     its completion time ``ceil(N / n_c) * (n_c + n_o_eff)`` (the time
     the device's last block lands; ``completion <= T`` is exactly the
     "full transfer by the deadline" regime boundary of Corollary 1).
     Infeasible points are masked to ``+inf`` and each device keeps its
     rate-major argmin — the same tie-breaking contract as
     ``_reduce_joint_argmin``.
  2. **Participation scan** — devices sort ascending by best-feasible
     bound (stable: ties keep population order), a prefix cumsum gives
     the aggregated bound ``F(K)`` for EVERY participant count ``K`` in
     one pass, and a prefix cummax gives each prefix's straggler-bounded
     round time.  ``argmin F(K)`` (first minimum, i.e. the smallest
     optimal ``K``) picks the round.

The aggregated objective is

    ``F(K) = (1/K) sum_{i in topK} b_i  -  sigma * (1 - 1/K)``

i.e. ``sigma / K + mean(b_i - sigma)``: the ``K`` participants train
independently on DISJOINT shards, so averaging their models keeps the
mean of the per-device bias terms (each bound's excess over the SGD
noise floor ``sigma = consts.variance_floor``) while the independent
gradient-noise floors average down as ``sigma / K``.  More devices
always shrink the noise term but drag the mean toward worse devices —
participation count is a real axis, not a monotone knob.

Every prefix over eligible devices already satisfies the deadline
(each member's best-feasible completion is ``<= T``), so the straggler
max is a REPORT (the realised round length), not a second constraint.

``valid`` masks the batch-padding lanes out of eligibility — a padded
copy of a real device must never join the round (the fleet planner can
discard pad results; a prefix scan cannot).

Like every fleet kernel, the body's first statement is
:func:`repro.fleet.tracing.record_trace` — the serving layer's
zero-post-warmup-traces audit counts this kernel too.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.fleet.link_kernels import kernel_table, kernel_table_version
from repro.fleet.objective_kernels import _corollary1_values, _switch_p_err
from repro.fleet.tracing import record_trace


def _build_round_solve(branches):
    """Jit the round solve closed over the link-kernel branch table."""

    @jax.jit
    def _solve(N, T, union_no, tau_p, rates, rate_mask, grid,
               link_model_id, link_params, valid, sigma, e0, contraction):
        # runs once per TRACE — the serving retrace audit
        record_trace(("federated",) + tuple(grid.shape))
        S = rates.shape[0]
        rate = rates[:, :, None]                               # (S, R, 1)
        g = grid[:, None, :].astype(jnp.float64)               # (S, 1, G)

        # ---- inner sweep: the fleet engine's joint-grid evaluation ----
        p = _switch_p_err(branches, link_model_id, link_params, rates)
        raw = g / rate + union_no[:, None, None]               # (S, R, G)
        dur = raw / (1.0 - p[:, :, None])
        n_o_eff = dur - g
        vals = _corollary1_values(
            g, N[:, None, None].astype(jnp.float64), T, n_o_eff,
            tau_p[:, None, None], sigma, e0, contraction)

        # completion = ceil(N / n_c) blocks at the REBUILT duration
        # g + n_o_eff (the scalar schedule's op order, not the raw dur),
        # so the numpy reference reproduces the comparison bit-for-bit;
        # completion <= T  <=>  Corollary 1's full-transfer regime
        blocks = jnp.ceil(N[:, None, None].astype(jnp.float64) / g)
        completion = blocks * (g + n_o_eff)
        feasible = (completion <= T) & rate_mask[:, :, None]
        masked = jnp.where(feasible, vals, jnp.inf)

        # per-device best feasible point, rate-major tie-breaking (the
        # _reduce_joint_argmin contract: first grid point within a rate,
        # then first rate)
        gi_per_rate = jnp.argmin(masked, axis=2)               # (S, R)
        ri = jnp.argmin(jnp.min(masked, axis=2), axis=1)       # (S,)
        s = jnp.arange(S)
        gi = gi_per_rate[s, ri]
        best = masked[s, ri, gi]                               # +inf if none
        best_t = completion[s, ri, gi]

        # ---- participation axis: sort + prefix scans over devices ----
        eligible = jnp.isfinite(best) & valid
        sort_key = jnp.where(eligible, best, jnp.inf)
        order = jnp.argsort(sort_key)          # stable: ties keep index order
        b_sorted = sort_key[order]
        t_sorted = jnp.where(eligible, best_t, -jnp.inf)[order]

        K = jnp.arange(1, S + 1, dtype=jnp.float64)
        curve = jnp.cumsum(b_sorted) / K - sigma * (1.0 - 1.0 / K)
        n_eligible = jnp.sum(eligible)
        curve = jnp.where(jnp.arange(1, S + 1) <= n_eligible,
                          curve, jnp.inf)
        k_best = jnp.argmin(curve) + 1         # ties -> smallest K
        round_time = jax.lax.cummax(t_sorted)[k_best - 1]

        return {
            "order": order, "k_best": k_best,
            "objective_value": curve[k_best - 1],
            "objective_curve": curve,
            "round_time": round_time, "n_eligible": n_eligible,
            "n_c": grid[s, gi], "rate": rates[s, ri],
            "bound_value": best, "p_err": p[s, ri],
            "n_o_eff": n_o_eff[s, ri, gi], "completion_time": best_t,
            "eligible": eligible,
        }

    return _solve


@lru_cache(maxsize=4)
def _round_solve_for(link_version: int):
    """The jitted round solve for the CURRENT link-kernel table; keyed on
    the registry version so late link plugins retrace instead of
    stale-dispatching (same scheme as ``_grid_solve_for``)."""
    del link_version  # cache key only
    return _build_round_solve(kernel_table())


def round_solve():
    """The jitted federated-round solve for the current link registry."""
    return _round_solve_for(kernel_table_version())
