"""Deterministic fault injection for the planning service.

Chaos testing is only useful when a failure found once can be found
again: :class:`FaultPlan` turns a seed plus per-injection-point rates
into a REPRODUCIBLE fault schedule — the decision for the n-th
invocation at a named injection point is a pure function of
``(seed, point, n)``, so the same spec replays the same faults whatever
wall-clock timing the run has.  The serving layer draws at its named
points (``solve.error`` / ``solve.latency`` in ``_plan_group``,
``queue.stall`` in the micro-batcher, ``cache.corrupt`` in
:class:`~repro.fleet.cache.PlanCache`); everything else in the repo is
chaos-free unless a plan is explicitly wired in.

Standalone on purpose (no ``repro.serve``/``repro.fleet`` imports), so
any layer can accept a plan without cycles.
"""
from repro.chaos.faults import (INJECTION_POINTS, FaultAction, FaultPlan,
                                FaultRule, InjectedFault, parse_chaos_spec)

__all__ = [
    "FaultAction", "FaultPlan", "FaultRule", "INJECTION_POINTS",
    "InjectedFault", "parse_chaos_spec",
]
