"""Seeded, schedule-based fault injection (see package docstring).

A :class:`FaultPlan` is a set of :class:`FaultRule`\\ s, one per named
injection point.  Each call to :meth:`FaultPlan.draw` advances that
point's invocation counter and returns the scheduled
:class:`FaultAction` (or ``None``).  The decision for invocation ``n``
is ``u(seed, point, n) < rate`` where ``u`` is a uniform derived from a
SHA-256 of the triple — no shared RNG state, so the schedule at one
point is independent of how many draws other points made, and two runs
with the same seed and the same per-point invocation sequences inject
byte-identical fault schedules (the chaos determinism property test
asserts exactly this).

``parse_chaos_spec`` turns the CLI's ``--chaos-spec`` string into a
plan: a comma-separated ``key=value`` list, e.g.::

    seed=7,solve_error=0.2,solve_latency=0.15:25ms,cache_corrupt=0.05,
    queue_stall=0.02:10ms

Rate-only points take ``<rate>``; latency-type points take
``<rate>:<duration>`` where the duration suffix is ``ms`` or ``s``
(default seconds).  Unknown keys raise ``ValueError`` — a typo'd
injection point silently injecting nothing would make a chaos gate
vacuous.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

#: The injection points the serving stack draws at.  ``error`` points
#: raise :class:`InjectedFault`; ``latency``/``stall`` points sleep.
INJECTION_POINTS = (
    "solve.error",      # _plan_group: raise before the chunk solve
    "solve.latency",    # _plan_group: artificial delay before the solve
    "queue.stall",      # MicroBatcher worker: delay before planning
    "cache.corrupt",    # PlanCache.get: flip the entry's checksum
)

#: Points whose action carries a duration rather than an exception.
_TIMED_POINTS = ("solve.latency", "queue.stall")


class InjectedFault(RuntimeError):
    """A chaos-injected transient solve failure.  Deliberately a plain
    ``RuntimeError`` subtype: the resilience layer must treat it exactly
    like any other transient exception (retry, then degrade) — injected
    faults that needed special handling would test nothing."""


@dataclass(frozen=True)
class FaultRule:
    """Injection schedule for one point: fire ``rate`` of invocations;
    timed points sleep ``duration_s`` when they fire."""

    point: str
    rate: float
    duration_s: float = 0.0

    def __post_init__(self):
        if self.point not in INJECTION_POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; valid: "
                f"{list(INJECTION_POINTS)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(
                f"{self.point}: rate must be in [0, 1], got {self.rate}")
        if self.duration_s < 0.0:
            raise ValueError(
                f"{self.point}: duration must be >= 0, got "
                f"{self.duration_s}")


@dataclass(frozen=True)
class FaultAction:
    """One scheduled fault: what the drawing site should do."""

    point: str
    #: ``"error"`` (raise :class:`InjectedFault`) or ``"delay"`` (sleep)
    kind: str
    duration_s: float = 0.0
    #: the invocation index that fired (journal/debug breadcrumb)
    index: int = 0


def _uniform(seed: int, point: str, index: int) -> float:
    """Uniform in [0, 1) as a pure function of (seed, point, index)."""
    digest = hashlib.sha256(
        f"{int(seed)}/{point}/{int(index)}".encode()).digest()
    return int.from_bytes(digest[:8], "big") / float(1 << 64)


class FaultPlan:
    """Deterministic fault schedule over the named injection points.

    Thread-safe: the per-point invocation counters are the only mutable
    state.  ``fires``/``draws`` expose lifetime per-point counts for the
    ``repro_resilience_faults_injected_total`` export.
    """

    def __init__(self, seed: int = 0,
                 rules: Iterable[FaultRule] = ()):
        self.seed = int(seed)
        self.rules: Dict[str, FaultRule] = {}
        for rule in rules:
            if rule.point in self.rules:
                raise ValueError(
                    f"duplicate rule for injection point {rule.point!r}")
            self.rules[rule.point] = rule
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self.fires: Dict[str, int] = {}

    @property
    def draws(self) -> Dict[str, int]:
        """Lifetime draw counts per point (fired or not)."""
        with self._lock:
            return dict(self._counters)

    def enabled(self, point: str) -> bool:
        rule = self.rules.get(point)
        return rule is not None and rule.rate > 0.0

    def _decide(self, point: str, index: int) -> Optional[FaultAction]:
        rule = self.rules.get(point)
        if rule is None or rule.rate <= 0.0:
            return None
        if _uniform(self.seed, point, index) >= rule.rate:
            return None
        kind = "delay" if point in _TIMED_POINTS else "error"
        return FaultAction(point=point, kind=kind,
                           duration_s=rule.duration_s, index=index)

    def draw(self, point: str) -> Optional[FaultAction]:
        """Advance ``point``'s invocation counter and return the
        scheduled action for it (``None`` = no fault this invocation)."""
        if point not in INJECTION_POINTS:
            raise ValueError(
                f"unknown injection point {point!r}; valid: "
                f"{list(INJECTION_POINTS)}")
        with self._lock:
            index = self._counters.get(point, 0)
            self._counters[point] = index + 1
        action = self._decide(point, index)
        if action is not None:
            with self._lock:
                self.fires[point] = self.fires.get(point, 0) + 1
        return action

    def schedule(self, point: str, n: int) -> List[bool]:
        """The first ``n`` fire/no-fire decisions at ``point`` — PURE
        (does not advance the counters), so tests can assert the exact
        schedule a run will see before running it."""
        return [self._decide(point, i) is not None for i in range(n)]

    def reset(self) -> None:
        """Rewind every invocation counter (fresh replay, same seed)."""
        with self._lock:
            self._counters.clear()
            self.fires = {}

    def spec(self) -> str:
        """A ``parse_chaos_spec``-round-trippable description."""
        parts = [f"seed={self.seed}"]
        for point in INJECTION_POINTS:
            rule = self.rules.get(point)
            if rule is None:
                continue
            key = point.replace(".", "_")
            if point in _TIMED_POINTS:
                parts.append(f"{key}={rule.rate:g}:{rule.duration_s:g}s")
            else:
                parts.append(f"{key}={rule.rate:g}")
        return ",".join(parts)


def _parse_duration(tok: str, key: str) -> float:
    tok = tok.strip()
    try:
        if tok.endswith("ms"):
            return float(tok[:-2]) / 1e3
        if tok.endswith("s"):
            return float(tok[:-1])
        return float(tok)
    except ValueError:
        raise ValueError(
            f"chaos spec: bad duration {tok!r} for {key!r} "
            "(want e.g. 25ms or 0.025s)") from None


def parse_chaos_spec(spec: str) -> FaultPlan:
    """Parse a ``--chaos-spec`` string into a :class:`FaultPlan` (see
    module docstring for the grammar).  An empty spec is an empty plan
    (no faults), so ``--chaos-spec ''`` is a clean control run."""
    seed = 0
    rules: List[FaultRule] = []
    for raw in str(spec).split(","):
        part = raw.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"chaos spec: expected key=value, got {part!r}")
        key, value = (t.strip() for t in part.split("=", 1))
        if key == "seed":
            try:
                seed = int(value)
            except ValueError:
                raise ValueError(
                    f"chaos spec: bad seed {value!r}") from None
            continue
        point = key.replace("_", ".", 1)
        if point not in INJECTION_POINTS:
            raise ValueError(
                f"chaos spec: unknown injection point {key!r}; valid: "
                f"{[p.replace('.', '_') for p in INJECTION_POINTS]}")
        duration = 0.0
        rate_tok = value
        if ":" in value:
            rate_tok, dur_tok = value.split(":", 1)
            duration = _parse_duration(dur_tok, key)
        if duration and point not in _TIMED_POINTS:
            raise ValueError(
                f"chaos spec: {key!r} takes a bare rate (no duration)")
        try:
            rate = float(rate_tok)
        except ValueError:
            raise ValueError(
                f"chaos spec: bad rate {rate_tok!r} for {key!r}") from None
        rules.append(FaultRule(point=point, rate=rate, duration_s=duration))
    return FaultPlan(seed=seed, rules=rules)
