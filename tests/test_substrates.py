"""Optimizers, schedules, checkpointing, data pipeline, hlo_cost."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.stream import BlockStreamer
from repro.data.synthetic import SyntheticTokens, make_regression_dataset
from repro.optim.optimizers import adamw, apply_updates, sgd, sgd_momentum
from repro.optim.schedules import cosine_decay, linear_warmup_cosine


def _quadratic_min(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    for i in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        updates, state = opt.update(grads, state, params, jnp.asarray(i))
        params = apply_updates(params, updates)
    return params["w"], target


def test_sgd_converges():
    w, t = _quadratic_min(sgd(0.1))
    np.testing.assert_allclose(w, t, atol=1e-3)


def test_momentum_converges():
    w, t = _quadratic_min(sgd_momentum(0.05, 0.9))
    np.testing.assert_allclose(w, t, atol=1e-3)


def test_adamw_converges():
    w, t = _quadratic_min(adamw(0.1, weight_decay=0.0), steps=400)
    np.testing.assert_allclose(w, t, atol=1e-2)


def test_schedules_shapes():
    s = linear_warmup_cosine(1e-3, warmup=10, total_steps=100)
    vals = [float(s(jnp.asarray(i))) for i in (0, 5, 10, 50, 100)]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(5e-4)
    assert vals[2] == pytest.approx(1e-3)
    assert vals[3] < vals[2]
    c = cosine_decay(1e-3, 100)
    assert float(c(jnp.asarray(0))) == pytest.approx(1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 7
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_block_streamer_protocol():
    s = BlockStreamer(n_samples=100, n_c=32, n_o=8.0, seed=0)
    seen = []
    while True:
        blk = s.next_block()
        if blk is None:
            break
        seen.extend(blk.tolist())
    assert sorted(seen) == list(range(100))  # permutation, complete, no dup
    assert s.n_blocks_total == 4
    assert s.block_duration == 40.0


def test_synthetic_tokens_deterministic():
    a = SyntheticTokens(100, 16, 4, seed=3).batch(5)
    b = SyntheticTokens(100, 16, 4, seed=3).batch(5)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 16)
    assert a.max() < 100


def test_regression_dataset_spectrum():
    X, y, w = make_regression_dataset(n=2048, d=8, l_max=2.0, l_min=0.05)
    eigs = np.linalg.eigvalsh(X.T @ X / len(X))
    assert eigs[-1] == pytest.approx(2.0, rel=1e-6)
    assert eigs[0] == pytest.approx(0.05, rel=1e-6)


def test_hlo_cost_scan_multiplication():
    from repro.launch.hlo_cost import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, xs).compile()
    cost = analyze_hlo(compiled.as_text())
    assert cost.flops == pytest.approx(7 * 2 * 64 ** 3)
