"""End-to-end decode parity: feeding tokens one-by-one through decode_step
(empty cache, teacher forcing) must reproduce the full-forward logits.
This exercises KV caches, ring indexing, RoPE-at-write, and every layer's
decode path for representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.models import init_params, make_decode_step, make_prefill_step
from repro.models.decode import init_cache
from repro.models.transformer import forward, lm_head_table
from repro.models.layers import unembed


def _full_logits(cfg, params, batch):
    hidden, _, _, _ = forward(params, batch, cfg)
    table = lm_head_table(params, cfg)
    return unembed(table, hidden[:, -1].astype(jnp.float32),
                   cfg.final_logit_softcap)


def _decode_all(cfg, params, tokens, shape):
    cache = init_cache(cfg, shape)
    if cache.get("k_pos") is not None:
        cache = dict(cache, k_pos=jnp.full_like(cache["k_pos"], -1))
    step = jax.jit(make_decode_step(cfg, shape))
    logits = None
    for t in range(tokens.shape[1]):
        logits, cache = step(params, cache,
                             {"token": tokens[:, t:t + 1],
                              "pos": jnp.asarray(t, jnp.int32)})
    return logits


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m", "minicpm3-4b",
                                  "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, seed=0)
    b, t = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (b, t), 0,
                                cfg.vocab_size, jnp.int32)
    shape = InputShape("parity", t, b, "decode")
    ref = _full_logits(cfg, params, {"tokens": tokens})
    got = _decode_all(cfg, params, tokens, shape)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_prefill_logits_match_forward():
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, seed=0)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0,
                                cfg.vocab_size, jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg))
    logits, _ = prefill(params, {"tokens": tokens})
    ref = _full_logits(cfg, params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)
