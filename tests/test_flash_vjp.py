"""Flash-attention custom VJP (the §Perf H1 optimisation) must match the
default-AD blockwise path in both outputs and gradients, for every mask
variant the architectures use."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.blockwise import flash_attention
from repro.models.flash_vjp import flash_attention_vjp

VARIANTS = [
    dict(causal=True),
    dict(causal=True, window=64),
    dict(causal=True, window=64, sink=16),
    dict(causal=True, logit_softcap=30.0),
    dict(causal=False),
]


@pytest.fixture(scope="module")
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, Hkv, D = 2, 192, 8, 4, 32
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, Hkv, D)),
            jax.random.normal(ks[2], (B, S, Hkv, D)))


@pytest.mark.parametrize("kw", VARIANTS, ids=[str(v) for v in VARIANTS])
def test_forward_matches(qkv, kw):
    q, k, v = qkv
    out1 = flash_attention_vjp(q, k, v, q_block=64, k_block=64, **kw)
    out2 = flash_attention(q, k, v, q_block=64, k_block=64, **kw)
    np.testing.assert_allclose(out1, out2, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("kw", VARIANTS, ids=[str(v) for v in VARIANTS])
def test_gradients_match(qkv, kw):
    q, k, v = qkv

    def loss(fn):
        return lambda q, k, v: jnp.sum(
            fn(q, k, v, q_block=64, k_block=64, **kw) ** 2)

    g1 = jax.grad(loss(flash_attention_vjp), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(flash_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_model_loss_invariant_under_flag():
    """Whole-model loss identical with the flag on/off (llama reduced)."""
    from repro.configs import get_config, reduced
    from repro.models import runtime
    from repro.models.transformer import init_model, loss_fn

    cfg = reduced(get_config("llama3.2-1b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    with runtime.flags(flash_vjp=False):
        l0 = loss_fn(params, {"tokens": tok}, cfg)
        g0 = jax.grad(loss_fn)(params, {"tokens": tok}, cfg)
    with runtime.flags(flash_vjp=True):
        l1 = loss_fn(params, {"tokens": tok}, cfg)
        g1 = jax.grad(loss_fn)(params, {"tokens": tok}, cfg)
    # the two paths reduce in different orders; f32 accumulation differences
    # pass through 2 layers + the CE logsumexp
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=3e-4)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_grad_accum_matches_full_batch():
    """Accumulated microbatch grads == full-batch grads (same step)."""
    from repro.configs import get_config, reduced
    from repro.models import init_params, make_train_step
    from repro.optim.optimizers import sgd

    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, 0)
    opt = sgd(1e-2)
    tok = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    step = jnp.zeros((), jnp.int32)
    p1, _, m1 = make_train_step(cfg, opt)(params, opt.init(params), step, batch)
    p2, _, m2 = make_train_step(cfg, opt, grad_accum=2)(
        params, opt.init(params), step, batch)
    np.testing.assert_allclose(np.asarray(m1["loss"]), np.asarray(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
