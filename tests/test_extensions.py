"""Paper Sec.-6 extensions: erasure channel + rate selection, multi-device
TDMA, and the Theorem-1 Monte-Carlo evaluator."""
import numpy as np
import pytest

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants
from repro.core.channel import ErasureChannel, plan_with_channel, simulate_noisy_stream
from repro.core.montecarlo import estimate_theorem1
from repro.core.multidevice import MultiDeviceSchedule, plan_multi_device
from repro.data.synthetic import make_regression_dataset

CONSTS = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=1.0, alpha=EP.alpha)
N, T = EP.n_samples, 1.5 * EP.n_samples


# ---------------------------------------------------------------------------
# erasure channel
# ---------------------------------------------------------------------------


def test_error_probability_monotone_in_rate():
    ch = ErasureChannel(beta=0.3)
    rates = [1.0, 1.5, 2.0, 4.0]
    ps = [ch.p_err(r) for r in rates]
    assert all(a <= b for a, b in zip(ps, ps[1:]))
    assert ps[0] == pytest.approx(0.0)


def test_expected_block_time_tradeoff():
    """Faster rate shortens payload but costs retransmissions — the
    expected block time is non-monotone in rate (a real trade-off)."""
    ch = ErasureChannel(beta=0.6)
    times = [ch.expected_block_time(1000, 50.0, r) for r in (1.0, 1.5, 8.0)]
    assert times[1] < times[0]          # moderate speed-up wins
    assert times[2] > times[1]          # reckless rate loses to ARQ


def test_joint_rate_block_planning():
    ch = ErasureChannel(beta=0.4)
    plan = plan_with_channel(N=N, T=T, n_o=500.0, tau_p=1.0, consts=CONSTS,
                             channel=ch)
    assert 1 <= plan["n_c"] <= N
    assert plan["rate"] >= 1.0
    assert np.isfinite(plan["bound"])
    # a noisier channel can never improve the achievable bound
    noisy = plan_with_channel(N=N, T=T, n_o=500.0, tau_p=1.0, consts=CONSTS,
                              channel=ErasureChannel(beta=0.4, p_base=0.3))
    assert noisy["bound"] >= plan["bound"] - 1e-12


def test_noisy_stream_simulation():
    ch = ErasureChannel(beta=0.2, p_base=0.1)
    times, counts = simulate_noisy_stream(
        n_samples=1000, n_c=100, n_o=20.0, rate=1.5, channel=ch, T=5000.0)
    assert counts[-1] <= 1000
    assert (np.diff(times) > 0).all()
    assert (np.diff(counts) >= 0).all()
    # with losses, delivery takes longer than the noiseless timeline
    noiseless_end = 10 * (100 / 1.5 + 20.0)
    if counts[-1] == 1000:
        assert times[-1] >= noiseless_end - 1e-9


# ---------------------------------------------------------------------------
# multi-device TDMA
# ---------------------------------------------------------------------------


def test_union_matches_single_device_reduction():
    sched = MultiDeviceSchedule(n_devices=4, samples_per_device=500,
                                n_c=50, n_o=10.0, T=6000.0, tau_p=1.0)
    eq = sched.equivalent_single_device()
    # at every whole TDMA round the union equals the reduced single stream
    round_time = sched.n_devices * (sched.n_c + sched.n_o)
    for k in range(1, 8):
        t = k * round_time
        assert sched.available_at(t) == eq.available_at(t), k


def test_multi_device_planner():
    out = plan_multi_device(n_devices=4, samples_per_device=N // 4, T=T,
                            n_o=100.0, tau_p=1.0, consts=CONSTS)
    assert out["n_c_per_device"] >= 1
    assert out["n_c_union"] >= out["n_c_per_device"]
    assert np.isfinite(out["bound"])


# ---------------------------------------------------------------------------
# Theorem-1 Monte-Carlo evaluator
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_theorem1_tighter_than_corollary1():
    X, y, _ = make_regression_dataset(n=2048, d=8, seed=3)
    consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=4.0,
                            alpha=1e-3)
    out = estimate_theorem1(X, y, n_c=256, n_o=50.0, T=1.5 * 2048,
                            consts=consts, alpha=1e-3, n_runs=2)
    # Corollary 1 replaces each per-block initial error with L D^2/2 —
    # the Monte-Carlo Theorem-1 value must be no larger
    assert out["theorem1"] <= out["corollary1"] + 1e-9
    # and both must upper-bound the realised gap
    assert out["empirical_gap"] <= out["corollary1"] + 1e-9
