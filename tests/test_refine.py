"""Coarse->fine refinement solver: window construction (refine_grid /
coarse_indices / refine_window_bounds), fixed-case argmin parity of the
refined vs dense solves for all three shipped objectives, the hypothesis
refinement-parity property (subset invariants + tail-guard exactness +
rate-major tie-breaking) over mixed link-model batches, dense fallbacks,
and the grid-mode plumbing (cache scoping, serving stats, CLI exits)."""
import numpy as np
import pytest

from repro.core import BoundConstants
from repro.core.objectives import (BoundObjective, MarkovARQObjective,
                                   MonteCarloObjective, RefineHints,
                                   refine_hints_for)
from repro.core.planner import (coarse_indices, fleet_grid, refine_grid,
                                refine_window_bounds)
from repro.core.scenario import (ErasureLink, FadingLink, GilbertElliottLink,
                                 IdealLink, MultiDevice, Scenario,
                                 SingleDevice)
from repro.fleet import GRID_MODES, FleetPlanner, PlanCache, ScenarioBatch
from repro.launch.plan_server import (default_consts, resolve_grid_modes,
                                      serve, synth_requests)

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
RATES5 = (1.0, 1.25, 1.5, 2.0, 3.0)


def _fleet_scenarios(n, seed):
    """Fleet-scale tight-deadline population (the paper's regime) mixing
    every registered channel family — the fixed refinement-parity cases."""
    rng = np.random.default_rng(seed)
    links = [
        lambda: IdealLink(rates=RATES5),
        lambda: ErasureLink(beta=float(rng.uniform(0.05, 1.5)),
                            p_base=float(rng.uniform(0.0, 0.4)),
                            rates=RATES5),
        lambda: FadingLink(snr=float(rng.uniform(2.0, 50.0)), rates=RATES5),
        lambda: GilbertElliottLink(p_gb=float(rng.uniform(0.01, 0.3)),
                                   p_bg=float(rng.uniform(0.2, 0.9)),
                                   p_good=float(rng.uniform(0.0, 0.2)),
                                   p_bad=float(rng.uniform(0.2, 0.9)),
                                   beta=float(rng.uniform(0.05, 1.0)),
                                   rates=RATES5),
    ]
    out = []
    for _ in range(n):
        N = int(rng.integers(1 << 17, 1 << 20))
        D = int(rng.choice([1, 2, 4, 8]))
        out.append(Scenario(
            N=N, T=float(rng.uniform(1.05, 1.4)) * N,
            n_o=float(rng.uniform(10.0, 5000.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
            link=links[int(rng.integers(4))](),
            topology=MultiDevice(D) if D > 1 else SingleDevice()))
    return out


# ---------------------------------------------------------------------------
# window construction
# ---------------------------------------------------------------------------


def test_coarse_indices_anchor_last():
    np.testing.assert_array_equal(coarse_indices(10, 3), [0, 3, 6, 9])
    np.testing.assert_array_equal(coarse_indices(11, 3), [0, 3, 6, 9, 10])
    np.testing.assert_array_equal(coarse_indices(4, 8), [0, 3])
    with pytest.raises(ValueError):
        coarse_indices(8, 0)


def test_refine_grid_bracket_windows():
    grid = np.arange(100, 120, dtype=np.int64)[None, :]    # G = 20
    centers = np.array([[5, 0, 19]])                       # interior + edges
    win_idx, win_grid, count = refine_grid(grid, centers, 3)
    assert win_idx.shape == (1, 3, 7)
    np.testing.assert_array_equal(win_idx[0, 0], [2, 3, 4, 5, 6, 7, 8])
    # edge clamping: the bracket clips, padding repeats the LAST real index
    np.testing.assert_array_equal(win_idx[0, 1], [0, 1, 2, 3, 3, 3, 3])
    np.testing.assert_array_equal(win_idx[0, 2], [16, 17, 18, 19, 19, 19, 19])
    np.testing.assert_array_equal(count[0], [7, 4, 4])
    np.testing.assert_array_equal(win_grid, grid[0][win_idx])


def test_refine_grid_tail_merge_and_padding():
    grid = np.arange(20, dtype=np.int64)[None, :] + 1
    centers = np.array([[4, 16]])
    # disjoint bracket + tail for the first rate; overlapping for the
    # second (bracket [14,18] touches tail [15, 20) -> single interval)
    win_idx, win_grid, count = refine_grid(grid, centers, 2, tail_start=[15])
    np.testing.assert_array_equal(count[0], [10, 6])
    np.testing.assert_array_equal(win_idx[0, 0],
                                  [2, 3, 4, 5, 6, 15, 16, 17, 18, 19])
    np.testing.assert_array_equal(win_idx[0, 1],
                                  [14, 15, 16, 17, 18, 19, 19, 19, 19, 19])
    # windows enumerate ascending dense indices (tie-breaking invariant)
    assert (np.diff(win_idx, axis=2) >= 0).all()
    # pad_multiple rounds the padded width up
    w8 = refine_grid(grid, centers, 2, tail_start=[15], pad_multiple=8)[0]
    assert w8.shape[2] == 16
    with pytest.raises(ValueError):
        refine_grid(grid, centers, 2, tail_start=[15], width=4)


def test_refine_window_bounds_matches_refine_grid():
    rng = np.random.default_rng(5)
    G = 64
    grid = np.cumsum(rng.integers(1, 5, (3, G)), axis=1)
    centers = rng.integers(0, G, (3, 4))
    tail = rng.integers(0, G + 1, 3)
    lo, hi2, t2, len1, count = refine_window_bounds(centers, 5, G, tail)
    win_idx, _, count2 = refine_grid(grid, centers, 5, tail_start=tail)
    np.testing.assert_array_equal(count, count2)
    for s in range(3):
        for r in range(4):
            want = sorted(set(range(lo[s, r], hi2[s, r] + 1))
                          | set(range(t2[s, r], G)))
            got = list(dict.fromkeys(win_idx[s, r].tolist()))
            assert got == want, (s, r)


# ---------------------------------------------------------------------------
# refined == dense: fixed cases, all three shipped objectives
# ---------------------------------------------------------------------------


def _assert_plans_identical(dense, refined):
    np.testing.assert_array_equal(dense.n_c, refined.n_c)
    np.testing.assert_array_equal(dense.rate, refined.rate)
    # same argmin point, same kernel ops -> bitwise-equal objective values
    np.testing.assert_array_equal(dense.bound_value, refined.bound_value)
    np.testing.assert_array_equal(dense.p_err, refined.p_err)
    np.testing.assert_array_equal(dense.full_transfer, refined.full_transfer)
    np.testing.assert_array_equal(dense.n_c_per_device,
                                  refined.n_c_per_device)


@pytest.mark.parametrize("objective", [BoundObjective(), MarkovARQObjective()],
                         ids=["corollary1", "markov_arq"])
def test_refined_matches_dense_bound_objectives_fixed(objective):
    """ISSUE acceptance: refined and dense solves produce argmin-identical
    plans on the fleet-scale tight-deadline population (the guarded
    sawtooth tail plus the coarse bracket covers every optimum here)."""
    batch = ScenarioBatch.from_scenarios(_fleet_scenarios(96, seed=23))
    G = 384
    grids = fleet_grid(batch.N, G)
    dense = FleetPlanner(grid_size=G).plan_batch(
        batch, CONSTS, grid=grids, objective=objective)
    refined = FleetPlanner(grid_size=G, grid_mode="refine").plan_batch(
        batch, CONSTS, grid=grids, objective=objective)
    _assert_plans_identical(dense, refined)
    # the refined pass really did evaluate fewer points
    assert refined.grid.shape[1] < G
    assert refined.bound_grid.shape == refined.grid.shape


@pytest.mark.slow
def test_refined_matches_dense_montecarlo_fixed():
    """Monte-Carlo refined == dense on bracket-resolved fixed cases (the
    empirical landscape is seed-noise ragged, so unlike the guarded bound
    objectives exactness holds on resolved basins, not universally —
    these cases are verified resolved)."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(96, 5))
    y = X @ rng.normal(size=5) + 0.1 * rng.normal(size=96)
    mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0)
    rates = (1.0, 1.5, 3.0)
    scs = [
        Scenario(N=1657, T=2100.0, n_o=99.0, tau_p=1.0,
                 link=ErasureLink(beta=0.3, p_base=0.1, rates=rates)),
        Scenario(N=699, T=899.0, n_o=238.4, tau_p=1.0,
                 link=ErasureLink(beta=0.6, p_base=0.05, rates=rates)),
        Scenario(N=545, T=635.0, n_o=111.2, tau_p=1.0,
                 link=IdealLink(rates=rates)),
        Scenario(N=1479, T=2350.0, n_o=213.6, tau_p=1.0,
                 link=ErasureLink(beta=0.2, p_base=0.15, rates=rates)),
    ]
    batch = ScenarioBatch.from_scenarios(scs)
    G = 32
    grids = fleet_grid(batch.N, G)
    dense = FleetPlanner(grid_size=G).plan_batch(batch, CONSTS, grid=grids,
                                                objective=mc)
    refined = FleetPlanner(grid_size=G, grid_mode="refine").plan_batch(
        batch, CONSTS, grid=grids, objective=mc)
    _assert_plans_identical(dense, refined)
    assert refined.grid.shape[1] < G


def test_refine_falls_back_to_dense_on_narrow_grids():
    """Below the objective's min_grid hint (brackets would clip at the
    grid edges) refine mode IS the dense solve, bitwise."""
    batch = ScenarioBatch.from_scenarios(_fleet_scenarios(8, seed=3))
    for G in (8, 24):
        dense = FleetPlanner(grid_size=G).plan_batch(batch, CONSTS)
        refined = FleetPlanner(grid_size=G, grid_mode="refine").plan_batch(
            batch, CONSTS)
        _assert_plans_identical(dense, refined)
        np.testing.assert_array_equal(dense.grid, refined.grid)
        np.testing.assert_array_equal(dense.bound_grid, refined.bound_grid)


def test_refine_hints_registry():
    assert refine_hints_for(BoundObjective()).tail_blocks == 32
    assert refine_hints_for(MarkovARQObjective()).stride == 16
    mc_hints = refine_hints_for(
        MonteCarloObjective(X=np.eye(4), y=np.ones(4)))
    assert mc_hints.tail_blocks is None and mc_hints.min_grid == 24
    # objects without declared hints get the registry default
    assert refine_hints_for(object()) == RefineHints()


# ---------------------------------------------------------------------------
# hypothesis property: refinement parity over mixed link-model batches
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _rate_sets = st.sets(st.sampled_from(RATES5), min_size=1).map(
        lambda s: tuple(sorted(s)))

    @st.composite
    def _link(draw):
        rates = draw(_rate_sets)
        kind = draw(st.sampled_from(["ideal", "erasure", "fading", "ge"]))
        if kind == "erasure":
            return ErasureLink(beta=draw(st.floats(0.0, 2.0)),
                               p_base=draw(st.floats(0.0, 0.9)),
                               rates=rates)
        if kind == "fading":
            return FadingLink(snr=draw(st.floats(0.5, 100.0)), rates=rates)
        if kind == "ge":
            return GilbertElliottLink(
                p_gb=draw(st.floats(0.01, 1.0)),
                p_bg=draw(st.floats(0.01, 1.0)),
                p_good=draw(st.floats(0.0, 0.9)),
                p_bad=draw(st.floats(0.0, 0.9)),
                beta=draw(st.floats(0.0, 2.0)), rates=rates)
        return IdealLink(rates=rates)

    @st.composite
    def _scenario(draw):
        N = draw(st.integers(256, 60000))
        T = draw(st.floats(0.4, 3.0)) * N
        n_o = draw(st.floats(0.0, 2000.0))
        tau_p = draw(st.sampled_from([0.5, 1.0, 2.0]))
        D = draw(st.integers(1, 8))
        return Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p, link=draw(_link()),
                        topology=MultiDevice(D) if D > 1 else SingleDevice())

    @settings(max_examples=15, deadline=None)
    @given(scs=st.lists(_scenario(), min_size=1, max_size=5),
           objective=st.sampled_from([BoundObjective(),
                                      MarkovARQObjective()]))
    def test_refinement_parity_property(scs, objective):
        """ISSUE acceptance: the coarse->fine argmin vs the dense-grid
        argmin, rate-major tie-breaking included, on arbitrary mixed
        link-model batches:

          * the refined optimum is the dense argmin over the EVALUATED
            subset, so its value can never beat the dense optimum, and
            whenever the plans coincide the values are bitwise equal;
          * any scenario whose dense argmin falls inside the guarded
            sawtooth tail (which is always evaluated densely) must
            produce the IDENTICAL plan — the tie-breaking acceptance;
          * outside the evaluated subset the refined plan stays within
            the documented residual-quality envelope of the dense one.
        """
        G = 96
        batch = ScenarioBatch.from_scenarios(scs)
        grids = fleet_grid(batch.N, G)
        dense = FleetPlanner(grid_size=G).plan_batch(
            batch, CONSTS, grid=grids, objective=objective)
        refined = FleetPlanner(grid_size=G, grid_mode="refine").plan_batch(
            batch, CONSTS, grid=grids, objective=objective)
        tail_blocks = refine_hints_for(objective).tail_blocks
        tail_start = np.sum(grids * tail_blocks < batch.N[:, None], axis=1)
        for i in range(len(batch)):
            d_nc, d_rate = int(dense.n_c[i]), float(dense.rate[i])
            r_nc, r_rate = int(refined.n_c[i]), float(refined.rate[i])
            dv, rv = float(dense.bound_value[i]), float(refined.bound_value[i])
            assert rv >= dv or (r_nc, r_rate) == (d_nc, d_rate), \
                "refined subset argmin beat the dense argmin"
            if (r_nc, r_rate) == (d_nc, d_rate):
                assert rv == dv  # same point -> bitwise-equal evaluation
            else:
                assert rv <= dv * 1.06 + 1e-12, (i, dv, rv)
            # dense argmin inside the always-evaluated guarded tail ->
            # the refined reduction must reproduce it exactly
            gi = int(np.argmin(dense.bound_grid[i]))
            if gi >= int(tail_start[i]):
                assert (r_nc, r_rate) == (d_nc, d_rate), \
                    (i, "tail-guarded dense argmin not reproduced")
                assert rv == dv

    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(data=st.data())
    def test_refinement_parity_property_montecarlo(data):
        """The refinement-parity property for the SIMULATED objective:
        the Monte-Carlo kernel has no tail guard, so the subset
        invariants (refined can never beat dense; coinciding plans are
        bitwise equal; residual gaps stay inside the documented
        envelope) are the exactness contract."""
        rng = np.random.default_rng(1)
        X = rng.normal(size=(64, 4))
        y = X @ rng.normal(size=4) + 0.1 * rng.normal(size=64)
        mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0)
        rates = (1.0, 2.0)
        scs = []
        for _ in range(2):   # fixed S so one kernel shape compiles
            N = data.draw(st.integers(256, 1024))
            scs.append(Scenario(
                N=N, T=data.draw(st.floats(1.05, 1.5)) * N,
                n_o=data.draw(st.floats(1.0, 300.0)), tau_p=1.0,
                link=ErasureLink(beta=data.draw(st.floats(0.0, 1.0)),
                                 p_base=data.draw(st.floats(0.0, 0.3)),
                                 rates=rates)))
        G = 32
        batch = ScenarioBatch.from_scenarios(scs)
        grids = fleet_grid(batch.N, G)
        dense = FleetPlanner(grid_size=G).plan_batch(
            batch, CONSTS, grid=grids, objective=mc)
        refined = FleetPlanner(grid_size=G, grid_mode="refine").plan_batch(
            batch, CONSTS, grid=grids, objective=mc)
        for i in range(len(batch)):
            same = (int(dense.n_c[i]), float(dense.rate[i])) == \
                (int(refined.n_c[i]), float(refined.rate[i]))
            dv = float(dense.bound_value[i])
            rv = float(refined.bound_value[i])
            if same:
                assert rv == dv
            else:
                assert rv >= dv
                assert rv <= dv * 1.06 + 1e-12
else:  # surface the missing property coverage as skips, not silence
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_refinement_parity_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_refinement_parity_property_montecarlo():
        pass


# ---------------------------------------------------------------------------
# grid-mode plumbing: caching, serving, CLI
# ---------------------------------------------------------------------------


def test_grid_mode_validation():
    assert resolve_grid_modes("all") == GRID_MODES
    assert resolve_grid_modes("refine,dense") == ("refine", "dense")
    with pytest.raises(ValueError):
        resolve_grid_modes("refined")   # typo must not silently fall back
    with pytest.raises(ValueError):
        resolve_grid_modes("")
    with pytest.raises(ValueError):
        FleetPlanner(grid_mode="coarse")
    with pytest.raises(ValueError):
        FleetPlanner().plan_batch(
            ScenarioBatch.from_scenarios(_fleet_scenarios(1, seed=1)),
            CONSTS, grid_mode="nope")


def test_cache_scoped_by_grid_mode():
    """Dense and refined entries never alias in a shared cache, even when
    the plans coincide (the satellite: grid mode folds into the cache
    context)."""
    planner_d = FleetPlanner(grid_size=48)
    planner_r = FleetPlanner(grid_size=48, grid_mode="refine")
    cache = PlanCache(maxsize=16)
    scs = _fleet_scenarios(2, seed=11)
    rec_d = planner_d.plan_many(scs, CONSTS, cache=cache)
    rec_r = planner_r.plan_many(scs, CONSTS, cache=cache)
    assert len(cache) == 4                       # two entries per mode
    assert planner_d.plan_many(scs, CONSTS, cache=cache) == rec_d
    assert planner_r.plan_many(scs, CONSTS, cache=cache) == rec_r
    # per-call override uses the override's scope, not the planner's
    assert planner_d.plan_many(scs, CONSTS, cache=cache,
                               grid_mode="refine") == rec_r
    assert len(cache) == 4


def test_serve_mixed_grid_mode_stream():
    requests = synth_requests(32, seed=13, dup_frac=0.0)
    modes = ["refine" if i % 2 else "dense" for i in range(32)]
    stats = serve(requests, planner=FleetPlanner(grid_size=16),
                  consts=default_consts(), cache=PlanCache(maxsize=64),
                  batch_size=16, grid_modes=modes)
    assert stats.requests_per_grid_mode == {"dense": 16, "refine": 16}
    assert all(rec is not None for rec in stats.records)
    # mode list must be per-request
    with pytest.raises(ValueError):
        serve(requests, planner=FleetPlanner(grid_size=16),
              consts=default_consts(), grid_modes=["dense"])
    # unknown mode names are rejected, not silently remapped
    with pytest.raises(ValueError):
        serve(requests, planner=FleetPlanner(grid_size=16),
              consts=default_consts(), grid_modes=["dense"] * 31 + ["x"])


def test_plan_server_cli_unknown_grid_mode_exits_2():
    from repro.launch.plan_server import main
    assert main(["--requests", "4", "--grid-mode", "bogus"]) == 2


def test_plan_server_cli_mixed_modes_smoke(capsys):
    from repro.launch.plan_server import main
    assert main(["--requests", "24", "--batch", "8", "--grid", "8",
                 "--grid-mode", "all", "--n-max", "2048"]) == 0
    out = capsys.readouterr().out
    assert "grid-mode mix:" in out and "dense=" in out and "refine=" in out
