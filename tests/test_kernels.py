"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret=True executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, ssd_scan
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref


def _mk_qkv(key, b, s, h, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,hkv,d", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 192, 6, 6, 64),     # non-power-of-two seq (but block multiple)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, s, h, hkv, d, dtype):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), b, s, h, hkv, d, dtype)
    out = flash_attention(q, k, v, q_block=64, kv_block=64, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("window,softcap", [(None, None), (96, None),
                                            (None, 30.0), (64, 50.0)])
def test_flash_attention_variants(window, softcap):
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 2, 256, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          q_block=64, kv_block=64, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, softcap=softcap
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_flash_attention_block_shape_sweep():
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 1, 256, 4, 4, 32, jnp.float32)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    for qb, kb in [(32, 64), (64, 32), (128, 128), (256, 64)]:
        out = flash_attention(q, k, v, q_block=qb, kv_block=kb, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5,
                                   err_msg=f"q_block={qb} kv_block={kb}")


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 32, 32),
    (1, 256, 8, 64, 1, 64, 64),   # production-like ratios
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_shapes(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n), dtype)
    cm = jax.random.normal(ks[4], (b, l, g, n), dtype)
    y, st = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, str_ = ssd_scan_ref(
        x.astype(jnp.float32), dt, a,
        jnp.repeat(bm.astype(jnp.float32), h // g, 2),
        jnp.repeat(cm.astype(jnp.float32), h // g, 2))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), yr, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st), str_, rtol=tol, atol=tol)


def test_ssd_kernel_matches_model_oracle():
    """The kernel must agree with the model's own chunked SSD (ssd_chunked)."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, l, h, p, g, n = 2, 128, 4, 16, 2, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    y1, s1 = ssd_scan(x, dt, a, bm, cm, chunk=32, interpret=True)
    y2, s2 = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
