"""Sharding rules: divisibility-aware fallback, batch/cache specs, and a
small-mesh end-to-end lowering (subprocess, 8 host devices)."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.models import abstract_cache, abstract_params
from repro.sharding import (_fits, batch_specs, cache_specs, param_specs,
                            zero_sharded_specs)


class FakeMesh:
    """Mesh stand-in with .shape and .axis_names only (rule fitting)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_always_fit(arch):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, MESH)

    def check(leaf, spec):
        assert _fits(spec, leaf.shape, MESH), (arch, leaf.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # at least half the parameter BYTES must be model-sharded (real TP)
    total = sharded = 0
    for leaf, spec in zip(jax.tree.leaves(params),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        b = int(np.prod(leaf.shape))
        total += b
        if any(ax is not None for ax in tuple(spec)):
            sharded += b
    assert sharded / total > 0.5, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_zero_specs_fit_and_widen(arch):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    zspecs = zero_sharded_specs(cfg, params, MESH)

    def check(leaf, spec):
        assert _fits(spec, leaf.shape, MESH), (arch, leaf.shape, spec)

    jax.tree.map(check, params, zspecs, is_leaf=lambda x: isinstance(x, P))


def test_yi_padded_heads_shard_cleanly():
    """yi's 56 q-heads are padded to 64 (§Perf H3) so wq/wo shard on the
    head axis; the kv projections (8 heads, non-divisible) replicate."""
    cfg = get_config("yi-34b")
    assert cfg.padded_heads == 64
    params = abstract_params(cfg)
    assert params["layers"]["attn"]["wq"].shape[2] == 64
    specs = param_specs(cfg, params, MESH)
    assert tuple(specs["layers"]["attn"]["wq"])[2] == "model"
    assert all(ax is None for ax in tuple(specs["layers"]["attn"]["wk"]))


def test_head_padding_preserves_function():
    """Padded-head model == unpadded model exactly (dead slots masked)."""
    import dataclasses
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import reduced
    from repro.models.attention import q_head_layout
    from repro.models.transformer import init_model, loss_fn

    cfg0 = reduced(get_config("yi-34b"))
    group = cfg0.num_heads // cfg0.num_kv_heads
    cfg1 = dataclasses.replace(
        cfg0, padded_heads=cfg0.num_kv_heads * (group + 2))
    p0 = init_model(jax.random.PRNGKey(0), cfg0)
    p1 = init_model(jax.random.PRNGKey(0), cfg1)
    _, mask = q_head_layout(cfg1)
    idx = np.where(np.asarray(mask))[0]
    for name, ax in (("wq", 2), ("wo", 1)):
        a1 = np.array(p1["layers"]["attn"][name])
        sl = [slice(None)] * a1.ndim
        sl[ax] = idx
        a1[tuple(sl)] = np.array(p0["layers"]["attn"][name])
        p1["layers"]["attn"][name] = jnp.asarray(a1)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg0.vocab_size)
    l0 = loss_fn(p0, {"tokens": tok}, cfg0)
    l1 = loss_fn(p1, {"tokens": tok}, cfg1)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-5)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_batch_and_cache_specs_fit(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not cfg.supports_shape(shape)[0]:
        pytest.skip("arch skips this shape")
    for mesh in (MESH, MESH_MP):
        bs = batch_specs(cfg, shape, mesh)
        from repro.models import input_specs as ispec
        abs_in = ispec(cfg, shape)
        for k, spec in bs.items():
            assert _fits(spec, abs_in[k].shape, mesh), (arch, shape_name, k)
        if shape.kind == "decode":
            cache = abstract_cache(cfg, shape)
            cs = cache_specs(cfg, shape, mesh, cache)

            def check(leaf, spec):
                assert _fits(spec, leaf.shape, mesh), (arch, shape_name,
                                                       leaf.shape, spec)

            jax.tree.map(check, cache, cs, is_leaf=lambda x: isinstance(x, P))


def test_small_mesh_end_to_end_lowering():
    """Real 2x2-device lowering+compile of a reduced arch (subprocess so the
    device-count flag can't leak into other tests)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.models import abstract_params, input_specs, make_train_step, abstract_opt_state
from repro.optim.optimizers import make_optimizer
from repro import sharding as shd
from repro.configs.base import InputShape

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("llama3.2-1b"))
shape = InputShape("small", 64, 4, "train")
params = abstract_params(cfg)
opt = make_optimizer("adamw", 1e-3)
opt_abs = abstract_opt_state(opt, params)
pspecs = shd.param_specs(cfg, params, mesh)
ospecs = shd.opt_state_specs(cfg, opt_abs, params, mesh)
bspecs = shd.batch_specs(cfg, shape, mesh)
sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
step = make_train_step(cfg, opt)
batch = input_specs(cfg, shape)
with mesh:
    lowered = jax.jit(step, in_shardings=(sh(pspecs), sh(ospecs),
                                          NamedSharding(mesh, P()), sh(bspecs))
                      ).lower(params, opt_abs,
                              jax.ShapeDtypeStruct((), jnp.int32), batch)
    compiled = lowered.compile()
print("MEM", compiled.memory_analysis().temp_size_in_bytes)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                        "PYTHONPATH": "src"},
                       cwd=__import__("os").path.dirname(
                           __import__("os").path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MEM" in r.stdout
