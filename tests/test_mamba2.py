"""Mamba2 SSD: chunked == sequential; prefill->decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.mamba2 import (init_mamba, init_mamba_cache, mamba_block,
                                 mamba_decode, ssd_chunked, ssd_reference)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("mamba2-780m"))


def test_chunked_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, l, h, p, g, n = 2, 96, 4, 8, 2, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    for chunk in (8, 16, 32, 96):
        y1, f1 = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
        y2, f2 = ssd_reference(x, dt, a, bm, cm)
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4,
                                   err_msg=f"chunk={chunk}")
        np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-4)


def test_prefill_decode_parity(cfg):
    """Running the block over a sequence == running decode token-by-token."""
    params = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, l = 2, 24
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (b, l, cfg.d_model))
    y_full, _ = mamba_block(params, x, cfg)

    cache = init_mamba_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(l):
        yt, cache = mamba_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


def test_prefill_cache_continues_decode(cfg):
    """Prefix via mamba_block, then continue with mamba_decode — must equal
    the all-at-once forward on the concatenated sequence."""
    params = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, l1, l2 = 1, 16, 4
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (b, l1 + l2, cfg.d_model))
    y_all, _ = mamba_block(params, x, cfg)

    _, cache = mamba_block(params, x[:, :l1], cfg)
    cache = {k: cache[k] for k in ("conv_x", "conv_B", "conv_C", "ssm")}
    ys = []
    for t in range(l1, l1 + l2):
        yt, cache = mamba_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(yt)
    y_cont = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cont), np.asarray(y_all[:, l1:]),
                               rtol=2e-4, atol=2e-4)
