"""MoE dispatch: grouped sort-dispatch vs dense oracle, capacity behaviour,
EP/TP sharding-regime selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.moe import init_moe, moe_dense_reference, moe_ffn
from repro.sharding import _moe_expert_parallel


@pytest.fixture(scope="module")
def setup():
    cfg = ArchConfig(
        name="t", family="moe", source="", d_model=32,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16,
                      num_shared_experts=2))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32))
    return cfg, params, x


def test_matches_dense_reference(setup):
    cfg, params, x = setup
    y1, a1 = moe_ffn(params, x, cfg, capacity_factor=8.0)  # no drops
    y2, a2 = moe_dense_reference(params, x, cfg)
    np.testing.assert_allclose(y1, y2, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(a1, a2, rtol=1e-6)


def test_gates_renormalised(setup):
    """Top-k gates are renormalised — output magnitude stays bounded even
    with small capacity (dropped tokens fall back to shared experts only)."""
    cfg, params, x = setup
    y_small, _ = moe_ffn(params, x, cfg, capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(y_small)))


def test_capacity_drop_monotone(setup):
    """Distance to the no-drop reference shrinks as capacity grows."""
    cfg, params, x = setup
    ref, _ = moe_dense_reference(params, x, cfg)
    errs = []
    for f in (0.25, 0.5, 1.0, 8.0):
        y, _ = moe_ffn(params, x, cfg, capacity_factor=f)
        errs.append(float(jnp.mean(jnp.abs(y - ref))))
    assert errs[-1] < 1e-5
    assert errs[0] >= errs[-1]


def test_aux_loss_penalises_imbalance():
    cfg = ArchConfig(name="t2", family="moe", source="", d_model=16,
                     moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=8))
    params = init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    # force-collapse the router onto one expert
    collapsed = dict(params)
    router = np.zeros((16, 4), np.float32)
    router[:, 0] = 10.0
    collapsed["router"] = jnp.asarray(router)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 16))
    _, aux_bal = moe_ffn(params, x, cfg)
    _, aux_col = moe_ffn(collapsed, x, cfg)
    assert float(aux_col) > float(aux_bal)


def test_ep_vs_tp_selection():
    assert _moe_expert_parallel(get_config("deepseek-moe-16b"))       # 64e -> EP
    assert not _moe_expert_parallel(get_config("mixtral-8x7b"))       # 8e  -> TP
