"""Corollary-1 bound (eqs. 14-15) and the planner's paper-claim trends."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants, calibrate_from_gram, corollary1_bound
from repro.core.planner import optimize_block_size
from repro.data.synthetic import make_regression_dataset

CONSTS = BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0, alpha=EP.alpha)
N = EP.n_samples
T = EP.T_factor * N


def test_stepsize_condition_checked():
    bad = BoundConstants(L=2.0, c=0.1, M=1.0, M_G=1.0, D=1.0, alpha=1.5)
    with pytest.raises(AssertionError):
        bad.validate()


def test_bound_above_variance_floor():
    grid = np.unique(np.logspace(0, np.log10(N), 50).astype(int))
    vals = corollary1_bound(grid, N=N, T=T, n_o=100.0, tau_p=1.0, consts=CONSTS)
    assert (vals >= CONSTS.variance_floor - 1e-12).all()
    assert np.isfinite(vals).all()


def test_optimal_block_smaller_than_dataset():
    """Paper: 'the optimized value of n_c is generally smaller than N,
    suggesting the advantages of pipelining'."""
    for n_o in (10.0, 100.0, 1000.0):
        plan = optimize_block_size(N=N, T=T, n_o=n_o, tau_p=1.0, consts=CONSTS)
        assert plan.n_c < N / 2


def test_overhead_increases_optimal_block():
    """Paper Fig. 3: larger n_o => larger n_c-tilde (overhead amortisation)."""
    ncs = [optimize_block_size(N=N, T=T, n_o=n_o, tau_p=1.0, consts=CONSTS).n_c
           for n_o in (10.0, 100.0, 1000.0, 5000.0)]
    assert all(a <= b for a, b in zip(ncs, ncs[1:]))
    assert ncs[-1] > ncs[0]


def test_large_overhead_foregoes_full_transfer():
    """Paper: for large n_o it is better to forego transmitting some data."""
    small = optimize_block_size(N=N, T=T, n_o=10.0, tau_p=1.0, consts=CONSTS)
    large = optimize_block_size(N=N, T=T, n_o=5000.0, tau_p=1.0, consts=CONSTS)
    assert small.full_transfer
    assert not large.full_transfer


def test_calibration_matches_paper_constants():
    X, _, _ = make_regression_dataset()
    L, c = calibrate_from_gram(X)
    assert abs(L - 1.908) < 1e-3    # paper's reported largest eigenvalue
    assert abs(c - 0.061) < 1e-3    # paper's reported smallest eigenvalue


@settings(max_examples=60, deadline=None)
@given(
    n_o=st.floats(0.0, 2000.0),
    d_diam=st.floats(0.25, 8.0),
    alpha=st.floats(1e-5, 1e-3),
)
def test_bound_finite_positive_everywhere(n_o, d_diam, alpha):
    consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=d_diam, alpha=alpha)
    consts.validate()
    grid = np.unique(np.logspace(0, np.log10(N), 40).astype(int))
    vals = corollary1_bound(grid, N=N, T=T, n_o=n_o, tau_p=1.0, consts=consts)
    assert np.isfinite(vals).all()
    assert (vals > 0).all()
