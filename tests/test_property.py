"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bounds import BoundConstants, corollary1_bound
from repro.core.protocol import BlockSchedule
from repro.core.streaming import make_buffer, receive_block, sample
from repro.launch.hlo_cost import shape_info
from repro.models.blockwise import flash_attention
from repro.models.attention import causal_mask, dot_product_attention


@settings(max_examples=30, deadline=None)
@given(
    blocks=st.lists(st.integers(1, 8), min_size=1, max_size=6),
)
def test_streaming_buffer_available_equals_sum(blocks):
    n = sum(blocks)
    buf = make_buffer(n, (2,))
    off = 0.0
    for sz in blocks:
        xb = jnp.full((sz, 2), off)
        buf = receive_block(buf, xb, jnp.full((sz,), off))
        off += 1.0
    assert int(buf.available) == n
    xs, _ = sample(buf, jax.random.PRNGKey(0), 32)
    assert bool(jnp.all(xs[:, 0] < off))


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([64, 96, 128]),
    h=st.sampled_from([2, 4]),
    seed=st.integers(0, 2 ** 16),
)
def test_flash_attention_equals_plain(s, h, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, 16))
    k = jax.random.normal(ks[1], (1, s, h, 16))
    v = jax.random.normal(ks[2], (1, s, h, 16))
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, causal=True, q_block=32, k_block=32)
    ref = dot_product_attention(q, k, v,
                                mask=causal_mask(pos, pos)[None, None, None])
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@settings(max_examples=50, deadline=None)
@given(
    n_c=st.integers(1, 18_576),
    n_o=st.floats(0.0, 4000.0),
)
def test_bound_regime_consistency(n_c, n_o):
    """The two bound formulas agree with the protocol's regime flag."""
    N, T = 18_576, 1.5 * 18_576
    consts = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
    sched = BlockSchedule(N=N, n_c=n_c, n_o=n_o, T=T, tau_p=1.0)
    val = corollary1_bound(np.asarray([n_c]), N=N, T=T, n_o=n_o, tau_p=1.0,
                           consts=consts)[0]
    assert np.isfinite(val) and val > 0
    if sched.full_transfer:
        # regime (b): sigma + r^{n_l} (e0 - sigma) s_b / B_d with
        # s_b / B_d < 2 for every feasible block size
        assert val <= consts.variance_floor + 2.0 * consts.init_gap


@settings(max_examples=80, deadline=None)
@given(st.sampled_from([
    ("f32[16,128]", 16 * 128 * 4),
    ("bf16[2,4,8]{2,1,0}", 2 * 4 * 8 * 2),
    ("(f32[4], s32[2,2])", 16 + 16),
    ("pred[7]", 7),
    ("u8[]", 1),
]), st.integers(0, 3))
def test_shape_info_parser(case, _salt):
    s, expected = case
    got, _ = shape_info(s)
    assert got == expected


@settings(max_examples=40, deadline=None)
@given(
    chunks=st.lists(st.lists(st.floats(1e-7, 1e4), min_size=0, max_size=40),
                    min_size=3, max_size=3),
)
def test_log_histogram_merge_associative_commutative(chunks):
    """Histogram merge is associative AND commutative AND equals the
    single-histogram record of the union — the algebra the per-bucket ->
    service -> fleet roll-up depends on."""
    from repro.obs import LogHistogram

    def hist(samples):
        h = LogHistogram(per_decade=7)
        for s in samples:
            h.record(s)
        return h

    a, b, c = (hist(ch) for ch in chunks)
    left = a.copy().merge(b).merge(c)
    right = a.copy().merge(b.copy().merge(c))
    swapped = c.copy().merge(a).merge(b)
    union = hist([s for ch in chunks for s in ch])
    for other in (right, swapped, union):
        assert left.counts == other.counts
        assert left.count == other.count
        assert left.max == other.max
        np.testing.assert_allclose(left.sum, other.sum, rtol=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16),
    size=st.integers(2, 8),
)
def test_scenario_batch_survives_pad_plan_unpad(seed, size):
    """Satellite invariant: a mixed-link population's device counts and
    packed link params survive pad -> plan -> unpad BITWISE — the
    federated round planner pads to serving buckets, and a pad lane that
    perturbed a real lane's batch row would silently re-plan a different
    device."""
    from repro.core import (ErasureLink, GilbertElliottLink, IdealLink,
                            MultiDevice, Scenario, SingleDevice)
    from repro.federated import RoundPlanner, plan_round_reference
    from repro.fleet.batch import ScenarioBatch
    from repro.fleet.planner import _pad_batch
    from repro.serve import default_consts

    rates = (1.0, 1.25, 1.5, 2.0, 3.0)
    rng = np.random.default_rng(seed)
    pop = []
    for i in range(size):
        link = [
            IdealLink(rates=rates),
            ErasureLink(beta=float(rng.uniform(0.0, 1.0)),
                        p_base=float(rng.uniform(0.0, 0.5)), rates=rates),
            GilbertElliottLink(p_gb=float(rng.uniform(0.05, 0.9)),
                               p_bg=float(rng.uniform(0.05, 0.9)),
                               p_good=float(rng.uniform(0.0, 0.4)),
                               p_bad=float(rng.uniform(0.1, 0.9)),
                               beta=float(rng.uniform(0.0, 1.0)),
                               rates=rates),
        ][i % 3]
        D = int(rng.integers(1, 5))
        n = int(rng.integers(64, 2048))
        pop.append(Scenario(
            N=n, T=float(rng.uniform(0.8, 2.0)) * n,
            n_o=float(rng.uniform(0.0, 500.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])), link=link,
            topology=MultiDevice(D) if D > 1 else SingleDevice()))

    # pad: real lanes are bitwise-identical to the unpadded batch
    batch = ScenarioBatch.from_scenarios(pop)
    padded = ScenarioBatch.from_scenarios(_pad_batch(list(pop), 8))
    assert len(padded) == 8
    for arr, parr in [(batch.n_devices, padded.n_devices),
                      (batch.link_model_id, padded.link_model_id),
                      (batch.link_params, padded.link_params),
                      (batch.rates, padded.rates),
                      (batch.N, padded.N), (batch.n_o, padded.n_o)]:
        assert np.array_equal(arr, parr[:size])
    # ... and round-trip losslessly through __getitem__
    for i, sc in enumerate(pop):
        got = padded[i]
        assert got.n_devices == sc.n_devices
        assert np.array_equal(np.asarray(got.link.pack_params(), np.float64),
                              np.asarray(sc.link.pack_params(), np.float64))
        assert type(got.link) is type(sc.link)

    # plan -> unpad: the planner's per-device outputs cover exactly the
    # real population and agree with the unpadded numpy reference
    consts = default_consts()
    deadline = 1.4 * float(np.median([sc.N for sc in pop]))
    plan = RoundPlanner(grid_size=8).plan_round(pop, consts,
                                                deadline=deadline, pad_to=8)
    assert len(plan) == size
    assert sorted(plan.order.tolist()) == list(range(size))
    ref = plan_round_reference(pop, consts, deadline=deadline, grid_size=8)
    assert np.array_equal(plan.participants, ref.participants)
    assert np.array_equal(plan.n_c, ref.n_c)
    assert np.array_equal(plan.rate, ref.rate)
