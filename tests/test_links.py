"""Pluggable link-model registry: rates validation, the FadingLink /
GilbertElliottLink channel physics (monotonicity, ErasureLink reduction),
pack/from_params round-trips, registration error handling, and the
Simulator's registry-generic ARQ timelines."""
import numpy as np
import pytest

from repro.core import (BoundConstants, BoundPlanner, ErasureLink, FadingLink,
                        GilbertElliottLink, IdealLink, P_ERR_MAX, RidgeTask,
                        Scenario, Simulator, link_spec, link_spec_for,
                        register_link_model, registered_link_models,
                        unregister_link_model)
from repro.core.links import MAX_LINK_PARAMS
from repro.data.synthetic import make_regression_dataset

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
RATES5 = (1.0, 1.25, 1.5, 2.0, 3.0)
ALL_LINK_CLASSES = (IdealLink, ErasureLink, FadingLink, GilbertElliottLink)


# ---------------------------------------------------------------------------
# rates validation (ISSUE satellite: duplicates / non-ascending rejected)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", ALL_LINK_CLASSES)
def test_rates_reject_duplicates_and_non_ascending(cls):
    """Silent duplicate rates waste grid columns and can skew the
    rate-major argmin tie-breaking; out-of-order sets reorder the tie
    winner — both now raise on construction."""
    assert cls(rates=(1.0, 1.5, 2.0)).rates == (1.0, 1.5, 2.0)
    for bad in ((1.0, 1.0), (1.0, 1.5, 1.5), (2.0, 1.0), (1.0, 3.0, 2.0)):
        with pytest.raises(ValueError, match="ascending"):
            cls(rates=bad)
    # the pre-existing checks still fire
    with pytest.raises(ValueError):
        cls(rates=())
    with pytest.raises(ValueError):
        cls(rates=(0.0, 1.0))


# ---------------------------------------------------------------------------
# registry bookkeeping
# ---------------------------------------------------------------------------


def test_builtin_registry_table():
    specs = registered_link_models()
    assert [(s.model_id, s.cls, s.n_params) for s in specs] == [
        (0, IdealLink, 0), (1, ErasureLink, 2), (2, FadingLink, 1),
        (3, GilbertElliottLink, 5)]
    assert link_spec(2).name == "FadingLink"
    assert link_spec_for(ErasureLink(beta=0.4)).model_id == 1
    with pytest.raises(KeyError, match="no link model registered"):
        link_spec(99)
    with pytest.raises(KeyError, match="not a registered link model"):
        link_spec_for(object())


def test_register_link_model_rejects_bad_declarations():
    with pytest.raises(ValueError, match="model_id"):
        register_link_model(type("NoId", (), {}))
    with pytest.raises(ValueError, match="N_PARAMS"):
        register_link_model(type("NoWidth", (), {"model_id": 50}))
    with pytest.raises(ValueError, match="MAX_LINK_PARAMS"):
        register_link_model(type("TooWide", (), {
            "model_id": 50, "N_PARAMS": MAX_LINK_PARAMS + 1}))
    with pytest.raises(TypeError, match="missing LinkModel methods"):
        register_link_model(type("NoMethods", (), {
            "model_id": 50, "N_PARAMS": 1}))
    # a stable id can never be taken over by a different class
    with pytest.raises(ValueError, match="already registered"):
        register_link_model(type("Imposter", (), {
            "model_id": IdealLink.model_id, "N_PARAMS": 0,
            **{m: (lambda self: None) for m in (
                "p_err", "expected_block_time", "pack_params",
                "from_params", "make_loss_process")}}))
    unregister_link_model(12345)  # unknown id: silent no-op


@pytest.mark.parametrize("link", [
    IdealLink(rates=(1.0, 2.0)),
    ErasureLink(beta=0.7, p_base=0.12, rates=RATES5),
    FadingLink(snr=17.5, rates=(0.5, 1.0, 4.0)),
    GilbertElliottLink(p_gb=0.07, p_bg=0.31, p_good=0.02, p_bad=0.55,
                       beta=0.9, rates=(1.0, 1.5)),
])
def test_pack_from_params_round_trip(link):
    spec = link_spec_for(link)
    params = link.pack_params()
    assert params.shape == (spec.n_params,)
    assert spec.cls.from_params(params, rates=link.rates) == link


# ---------------------------------------------------------------------------
# channel physics
# ---------------------------------------------------------------------------


def test_fading_link_outage_formula_and_validation():
    link = FadingLink(snr=10.0, rates=RATES5)
    r = np.asarray(RATES5)
    np.testing.assert_allclose(
        link.p_err(r), np.minimum(1.0 - np.exp(-(2.0 ** r - 1.0) / 10.0),
                                  P_ERR_MAX), rtol=1e-15)
    # a stronger link is never less reliable
    assert float(FadingLink(snr=30.0).p_err(2.0)) < float(link.p_err(2.0))
    for bad in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError):
            FadingLink(snr=bad)


def test_gilbert_elliott_stationary_loss_and_validation():
    link = GilbertElliottLink(p_gb=0.1, p_bg=0.4, p_good=0.02, p_bad=0.6,
                              beta=0.0, rates=RATES5)
    pi_b = 0.1 / 0.5
    assert link.stationary_bad == pytest.approx(pi_b)
    # beta = 0: rate-independent, exactly the stationary mixture
    assert float(link.p_err(2.0)) == pytest.approx(
        0.02 + pi_b * (0.6 - 0.02))
    for kw in (dict(p_gb=-0.1), dict(p_bg=1.5), dict(p_gb=0.0, p_bg=0.0),
               dict(p_good=1.0), dict(p_bad=-0.2), dict(beta=-1.0)):
        with pytest.raises(ValueError):
            GilbertElliottLink(**kw)


def test_scalar_planner_plans_fading_and_gilbert_elliott():
    """Both new channels flow through the scalar BoundPlanner with the
    rate-reliability trade-off intact: the joint search never loses to a
    forced rate-1 plan, and p_err/n_o_eff reflect the link's formulas."""
    for link in (FadingLink(snr=6.0, rates=RATES5),
                 GilbertElliottLink(p_gb=0.2, p_bg=0.5, p_good=0.1,
                                    p_bad=0.7, beta=0.4, rates=RATES5)):
        sc = Scenario(N=4096, T=1.4 * 4096, n_o=150.0, link=link)
        plan = BoundPlanner().plan(sc, CONSTS)
        assert plan.rate in RATES5
        assert plan.p_err == pytest.approx(float(link.p_err(plan.rate)))
        forced = BoundPlanner().plan(
            Scenario(N=4096, T=1.4 * 4096, n_o=150.0,
                     link=type(link).from_params(link.pack_params(),
                                                 rates=(1.0,))), CONSTS)
        assert plan.bound_value <= forced.bound_value + 1e-12


# ---------------------------------------------------------------------------
# hypothesis properties (ISSUE satellite)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _probs = st.floats(0.0, 0.95)
    _trans = st.floats(0.001, 1.0)

    @settings(max_examples=100, deadline=None)
    @given(beta=st.floats(0.0, 5.0), p=_probs, p_gb=_trans, p_bg=_trans,
           rate=st.floats(0.1, 10.0))
    def test_gilbert_elliott_reduces_to_erasure_exactly(beta, p, p_gb, p_bg,
                                                        rate):
        """Degenerate-case contract: equal good/bad loss makes the chain
        indistinguishable from an i.i.d. erasure channel — BITWISE, for
        any transition probabilities, so mixed fleets can rely on the
        reduction at argmin resolution."""
        ge = GilbertElliottLink(p_gb=p_gb, p_bg=p_bg, p_good=p, p_bad=p,
                                beta=beta, rates=(1.0,))
        er = ErasureLink(beta=beta, p_base=p, rates=(1.0,))
        assert float(ge.p_err(rate)) == float(er.p_err(rate))
        assert float(ge.expected_block_time(100, 10.0, rate)) == \
            float(er.expected_block_time(100, 10.0, rate))

    @settings(max_examples=100, deadline=None)
    @given(snr=st.floats(0.01, 1000.0),
           r1=st.floats(0.1, 10.0), r2=st.floats(0.1, 10.0))
    def test_fading_p_err_monotone_in_rate_and_capped(snr, r1, r2):
        """p_err is non-decreasing in the rate (faster is never more
        reliable on a fading link) and capped at P_ERR_MAX."""
        link = FadingLink(snr=snr, rates=(1.0,))
        lo, hi = sorted((r1, r2))
        p_lo, p_hi = float(link.p_err(lo)), float(link.p_err(hi))
        assert 0.0 <= p_lo <= p_hi <= P_ERR_MAX


# ---------------------------------------------------------------------------
# Simulator ARQ timelines through the registry
# ---------------------------------------------------------------------------


def _ridge_task():
    X, y, _ = make_regression_dataset(n=1024, d=6, seed=4)
    return RidgeTask(X=X, y=y, alpha=1e-3)


@pytest.mark.parametrize("link", [
    FadingLink(snr=5.0, rates=(1.0, 1.5, 2.0)),
    GilbertElliottLink(p_gb=0.15, p_bg=0.5, p_good=0.05, p_bad=0.7,
                       beta=0.2, rates=(1.0, 1.5, 2.0)),
])
def test_simulator_attaches_arq_timeline_for_new_links(link):
    sc = Scenario(N=1024, T=1.6 * 1024, n_o=16.0, link=link)
    plan = BoundPlanner().plan(sc, CONSTS)
    report = Simulator().run(sc, plan, _ridge_task())
    assert report.arq_times is not None and report.arq_counts is not None
    assert (np.diff(report.arq_times) > 0).all()
    assert (np.diff(report.arq_counts) >= 0).all()
    assert report.arq_counts[-1] <= 1024


def test_gilbert_elliott_loss_process_is_burstier_than_erasure():
    """At the same stationary loss probability, a sticky bad state makes
    consecutive losses much more likely — the burst structure the planner
    abstracts away but the realised timeline must show."""
    ge = GilbertElliottLink(p_gb=0.01, p_bg=0.09, p_good=0.0, p_bad=0.8,
                            beta=0.0, rates=(1.0,))
    p_stat = float(ge.p_err(1.0))
    er = ErasureLink(beta=0.0, p_base=p_stat, rates=(1.0,))

    def run_rate(link, seed):
        rng = np.random.default_rng(seed)
        step = link.make_loss_process(1.0, rng)
        draws = np.asarray([step() for _ in range(20000)])
        pairs = draws[1:] & draws[:-1]
        return draws.mean(), pairs.mean()

    ge_rate, ge_pairs = run_rate(ge, 0)
    er_rate, er_pairs = run_rate(er, 0)
    assert ge_rate == pytest.approx(er_rate, abs=0.05)   # same long-run loss
    assert ge_pairs > 2.0 * er_pairs                     # but bursty
