"""Always-on planning service: micro-batcher edge cases, bucketed AOT
warmup (zero post-warmup traces), admission-policy registry, PlanCache
invalidation/stats, session drift -> re-plan, and bitwise parity of
served plans against direct ``FleetPlanner.plan_many`` calls."""
import threading
import time

import numpy as np
import pytest

from repro.core import (BoundConstants, ErasureLink, GilbertElliottLink,
                        Scenario)
from repro.fleet import FleetPlanner, PlanCache
from repro.serve import (AdmissionDecision, MicroBatcher, PlanRequest,
                         PlanningService, ServiceConfig, group_requests,
                         policy_spec, register_policy, registered_policies,
                         reestimate_link, synth_requests, unregister_policy)

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
# the catalogue's 5-wide rate set: custom links in service tests must
# match it, or a batch of one would present a NEW padded rate width to
# the jitted kernel and trip the zero-post-warmup-traces assertions
RATES = (1.0, 1.25, 1.5, 2.0, 3.0)

# one small warm population shared by the service tests (keep grids tiny:
# CI runs on one CPU core)
SMALL = dict(grid_size=16, batch_buckets=(4, 8), flush_interval=0.01,
             objective_ids=("corollary1", "markov_arq"), n_max=512,
             min_observations=4)


def _scenario(seed=0, n=1024, link=None):
    rng = np.random.default_rng(seed)
    return Scenario(N=n, T=float(rng.uniform(1.2, 2.0)) * n,
                    n_o=float(rng.uniform(5.0, 500.0)),
                    link=link if link is not None
                    else ErasureLink(beta=0.4, p_base=0.1, rates=RATES))


# ---------------------------------------------------------------------------
# MicroBatcher edge cases (no jax involved: plan_group is a stub)
# ---------------------------------------------------------------------------

def _collecting_batcher(**kw):
    batches = []

    def plan_group(reqs):
        batches.append(list(reqs))
        for r in reqs:
            r.future.set_result(r.scenario)
    return MicroBatcher(plan_group, **kw), batches


def test_batcher_flush_on_size():
    b, batches = _collecting_batcher(max_batch=4, flush_interval=30.0)
    b.start()
    try:
        futs = [b.submit(PlanRequest(scenario=i)) for i in range(4)]
        for f in futs:       # a full batch must flush without the deadline
            assert f.result(timeout=5.0) is not None or True
    finally:
        b.stop()
    assert sum(len(g) for g in batches) == 4


def test_batcher_deadline_flushes_partial_batch():
    b, batches = _collecting_batcher(max_batch=1000, flush_interval=0.02)
    b.start()
    try:
        futs = [b.submit(PlanRequest(scenario=i)) for i in range(3)]
        out = [f.result(timeout=5.0) for f in futs]
        assert out == [0, 1, 2]   # deadline flushed a far-from-full batch
    finally:
        b.stop()
    assert sum(len(g) for g in batches) == 3


def test_batcher_clean_shutdown_drains_queue():
    release = threading.Event()
    done = []

    def slow_plan(reqs):
        release.wait(5.0)
        for r in reqs:
            done.append(r.scenario)
            r.future.set_result(r.scenario)

    b = MicroBatcher(slow_plan, max_batch=2, flush_interval=0.001)
    b.start()
    futs = [b.submit(PlanRequest(scenario=i)) for i in range(7)]
    release.set()
    b.stop(drain=True)            # must plan everything still queued
    assert sorted(done) == list(range(7))
    assert [f.result(timeout=0) for f in futs] == list(range(7))
    with pytest.raises(RuntimeError):
        b.submit(PlanRequest(scenario=99))   # stopped: submissions refused


def test_batcher_stop_without_drain_cancels():
    hold = threading.Event()

    def stall(reqs):
        hold.wait(5.0)
        for r in reqs:
            r.future.set_result(r.scenario)

    b = MicroBatcher(stall, max_batch=1, flush_interval=0.001)
    b.start()
    futs = [b.submit(PlanRequest(scenario=i)) for i in range(5)]
    time.sleep(0.05)              # let the worker take (and stall on) one
    hold.set()
    b.stop(drain=False)
    states = [f.cancelled() for f in futs]
    assert any(states), "queued futures must be cancelled on drain=False"
    for f, cancelled in zip(futs, states):
        if not cancelled:
            f.result(timeout=5.0)  # the in-flight batch still completes


def test_batcher_exception_propagates_to_futures():
    def broken(reqs):
        raise RuntimeError("kernel exploded")

    b = MicroBatcher(broken, max_batch=2, flush_interval=0.001)
    b.start()
    fut = b.submit(PlanRequest(scenario=0))
    with pytest.raises(RuntimeError, match="kernel exploded"):
        fut.result(timeout=5.0)
    b.stop()


def test_group_requests_preserves_interleaved_order():
    obj_a, obj_b = object(), object()
    reqs = [PlanRequest(scenario=i, objective=obj_a if i % 3 else obj_b,
                        grid_mode="dense" if i % 2 else "refine")
            for i in range(12)]
    groups = group_requests(reqs, key=PlanRequest.group_key)
    # every (objective, mode) pair present, first-seen order, and each
    # group preserves arrival order
    assert sum(len(g) for g in groups) == 12
    seen = set()
    for g in groups:
        key = g[0].group_key()
        assert key not in seen
        seen.add(key)
        assert all(r.group_key() == key for r in g)
        assert [r.scenario for r in g] == sorted(r.scenario for r in g)
    assert len(seen) == 4


# ---------------------------------------------------------------------------
# PlanCache invalidation + observable stats (satellite)
# ---------------------------------------------------------------------------

def test_plan_cache_stats_and_invalidate():
    cache = PlanCache(maxsize=2)
    planner = FleetPlanner(grid_size=8)
    scenarios = [_scenario(seed=s, n=512 + 64 * s) for s in range(3)]
    ctx = planner.cache_context(CONSTS)

    planner.plan_many(scenarios[:1], CONSTS, cache=cache)
    planner.plan_many(scenarios[:1], CONSTS, cache=cache)   # hit
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    assert stats["hits_by_objective"] == {"corollary1": 1}
    assert stats["misses_by_objective"] == {"corollary1": 1}

    # invalidate: the exact entry disappears, the next lookup re-solves
    # (entries live under the RESOLVED objective's token, so the caller
    # names the objective — a value-equal instance produces the same key)
    obj = planner._resolve_objective(None)
    assert cache.invalidate(scenarios[0], context=ctx, objective=obj) is True
    assert cache.invalidate(scenarios[0], context=ctx, objective=obj) \
        is False  # idempotent
    stats = cache.stats()
    assert stats["invalidations"] == 1 and stats["size"] == 0
    planner.plan_many(scenarios[:1], CONSTS, cache=cache)
    assert cache.stats()["misses"] == 2

    # LRU eviction is counted
    planner.plan_many(scenarios, CONSTS, cache=cache)
    stats = cache.stats()
    assert stats["size"] == 2
    assert stats["evictions"] >= 1
    assert 0.0 <= stats["hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Admission-policy registry (pluggable, mirrors links/objectives)
# ---------------------------------------------------------------------------

def test_policy_registry_builtins_and_plugin():
    ids = {spec.policy_id for spec in registered_policies()}
    assert {"static", "link_aware"} <= ids
    with pytest.raises(KeyError, match="unregistered admission policy"):
        policy_spec("nope")

    @register_policy
    class EverythingMarkov:
        policy_id = "test_all_markov"

        def admit(self, scenario, *, load):
            return AdmissionDecision("markov_arq", "dense")

    try:
        assert policy_spec("test_all_markov").cls is EverythingMarkov
        decision = EverythingMarkov().admit(_scenario(), load=0.0)
        assert decision == AdmissionDecision("markov_arq", "dense")
    finally:
        unregister_policy("test_all_markov")
    with pytest.raises(KeyError):
        policy_spec("test_all_markov")


def test_register_policy_validates_interface():
    with pytest.raises(TypeError, match="policy_id"):
        register_policy(type("NoId", (), {}))
    with pytest.raises(TypeError, match="admit"):
        register_policy(type("NoAdmit", (), {"policy_id": "x_no_admit"}))


def test_link_aware_policy_routes_sticky_ge_to_markov():
    policy = policy_spec("link_aware").cls()
    sticky = GilbertElliottLink(p_gb=0.05, p_bg=0.2, p_good=0.01,
                                p_bad=0.6, rates=RATES)
    fast = GilbertElliottLink(p_gb=0.5, p_bg=0.5, p_good=0.01,
                              p_bad=0.6, rates=RATES)
    assert policy.admit(_scenario(link=sticky), load=0.0).objective_id \
        == "markov_arq"
    assert policy.admit(_scenario(link=fast), load=0.0).objective_id \
        == "corollary1"
    assert policy.admit(_scenario(), load=0.0).grid_mode == "dense"
    assert policy.admit(_scenario(), load=2.0).grid_mode == "refine"


# ---------------------------------------------------------------------------
# PlanningService: warmup, zero traces, parity, stats
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def warm_service():
    service = PlanningService(ServiceConfig(**SMALL))
    service.warmup()
    service.start()
    yield service
    service.stop()


def test_service_zero_post_warmup_traces_and_parity(warm_service):
    service = warm_service
    requests = synth_requests(24, seed=5, dup_frac=0.0, n_classes=24,
                              models=("ideal", "erasure", "fading",
                                      "gilbert_elliott"), n_max=512)
    instances = list(service.objectives.values())
    modes = service.config.grid_modes
    futures, assigned = [], []
    for i, sc in enumerate(requests):
        if i % 3 == 0:
            futures.append(service.submit(sc))       # admission policy
            assigned.append((None, None))
        else:
            obj = instances[i % len(instances)]
            mode = modes[i % len(modes)]
            futures.append(service.submit(sc, objective=obj, grid_mode=mode))
            assigned.append((obj, mode))
    records = [f.result(timeout=60) for f in futures]

    stats = service.stats()
    assert stats.counters.get("post_warmup_traces", 0) == 0, stats.buckets
    assert stats.n_planned >= 24
    assert stats.latency_p99_ms >= stats.latency_p50_ms >= 0.0
    assert stats.plans_per_sec > 0

    # bitwise parity: the service adds batching/caching, never arithmetic
    direct = FleetPlanner(grid_size=SMALL["grid_size"],
                          pow2_refine_widths=True)
    for sc, rec, (obj, mode) in zip(requests, records, assigned):
        if obj is None:
            continue  # policy-routed: mode pick is load-dependent
        want = direct.plan_many([sc], service.consts, objective=obj,
                                grid_mode=mode)[0]
        assert want == rec


def test_service_objective_and_mode_validation(warm_service):
    sc = _scenario()
    with pytest.raises(KeyError, match="not served"):
        warm_service.submit(sc, objective="montecarlo")
    with pytest.raises(ValueError, match="not served"):
        warm_service.submit(sc, objective="corollary1", grid_mode="bogus")


def test_service_config_validation():
    with pytest.raises(ValueError, match="powers of two"):
        ServiceConfig(batch_buckets=(3,))
    with pytest.raises(ValueError, match="ascend"):
        ServiceConfig(batch_buckets=(8, 4))
    with pytest.raises(ValueError, match="grid mode"):
        ServiceConfig(grid_modes=("sparse",))


# ---------------------------------------------------------------------------
# Drift-triggered re-planning
# ---------------------------------------------------------------------------

def test_reestimate_link_gilbert_elliott_and_erasure():
    ge = GilbertElliottLink(p_gb=0.05, p_bg=0.45, p_good=0.01, p_bad=0.8,
                            rates=RATES)
    worse = reestimate_link(ge, rate=1.0, observed_loss=0.6)
    assert isinstance(worse, GilbertElliottLink)
    # mixing speed preserved, occupancy re-fit upward
    assert worse.p_gb + worse.p_bg == pytest.approx(ge.p_gb + ge.p_bg)
    pi_old = ge.p_gb / (ge.p_gb + ge.p_bg)
    pi_new = worse.p_gb / (worse.p_gb + worse.p_bg)
    assert pi_new > pi_old
    assert worse.p_err(1.0) == pytest.approx(0.6, abs=1e-9)

    er = ErasureLink(beta=0.4, p_base=0.05, rates=RATES)
    worse_er = reestimate_link(er, rate=1.5, observed_loss=0.5)
    assert worse_er.p_err(1.5) == pytest.approx(0.5, abs=1e-9)

    degenerate = GilbertElliottLink(p_gb=0.1, p_bg=0.4, p_good=0.3,
                                    p_bad=0.3, rates=RATES)
    assert reestimate_link(degenerate, 1.0, 0.6) is None


def test_session_drift_triggers_replan_with_changed_argmin(warm_service):
    service = warm_service
    # a GE link planned while mostly-good; the chain then degrades hard
    link = GilbertElliottLink(p_gb=0.02, p_bg=0.5, p_good=0.005, p_bad=0.9,
                              beta=0.3, rates=RATES)
    scenario = _scenario(seed=11, n=2048, link=link)
    fut = service.open_session("dev-0", scenario, objective="markov_arq",
                               grid_mode="dense")
    first = fut.result(timeout=60)
    session = service.session("dev-0")
    assert session.plan == first and session.generation == 1

    # stream heavy observed loss: EWMA -> ~0.9 while the plan priced the
    # near-stationary chain (pi_bad ~ 0.04)
    replan_future = None
    for _ in range(50):
        replan_future = service.observe("dev-0", [True] * 4)
        if replan_future is not None:
            break
    assert replan_future is not None, "drift never fired"
    second = replan_future.result(timeout=60)
    assert session.replans == 1
    assert session.generation == 2
    assert session.scenario.link != link         # link was re-estimated
    # the degraded channel must change the chosen operating point
    assert (second.n_c, second.rate, second.p_err) \
        != (first.n_c, first.rate, first.p_err)
    # and the re-planned answer must equal a direct solve of the
    # re-estimated scenario (drift path reuses the ordinary plan path)
    direct = FleetPlanner(grid_size=SMALL["grid_size"],
                          pow2_refine_widths=True)
    want = direct.plan_many([session.scenario], service.consts,
                            objective=service.objectives["markov_arq"],
                            grid_mode="dense")[0]
    assert want == second
    stats = service.stats()
    assert stats.counters.get("drift_replans", 0) >= 1
    assert stats.cache.get("invalidations", 0) >= 1
    assert stats.counters.get("post_warmup_traces", 0) == 0
    service.close_session("dev-0")
    with pytest.raises(KeyError):
        service.session("dev-0")


def test_session_open_rejects_duplicate_and_tracks_count(warm_service):
    service = warm_service
    sc = _scenario(seed=21, n=768)
    service.open_session("dup-1", sc, objective="corollary1",
                         grid_mode="dense").result(timeout=60)
    try:
        with pytest.raises(ValueError, match="already open"):
            service.open_session("dup-1", sc, objective="corollary1",
                                 grid_mode="dense")
        assert service.stats().counters["sessions_open"] >= 1
    finally:
        service.close_session("dup-1")


# ---------------------------------------------------------------------------
# Launch driver wiring
# ---------------------------------------------------------------------------

def test_serve_cli_rejects_unknown_names():
    from repro.launch.serve import main
    assert main(["--objective", "bogus", "--requests", "1"]) == 2
    assert main(["--policy", "bogus", "--requests", "1"]) == 2
    assert main(["--grid-mode", "bogus", "--requests", "1"]) == 2
    assert main(["--buckets", "3", "--requests", "1"]) == 2


def test_plan_server_reports_batch_latency_percentiles():
    from repro.launch.plan_server import serve
    planner = FleetPlanner(grid_size=8)
    reqs = synth_requests(12, seed=3, dup_frac=0.0, n_classes=12,
                          models=("erasure",), n_max=512)
    stats = serve(reqs, planner=planner, consts=CONSTS,
                  cache=PlanCache(maxsize=64), batch_size=4)
    assert stats.batch_p99_ms >= stats.batch_p50_ms > 0.0
    assert stats.batch_max_ms >= stats.batch_p99_ms


# ---------------------------------------------------------------------------
# Observability: spans, metrics export, flush causes, CLI wiring
# ---------------------------------------------------------------------------

def test_batcher_counts_flush_causes():
    b, batches = _collecting_batcher(max_batch=2, flush_interval=0.02)
    b.start()
    try:
        futs = [b.submit(PlanRequest(scenario=i)) for i in range(4)]
        for f in futs:
            f.result(timeout=5.0)       # two full batches -> size flushes
        last = b.submit(PlanRequest(scenario=9))
        last.result(timeout=5.0)        # partial batch -> deadline flush
    finally:
        b.stop()
    assert b.flush_causes["size"] >= 1
    assert b.flush_causes["deadline"] >= 1
    assert sum(b.flush_causes.values()) == len(batches)


def test_service_spans_sum_to_latency(warm_service):
    service = warm_service
    requests = synth_requests(12, seed=40, dup_frac=0.0, n_classes=12,
                              models=("erasure", "fading"), n_max=512)
    futures = [service.submit(sc) for sc in requests]
    for f in futures:
        f.result(timeout=60)
    spans = service.spans.snapshot()
    assert spans and service.spans.recorded >= 12
    for span in spans:
        # the phases partition the enqueue-to-plan latency exactly:
        # contiguous intervals cut from one monotonic clock
        assert abs(span.phase_sum - span.latency_s) <= 1e-6, span
        assert span.solve_device_s <= span.solve_s + 1e-9
        assert all(v >= 0.0 for v in span.phases().values())
        assert span.bucket in warm_service.config.batch_buckets
        assert span.objective in SMALL["objective_ids"]
    totals = service.spans.totals()
    assert 0.0 < service.spans.solve_fraction <= 1.0
    phase_sum = sum(totals[p] for p in ("batch_wait", "pad", "cache_lookup",
                                        "solve", "resolve"))
    assert phase_sum == pytest.approx(totals["latency"], rel=1e-9)


def test_service_metrics_round_trip_all_counters(warm_service):
    from repro.serve.export import GAUGE_COUNTERS
    service = warm_service
    requests = synth_requests(8, seed=41, dup_frac=0.0, n_classes=8,
                              models=("erasure",), n_max=512)
    for f in [service.submit(sc) for sc in requests]:
        f.result(timeout=60)
    stats = service.stats()
    snap = service.metrics_snapshot()   # parses the rendered exposition

    # EVERY ServiceStats counter is reachable through the export
    for name, v in stats.counters.items():
        if name in GAUGE_COUNTERS:
            assert snap[f"repro_serve_{name}"][()] == v, name
        else:
            assert snap[f"repro_serve_{name}_total"][()] == v, name
    assert {"flushes_size", "flushes_deadline", "flushes_drain"} \
        <= set(stats.counters)

    # per-bucket counters carry their (objective, grid_mode, bucket) labels
    for (oid, mode, bucket), slot in stats.buckets.items():
        labels = (("bucket", str(bucket)), ("grid_mode", mode),
                  ("objective", oid))
        assert snap["repro_serve_bucket_requests_total"][labels] \
            == slot["requests"]

    # one span and one histogram sample per planned request, and the
    # exported phase totals re-partition the exported latency total
    assert snap["repro_serve_spans_recorded_total"][()] \
        == snap["repro_serve_latency_seconds_count"][()]
    phase_total = sum(
        v for labels, v in snap["repro_serve_phase_seconds_total"].items()
        if dict(labels)["phase"] != "admit")
    assert phase_total == pytest.approx(
        snap["repro_serve_span_latency_seconds_total"][()], rel=1e-6)
    assert snap["repro_serve_solve_device_seconds_total"][()] > 0.0
    assert 0.0 < snap["repro_serve_solve_fraction"][()] <= 1.0
    # the zero-trace SLO series a scrape would alert on
    assert snap["repro_serve_post_warmup_traces_total"][()] == 0
    assert snap["repro_fleet_traces_total"][()] > 0
    assert service.metrics.value("repro_serve_planned_total") \
        == stats.n_planned


def test_service_journal_records_session_lifecycle(warm_service):
    service = warm_service
    before = service.journal.counts()
    sc = _scenario(seed=51, n=640)
    service.open_session("obs-1", sc, objective="corollary1",
                         grid_mode="dense").result(timeout=60)
    service.close_session("obs-1")
    counts = service.journal.counts()
    assert counts.get("session_open", 0) == before.get("session_open", 0) + 1
    assert counts.get("session_close", 0) \
        == before.get("session_close", 0) + 1
    kinds = [e["kind"] for e in service.journal.tail(50)]
    assert "session_open" in kinds and "session_close" in kinds
    closes = [e for e in service.journal.tail(50)
              if e["kind"] == "session_close"
              and e["session_id"] == "obs-1"]
    assert closes and closes[-1]["generation"] == 1


def test_serve_cli_writes_metrics_textfile_and_journal(tmp_path):
    from repro.launch.serve import main
    from repro.obs import parse_exposition, read_jsonl
    metrics_path = tmp_path / "metrics.prom"
    journal_path = tmp_path / "events.jsonl"
    # --policy-frac 0: the link_aware policy may route to "refine",
    # which this one-mode config does not serve
    rc = main(["--requests", "6", "--buckets", "4", "--grid", "8",
               "--n-max", "512", "--models", "erasure",
               "--objective", "corollary1", "--grid-mode", "dense",
               "--policy-frac", "0",
               "--metrics-textfile", str(metrics_path),
               "--journal", str(journal_path)])
    assert rc == 0
    snap = parse_exposition(metrics_path.read_text())
    assert snap["repro_serve_planned_total"][()] == 6
    assert snap["repro_serve_post_warmup_traces_total"][()] == 0
    assert snap["repro_serve_latency_seconds_count"][()] == 6
    events = read_jsonl(str(journal_path))
    assert any(e["kind"] == "warmup" for e in events)
