"""Resilience layer: deterministic fault injection, retry/backoff +
circuit breaker, deadline-budget degradation ladder, bounded-queue
shedding, health states, and the chaos gate (faults on -> 100%
completion with stamped fallbacks; faults off -> bitwise parity)."""
import threading
import time
from concurrent.futures import Future

import pytest

from repro.chaos import (INJECTION_POINTS, FaultPlan, FaultRule,
                         InjectedFault, parse_chaos_spec)
from repro.fleet import FleetPlanner, PlanCache
from repro.serve import (BREAKER_STATES, FALLBACK_LEVELS, HEALTH_STATES,
                         CircuitBreaker, LoadSheddingPolicy, MicroBatcher,
                         PlanRequest, PlanningService, QueueFull,
                         RequestShed, ResilienceManager, RetryPolicy,
                         ServiceConfig, SolveTimeEstimator, policy_spec,
                         synth_requests)
from repro.serve.resilience import CLOSED, HALF_OPEN, OPEN

# same small warm population the serving tests use (keep grids tiny:
# CI runs on one CPU core)
SMALL = dict(grid_size=16, batch_buckets=(4, 8), flush_interval=0.01,
             objective_ids=("corollary1", "markov_arq"), n_max=512,
             min_observations=4)

# every injection point enabled, transient rates: most solves succeed,
# some chunks exhaust their retries and walk the ladder
CHAOS_SPEC = ("seed=7,solve_error=0.4,solve_latency=0.2:2ms,"
              "cache_corrupt=0.3,queue_stall=0.2:1ms")


# ---------------------------------------------------------------------------
# FaultPlan: determinism, spec grammar, counters
# ---------------------------------------------------------------------------

def test_fault_plan_schedule_is_deterministic_and_pure():
    a = parse_chaos_spec(CHAOS_SPEC)
    b = parse_chaos_spec(CHAOS_SPEC)
    for point in INJECTION_POINTS:
        assert a.schedule(point, 64) == b.schedule(point, 64)
    # schedule() is pure: it never advances the draw counters
    assert a.draws == {}
    # draw() follows the published schedule exactly
    want = a.schedule("solve.error", 32)
    got = [a.draw("solve.error") is not None for _ in range(32)]
    assert got == want
    assert a.fires.get("solve.error", 0) == sum(want)
    assert a.draws["solve.error"] == 32
    # reset() rewinds to a byte-identical replay
    a.reset()
    assert [a.draw("solve.error") is not None for _ in range(32)] == want


def test_fault_plan_points_are_independent():
    plan = FaultPlan(seed=3, rules=(FaultRule("solve.error", 0.5),
                                    FaultRule("cache.corrupt", 0.5)))
    want = plan.schedule("cache.corrupt", 16)
    # interleave draws at another point: cache.corrupt's schedule must
    # not shift (no shared RNG stream)
    got = []
    for _ in range(16):
        plan.draw("solve.error")
        got.append(plan.draw("cache.corrupt") is not None)
    assert got == want


def test_parse_chaos_spec_grammar_and_round_trip():
    plan = parse_chaos_spec(CHAOS_SPEC)
    assert plan.seed == 7
    assert plan.rules["solve.latency"].duration_s == pytest.approx(2e-3)
    assert not plan.rules["cache.corrupt"].duration_s
    # spec() round-trips through the parser to the same schedule
    again = parse_chaos_spec(plan.spec())
    for point in INJECTION_POINTS:
        assert again.schedule(point, 32) == plan.schedule(point, 32)
    assert parse_chaos_spec("").rules == {}      # empty = clean control
    with pytest.raises(ValueError, match="unknown injection point"):
        parse_chaos_spec("solve_eror=0.5")
    with pytest.raises(ValueError, match="bad rate"):
        parse_chaos_spec("solve_error=lots")
    with pytest.raises(ValueError, match="bare rate"):
        parse_chaos_spec("solve_error=0.5:10ms")
    with pytest.raises(ValueError, match="rate must be in"):
        parse_chaos_spec("solve_error=1.5")
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultPlan(rules=(FaultRule("bogus.point", 0.5),))


# ---------------------------------------------------------------------------
# CircuitBreaker: transitions, probes, recovery
# ---------------------------------------------------------------------------

def _clocked_breaker(**kw):
    t = [0.0]
    transitions = []
    brk = CircuitBreaker(clock=lambda: t[0],
                         on_transition=lambda a, b: transitions.append(
                             (a, b)), **kw)
    return brk, t, transitions


def test_breaker_full_cycle_and_counters():
    brk, t, transitions = _clocked_breaker(threshold=2, cooldown_s=1.0)
    assert brk.state == CLOSED and brk.allow()
    brk.record_failure()
    assert brk.state == CLOSED          # below threshold
    brk.record_failure()
    assert brk.state == OPEN and brk.trips == 1
    assert not brk.allow()              # cooldown not elapsed
    t[0] += 1.0
    assert brk.allow()                  # promotes + admits the probe
    assert brk.state == HALF_OPEN and brk.probes == 1
    brk.record_failure()                # probe failed: re-open
    assert brk.state == OPEN and brk.trips == 1   # re-open is not a trip
    t[0] += 1.0
    assert brk.allow() and brk.state == HALF_OPEN
    brk.record_success()                # probe succeeded: recover
    assert brk.state == CLOSED and brk.recoveries == 1
    assert brk.failures == 0
    # transitions never skip a state
    legal = {(CLOSED, OPEN), (OPEN, HALF_OPEN),
             (HALF_OPEN, CLOSED), (HALF_OPEN, OPEN)}
    assert set(transitions) <= legal
    assert transitions[0] == (CLOSED, OPEN)


def test_breaker_success_resets_consecutive_failures():
    brk, _, _ = _clocked_breaker(threshold=3, cooldown_s=1.0)
    brk.record_failure()
    brk.record_failure()
    brk.record_success()                # streak broken
    brk.record_failure()
    brk.record_failure()
    assert brk.state == CLOSED          # 2 < threshold again
    with pytest.raises(ValueError, match="threshold"):
        CircuitBreaker(threshold=0)


# ---------------------------------------------------------------------------
# hypothesis: breaker state machine + chaos determinism property
# ---------------------------------------------------------------------------

def test_breaker_state_machine_never_skips_states():
    pytest.importorskip("hypothesis")
    from hypothesis import settings
    from hypothesis.stateful import (RuleBasedStateMachine, rule,
                                     invariant, run_state_machine_as_test)

    class BreakerMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.t = 0.0
            self.transitions = []
            self.brk = CircuitBreaker(
                threshold=2, cooldown_s=1.0, clock=lambda: self.t,
                on_transition=lambda a, b: self.transitions.append((a, b)))

        @rule()
        def allow(self):
            before = self.brk.state
            probes = self.brk.probes
            admitted = self.brk.allow()
            if before == CLOSED:
                assert admitted
            # a probe is only ever admitted from (or into) half-open
            if self.brk.probes > probes:
                assert self.brk.state == HALF_OPEN

        @rule()
        def succeed(self):
            self.brk.record_success()
            assert self.brk.state in (CLOSED, OPEN)

        @rule()
        def fail(self):
            self.brk.record_failure()

        @rule()
        def tick(self):
            self.t += 0.6

        @invariant()
        def state_is_valid_and_transitions_are_adjacent(self):
            assert self.brk.state in BREAKER_STATES
            legal = {(CLOSED, OPEN), (OPEN, HALF_OPEN),
                     (HALF_OPEN, CLOSED), (HALF_OPEN, OPEN)}
            assert set(self.transitions) <= legal
            for (_, into), (frm, _) in zip(self.transitions,
                                           self.transitions[1:]):
                assert frm == into      # the chain has no gaps

    run_state_machine_as_test(
        BreakerMachine, settings=settings(max_examples=30,
                                          stateful_step_count=40,
                                          deadline=None))


def test_chaos_schedule_property_same_seed_same_faults():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           rate=st.floats(0.0, 1.0, allow_nan=False))
    def check(seed, rate):
        mk = lambda: FaultPlan(seed=seed, rules=(  # noqa: E731
            FaultRule("solve.error", rate),))
        a, b = mk(), mk()
        sched = a.schedule("solve.error", 40)
        assert sched == b.schedule("solve.error", 40)
        assert [b.draw("solve.error") is not None
                for _ in range(40)] == sched
        # rate bounds the empirical fire fraction only degenerately
        if rate == 0.0:
            assert not any(sched)
        if rate == 1.0:
            assert all(sched)

    check()


# ---------------------------------------------------------------------------
# RetryPolicy, estimator, manager-level retry/breaker loop
# ---------------------------------------------------------------------------

def test_retry_policy_backoff_is_seeded_and_capped():
    policy = RetryPolicy(attempts=5, base_s=0.01, cap_s=0.05, seed=11)
    a = [policy.delays().next_delay() for _ in range(1)]
    d1, d2 = policy.delays(), policy.delays()
    seq1 = [d1.next_delay() for _ in range(6)]
    seq2 = [d2.next_delay() for _ in range(6)]
    assert seq1 == seq2                    # same seed, same sequence
    assert all(0.01 <= d <= 0.05 for d in seq1 + a)
    with pytest.raises(ValueError, match="attempts"):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError, match="base_s"):
        RetryPolicy(base_s=0.5, cap_s=0.1)


def test_solve_time_estimator_quantile_and_empty():
    est = SolveTimeEstimator(quantile=90.0)
    assert est.estimate("corollary1", "dense") == 0.0   # optimistic
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 50, 50):
        est.observe("corollary1", "dense", ms * 1e-3)
    q = est.estimate("corollary1", "dense")
    assert q > 5e-3                        # the p90 sees the slow tail
    assert est.estimate("corollary1", "refine") == 0.0  # keys separate


def test_run_attempts_retries_then_raises_and_trips_breaker():
    mgr = ResilienceManager(retry=RetryPolicy(attempts=3, base_s=1e-4,
                                              cap_s=1e-3),
                            breaker_threshold=3, breaker_cooldown_s=9.0)
    calls = []
    naps = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"
    assert mgr.run_attempts("o", "dense", flaky,
                            sleep=naps.append) == "ok"
    assert len(calls) == 3 and mgr.retries == 2 and len(naps) == 2
    assert mgr.breaker("o", "dense").state == CLOSED  # success reset it

    def always():
        raise RuntimeError("hard")
    with pytest.raises(RuntimeError, match="hard"):
        mgr.run_attempts("o", "dense", always, sleep=naps.append)
    assert mgr.breaker("o", "dense").state == OPEN
    snap = mgr.snapshot()
    assert snap["breakers"][("o", "dense")]["trips"] == 1
    assert snap["retries"] == mgr.retries
    assert snap["backoff_seconds"] == pytest.approx(sum(naps))


def test_run_attempts_breaker_recovery_via_half_open_probe():
    t = [0.0]
    mgr = ResilienceManager(retry=RetryPolicy(attempts=1),
                            breaker_threshold=2, breaker_cooldown_s=1.0,
                            clock=lambda: t[0])

    def boom():
        raise RuntimeError("down")
    for _ in range(2):
        with pytest.raises(RuntimeError):
            mgr.run_attempts("o", "dense", boom, sleep=lambda s: None)
    brk = mgr.breaker("o", "dense")
    assert brk.state == OPEN and not brk.allow()
    t[0] += 1.0
    assert brk.allow()                    # the half-open probe admission
    assert brk.state == HALF_OPEN and brk.probes == 1
    mgr.run_attempts("o", "dense", lambda: "ok", sleep=lambda s: None)
    assert brk.state == CLOSED and brk.recoveries == 1


def test_manager_health_derivation():
    mgr = ResilienceManager()
    assert mgr.health(warmed=False, queue_depth=0,
                      max_pending=0).state == "STARTING"
    assert mgr.health(warmed=True, queue_depth=0,
                      max_pending=0).state == "READY"
    report = mgr.health(warmed=True, queue_depth=4, max_pending=4)
    assert report.state == "SHEDDING" and not report.ready
    brk = mgr.breaker("o", "dense")
    brk.record_failure()
    for _ in range(mgr.breaker_threshold):
        brk.record_failure()
    report = mgr.health(warmed=True, queue_depth=0, max_pending=4)
    assert report.state == "DEGRADED" and report.ready
    assert any("breaker" in r for r in report.reasons)
    drift = mgr.health(warmed=True, queue_depth=0, max_pending=0,
                       drift_backlog=8, drift_backlog_limit=8)
    assert drift.state == "DEGRADED"
    assert [s for s in HEALTH_STATES] == \
        ["STARTING", "READY", "DEGRADED", "SHEDDING"]


# ---------------------------------------------------------------------------
# Bounded ingestion queue + load-shedding policy + corrupting cache
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_instead_of_blocking():
    release = threading.Event()

    def plan_group(reqs):
        release.wait(timeout=10.0)
        for r in reqs:
            r.future.set_result(r.scenario)
    b = MicroBatcher(plan_group, max_batch=1, flush_interval=0.005,
                     max_pending=2)
    b.start()
    try:
        first = b.submit(PlanRequest(scenario=0))
        # the worker is stuck in plan_group holding request 0, so these
        # two fill the bounded queue...
        deadline = time.monotonic() + 5.0
        queued = []
        while len(queued) < 2 and time.monotonic() < deadline:
            try:
                queued.append(b.submit(PlanRequest(scenario=1)))
            except QueueFull:
                time.sleep(0.001)
        assert len(queued) == 2
        # ... and the next submit is REJECTED immediately, not blocked
        t0 = time.monotonic()
        with pytest.raises(QueueFull, match="capacity"):
            b.submit(PlanRequest(scenario=2))
        assert time.monotonic() - t0 < 1.0
        assert b.rejections >= 1
        release.set()
        assert first.result(timeout=5.0) == 0
        for f in queued:
            assert f.result(timeout=5.0) == 1
    finally:
        release.set()
        b.stop()
    with pytest.raises(ValueError, match="max_pending"):
        MicroBatcher(plan_group, max_pending=-1)


def test_load_shedding_policy_sheds_at_threshold():
    spec = policy_spec("load_shedding")
    policy = spec.cls()
    assert isinstance(policy, LoadSheddingPolicy)
    sc = synth_requests(1, seed=0, models=("erasure",), n_max=512)[0]
    ok = policy.admit(sc, load=0.0)
    assert ok.action == "accept" and ok.accepted
    shed = policy.admit(sc, load=policy.shed_load)
    assert shed.action == "shed" and not shed.accepted
    # the shed decision still carries the inner policy's routing
    assert shed.objective_id == policy.admit(sc, load=0.0).objective_id


def test_cache_checksums_detect_injected_corruption():
    sc = synth_requests(1, seed=1, models=("erasure",), n_max=512)[0]
    hits = [True, False]     # corrupt the first read only
    cache = PlanCache(maxsize=8, corruptor=lambda: hits.pop(0)
                      if hits else False)
    cache.put(sc, "record")
    assert cache.get(sc) is None         # corrupted -> dropped, a miss
    assert cache.corruptions == 1 and cache.misses == 1
    cache.put(sc, "record")
    assert cache.get(sc) == "record"     # clean read round-trips
    assert cache.stats()["corruptions"] == 1
    # peek never draws corruption and never counts
    always = PlanCache(maxsize=8, corruptor=lambda: True)
    always.put(sc, "record")
    assert always.peek(sc) == "record"
    assert always.corruptions == 0 and always.hits + always.misses == 0


# ---------------------------------------------------------------------------
# Service-level: chaos gate, deterministic degrade, budgets, recovery
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_service():
    """Warm service under transient chaos at every injection point."""
    cfg = ServiceConfig(retry_attempts=2, breaker_threshold=3,
                        breaker_cooldown_s=0.05, chaos_spec=CHAOS_SPEC,
                        **SMALL)
    service = PlanningService(cfg)
    service.warmup()
    service.start()
    yield service
    service.stop()


@pytest.fixture(scope="module")
def blackout_service():
    """Warm service whose every requested-objective solve fails: the
    degradation ladder is the only way a request completes."""
    cfg = ServiceConfig(retry_attempts=2, breaker_threshold=3,
                        breaker_cooldown_s=30.0,
                        chaos_spec="seed=1,solve_error=1.0", **SMALL)
    service = PlanningService(cfg)
    service.warmup()
    service.start()
    yield service
    service.stop()


def test_chaos_gate_completion_stamps_and_parity(chaos_service):
    service = chaos_service
    # dup_frac=0: all-distinct scenarios, so no record is served off the
    # quantised cache (a hit replays the KEY's first plan, which is only
    # grid-resolution-close for a near-duplicate — not bitwise equal)
    requests = synth_requests(48, seed=9, dup_frac=0.0, n_classes=48,
                              models=("erasure", "gilbert_elliott"),
                              n_max=512)
    instances = list(service.objectives.values())
    modes = service.config.grid_modes
    futures, assigned = [], []
    for i, sc in enumerate(requests):
        obj = instances[i % len(instances)]
        mode = modes[i % len(modes)]
        futures.append(service.submit(sc, objective=obj, grid_mode=mode))
        assigned.append((obj, mode))
    # 100% completion: chaos may degrade answers, never lose them
    records = [f.result(timeout=120) for f in futures]
    assert all(r is not None for r in records)

    stats = service.stats()
    snap = stats.resilience
    # faults actually fired (the run was a chaos run, not a control)
    assert sum(snap["faults_injected"].values()) > 0
    assert snap["faults_injected"].get("solve.error", 0) > 0
    # every non-full record is stamped with a ladder level AND counted
    degraded = [r for r in records if r.fallback != "full"]
    for rec in degraded:
        assert rec.fallback in FALLBACK_LEVELS[1:]
    assert sum(snap["fallbacks"].values()) >= len(degraded)
    if degraded:
        assert stats.counters["degraded"] >= len(degraded)
        assert sum(snap["degrade_reasons"].values()) >= len(degraded)
    # the fallback ladder never traces post-warmup
    assert stats.counters.get("post_warmup_traces", 0) == 0, stats.buckets
    # faults-off parity: a record the chaos run served at level "full"
    # is bitwise what a direct chaos-free solve produces
    direct = FleetPlanner(grid_size=SMALL["grid_size"],
                          pow2_refine_widths=True)
    checked = 0
    for sc, rec, (obj, mode) in zip(requests, records, assigned):
        if rec.fallback != "full" or checked >= 8:
            continue
        want = direct.plan_many([sc], service.consts, objective=obj,
                                grid_mode=mode)[0]
        assert want == rec
        checked += 1
    assert checked > 0


def test_blackout_degrades_every_request_deterministically(
        blackout_service):
    service = blackout_service
    requests = synth_requests(12, seed=4, dup_frac=0.0, n_classes=12,
                              models=("erasure",), n_max=512)
    obj = service.objectives["markov_arq"]
    futures = [service.submit(sc, objective=obj, grid_mode="dense")
               for sc in requests]
    records = [f.result(timeout=120) for f in futures]
    # every solve failed, so every answer came off the ladder
    assert all(r.fallback in ("cached", "bound", "last_good")
               for r in records)
    # the bound rung serves the dense Corollary-1 objective
    bound = [r for r in records if r.fallback == "bound"]
    assert bound and all(r.objective == "corollary1" for r in bound)
    snap = service.stats().resilience
    assert snap["fallbacks"].get("bound", 0) >= len(bound)
    assert snap["degrade_reasons"].get("solve_failed", 0) > 0
    # enough consecutive failures tripped the group's breaker
    brk = service.resilience.breaker("markov_arq", "dense")
    assert brk.state in (OPEN, HALF_OPEN) and brk.trips >= 1
    assert service.health().state == "DEGRADED"
    assert service.stats().counters.get("post_warmup_traces", 0) == 0


def test_blackout_budget_triage_degrades_before_solving(
        blackout_service):
    service = blackout_service
    sc = synth_requests(1, seed=6, models=("erasure",), n_max=512)[0]
    before = service.stats().resilience["budget_exceeded"]
    fut = service.submit(sc, objective="corollary1", grid_mode="dense",
                         budget_s=1e-9)
    rec = fut.result(timeout=120)
    # the budget was blown before the solve could run: degraded, and
    # counted as a budget degrade (not a solve failure)
    assert rec.fallback in ("cached", "bound", "last_good")
    assert service.stats().resilience["budget_exceeded"] > before


def test_blackout_breaker_recovers_once_faults_clear(blackout_service):
    service = blackout_service
    # the markov_arq/dense breaker is open (tripped by the test above;
    # trip it here too so this test stands alone), then the fault rule
    # is cleared — the outage "ends"
    brk = service.resilience.breaker("markov_arq", "dense")
    while brk.state == CLOSED:
        brk.record_failure()
    service.faults.rules["solve.error"] = FaultRule("solve.error", 0.0)
    brk.cooldown_s = 0.0                  # cooldown elapses immediately
    sc = synth_requests(1, seed=8, models=("erasure",), n_max=512)[0]
    fut = service.submit(sc, objective="markov_arq", grid_mode="dense")
    rec = fut.result(timeout=120)
    # ... and the half-open probe solve recovers the breaker
    assert rec.fallback == "full"
    assert brk.state == CLOSED and brk.recoveries >= 1 \
        and brk.probes >= 1
    assert service.health().state == "READY"


def test_resilience_metrics_exported(chaos_service):
    # ensure the per-breaker families have at least one series
    chaos_service.resilience.breaker("corollary1", "dense")
    snap = chaos_service.metrics_snapshot()
    for family in ("repro_resilience_fallbacks_total",
                   "repro_resilience_retries_total",
                   "repro_resilience_faults_injected_total",
                   "repro_resilience_breaker_state",
                   "repro_resilience_breaker_trips_total",
                   "repro_resilience_health_state"):
        assert family in snap, sorted(snap)
    states = snap["repro_resilience_health_state"]
    assert list(states.values())[0] in range(len(HEALTH_STATES))
    onehot = snap["repro_resilience_health"]
    assert sum(onehot.values()) == 1.0    # exactly one state is current
    # ladder levels and ENABLED injection points are pre-declared at 0,
    # so dashboards can rate() them before the first incident
    levels = {dict(lbls)["level"] for lbls in
              snap["repro_resilience_fallbacks_total"]}
    assert set(FALLBACK_LEVELS[1:]) <= levels
    points = {dict(lbls)["point"] for lbls in
              snap["repro_resilience_faults_injected_total"]}
    assert {"solve.error", "solve.latency", "queue.stall",
            "cache.corrupt"} <= points


def test_service_sheds_when_queue_is_full():
    release = threading.Event()
    cfg = ServiceConfig(max_pending=1, flush_interval=30.0,
                        batch_buckets=(4,), grid_size=8,
                        objective_ids=("corollary1",), n_max=512)
    service = PlanningService(cfg)
    # stall the worker without jax: replace the group planner with a gate
    service.batcher._plan_group = lambda reqs: (
        release.wait(timeout=10.0),
        [r.future.set_result(None) for r in reqs])
    service.warmup = lambda *a, **k: 0
    service.start()
    try:
        sc = synth_requests(1, seed=2, models=("erasure",), n_max=512)[0]
        service.submit(sc, objective="corollary1", grid_mode="dense")
        deadline = time.monotonic() + 5.0
        shed = None
        while shed is None and time.monotonic() < deadline:
            try:
                service.submit(sc, objective="corollary1",
                               grid_mode="dense")
            except RequestShed as exc:
                shed = exc
            time.sleep(0.001)
        assert shed is not None
        snap = service.stats()
        assert snap.counters["shed"] >= 1
        assert snap.resilience["sheds"].get("queue_full", 0) >= 1
    finally:
        release.set()
        service.stop()


# ---------------------------------------------------------------------------
# One-shot plan server: chaos determinism end to end + CLI validation
# ---------------------------------------------------------------------------

def test_plan_server_chaos_run_is_deterministic():
    from repro.launch.plan_server import serve
    from repro.serve import default_consts, resolve_objectives
    reqs = synth_requests(12, seed=5, dup_frac=0.0, n_classes=12,
                          models=("erasure",), n_max=512)
    catalogue = resolve_objectives(("corollary1", "markov_arq"))

    def run():
        planner = FleetPlanner(grid_size=8)
        instances = list(catalogue.values())
        objectives = [instances[i % 2] for i in range(len(reqs))]
        faults = parse_chaos_spec("seed=13,solve_error=0.5")
        return serve(reqs, planner=planner, consts=default_consts(),
                     cache=PlanCache(maxsize=64), batch_size=4,
                     objectives=objectives, faults=faults)
    a, b = run(), run()
    # same seed, same stream -> identical faults, identical records
    # (including which groups degraded and to what)
    assert a.faults_injected == b.faults_injected
    assert a.n_degraded == b.n_degraded
    assert a.records == b.records
    assert a.n_degraded > 0              # the chaos actually bit
    assert all(r is not None for r in a.records)
    degraded = [r for r in a.records if r.fallback == "bound"]
    assert len(degraded) == a.n_degraded


def test_cli_flags_validate_chaos_spec():
    from repro.launch.plan_server import main as plan_server_main
    from repro.launch.serve import main as serve_main
    assert plan_server_main(["--chaos-spec", "bogus_point=0.5",
                             "--requests", "1"]) == 2
    assert serve_main(["--chaos-spec", "bogus_point=0.5",
                       "--requests", "1"]) == 2


def test_future_type_contract():
    # PlanRequest futures are concurrent.futures.Future: the shed path
    # must reject BEFORE a future exists, never resolve one with an error
    req = PlanRequest(scenario=None)
    assert isinstance(req.future, Future)
    assert req.remaining_budget() is None          # no budget -> None
    req2 = PlanRequest(scenario=None, budget_s=60.0)
    remaining = req2.remaining_budget()
    assert remaining is not None and 59.0 < remaining <= 60.0
