"""Pluggable planner-objective registry: registration contracts, the
BoundObjective extraction (bitwise-identical plans), the exact burst-aware
MarkovARQObjective (reduction + strictly-better sticky plans, scalar and
fleet), the batched MonteCarloObjective (seed-for-seed equal to the scalar
planner, fixed cases + hypothesis property), objective-scoped PlanCache
keys, the mixed-objective plan server, a custom-objective plugin going
end-to-end, and the unknown-objective CLI exit code."""
from dataclasses import dataclass
from typing import ClassVar

import numpy as np
import pytest

from repro.core import (BoundConstants, BoundObjective, BoundPlanner,
                        ErasureLink, FadingLink, GilbertElliottLink,
                        IdealLink, MarkovARQObjective, MonteCarloObjective,
                        MonteCarloPlanner, MultiDevice, ObjectivePlanner,
                        Scenario, SingleDevice, objective_spec,
                        objective_spec_for, register_objective,
                        registered_objectives, unregister_objective)
from repro.core.planner import fleet_grid
from repro.fleet import (FleetPlanner, PlanCache, ScenarioBatch,
                         grid_objective_builder, objective_token,
                         register_objective_kernel,
                         unregister_objective_kernel)
from repro.launch.plan_server import (default_consts, resolve_objectives,
                                      serve, synth_requests)

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
RATES5 = (1.0, 1.25, 1.5, 2.0, 3.0)

#: Sticky Gilbert-Elliott chain: long bursts (p_gb + p_bg << 1) and a much
#: lossier bad state, where the stationary-loss approximation materially
#: underestimates the ARQ cost.
STICKY_LINK = GilbertElliottLink(p_gb=0.05, p_bg=0.05, p_good=0.0,
                                 p_bad=0.85, beta=0.7, rates=RATES5)
STICKY_SC = Scenario(N=8192, T=1.8 * 8192, n_o=800.0, link=STICKY_LINK)


def _ridge_data(n=128, d=6, seed=5):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = X @ rng.normal(size=d) + 0.1 * rng.normal(size=n)
    return X, y


def _mixed_scenarios():
    return [
        Scenario(N=2048, T=1.5 * 2048, n_o=100.0),
        Scenario(N=18576, T=1.2 * 18576, n_o=500.0,
                 link=ErasureLink(beta=0.4, rates=RATES5)),
        Scenario(N=4096, T=1.4 * 4096, n_o=200.0,
                 link=FadingLink(snr=8.0, rates=RATES5)),
        Scenario(N=8192, T=1.3 * 8192, n_o=300.0,
                 link=GilbertElliottLink(p_gb=0.1, p_bg=0.6, p_good=0.05,
                                         p_bad=0.6, beta=0.3, rates=RATES5),
                 topology=MultiDevice(2)),
        STICKY_SC,
    ]


# ---------------------------------------------------------------------------
# registry contracts
# ---------------------------------------------------------------------------


def test_builtin_objectives_registered():
    ids = [s.objective_id for s in registered_objectives()]
    assert ids == sorted(ids)
    assert {"corollary1", "markov_arq", "montecarlo"} <= set(ids)
    assert objective_spec("corollary1").cls is BoundObjective
    assert objective_spec_for(MarkovARQObjective()).objective_id \
        == "markov_arq"


def test_register_objective_validation():
    with pytest.raises(KeyError, match="known ids"):
        objective_spec("definitely_not_registered")

    class NoId:
        pass

    with pytest.raises(ValueError, match="objective_id"):
        register_objective(NoId)

    class MissingMethods:
        objective_id = "missing_methods"

    with pytest.raises(TypeError, match="missing Objective methods"):
        register_objective(MissingMethods)

    class Duplicate:
        objective_id = "corollary1"

        def evaluate(self, *a): ...
        def effective_overhead(self, *a): ...
        def cache_token(self): ...

    with pytest.raises(ValueError, match="already registered"):
        register_objective(Duplicate)
    with pytest.raises(KeyError, match="not a registered objective"):
        objective_spec_for(Duplicate)
    # unregister is a tolerant no-op on absent ids
    unregister_objective("never_registered")


def test_objective_cache_tokens_distinct():
    X, y = _ridge_data()
    tokens = {objective_token(BoundObjective()),
              objective_token(MarkovARQObjective()),
              objective_token(MonteCarloObjective(X=X, y=y)),
              objective_token(None)}
    assert len(tokens) == 4
    # MC hyperparameters and DATA are part of the token
    assert objective_token(MonteCarloObjective(X=X, y=y, n_runs=3)) != \
        objective_token(MonteCarloObjective(X=X, y=y, n_runs=5))
    X2 = X.copy()
    X2[0, 0] += 1.0
    assert objective_token(MonteCarloObjective(X=X, y=y)) != \
        objective_token(MonteCarloObjective(X=X2, y=y))

    class NoToken:
        objective_id = "no_token"

    with pytest.raises(TypeError, match="cache_token"):
        objective_token(NoToken())


def test_montecarlo_objective_validates_inputs():
    X, y = _ridge_data()
    with pytest.raises(ValueError, match="data"):
        MonteCarloObjective()
    with pytest.raises(ValueError, match="n_runs"):
        MonteCarloObjective(X=X, y=y, n_runs=0)


# ---------------------------------------------------------------------------
# BoundObjective: the extraction is bitwise-identical to the old planner
# ---------------------------------------------------------------------------


def test_objective_planner_matches_bound_planner_bitwise():
    for sc in _mixed_scenarios():
        a = BoundPlanner().plan(sc, CONSTS)
        b = ObjectivePlanner().plan(sc, CONSTS)  # default BoundObjective
        assert (a.n_c, a.rate, a.bound_value) == (b.n_c, b.rate,
                                                  b.bound_value)
        assert a.schedule == b.schedule and a.boundary == b.boundary
        np.testing.assert_array_equal(a.bound_grid, b.bound_grid)
        assert b.objective == "corollary1"


def test_fleet_default_objective_unchanged():
    batch = ScenarioBatch.from_scenarios(_mixed_scenarios())
    fp = FleetPlanner(grid_size=40).plan_batch(batch, CONSTS)
    fb = FleetPlanner(grid_size=40).plan_batch(batch, CONSTS,
                                               objective=BoundObjective())
    assert fp.objective == fb.objective == "corollary1"
    for field in ("n_c", "rate", "bound_value", "p_err", "n_o_eff"):
        np.testing.assert_array_equal(getattr(fp, field),
                                      getattr(fb, field))


# ---------------------------------------------------------------------------
# MarkovARQObjective: exact burst-aware ARQ planning
# ---------------------------------------------------------------------------


def test_markov_arq_inflation_exact_vs_stationary():
    link = STICKY_LINK
    rates = np.asarray(RATES5)
    exact = link.exact_arq_inflation(rates)
    stationary = 1.0 / (1.0 - link.p_err(rates))
    # failures cluster on a sticky chain: the exact expected attempts per
    # block strictly exceed the memoryless stationary approximation
    assert np.all(exact > stationary)
    # degenerate chain: bitwise reduction whatever the transition probs
    deg = GilbertElliottLink(p_gb=0.05, p_bg=0.05, p_good=0.3, p_bad=0.3,
                             beta=0.7, rates=RATES5)
    np.testing.assert_array_equal(deg.exact_arq_inflation(rates),
                                  1.0 / (1.0 - deg.p_err(rates)))
    np.testing.assert_array_equal(
        deg.exact_expected_block_time(100.0, 10.0, rates),
        deg.expected_block_time(100.0, 10.0, rates))


def test_markov_arq_equals_bound_for_memoryless_links():
    for sc in _mixed_scenarios()[:3]:  # ideal / erasure / fading
        a = BoundPlanner().plan(sc, CONSTS)
        m = ObjectivePlanner(objective=MarkovARQObjective()).plan(sc, CONSTS)
        assert (a.n_c, a.rate, a.bound_value) == (m.n_c, m.rate,
                                                  m.bound_value)
        np.testing.assert_array_equal(a.bound_grid, m.bound_grid)
        assert m.objective == "markov_arq"


def test_markov_arq_sticky_chain_plans_strictly_better():
    """ISSUE acceptance: on a sticky Gilbert-Elliott chain the exact
    burst-aware objective picks a different plan whose EXACT expected
    block time is strictly lower than the stationary-approximation
    plan's."""
    sc, link = STICKY_SC, STICKY_LINK
    stat = BoundPlanner().plan(sc, CONSTS)
    markov = ObjectivePlanner(objective=MarkovARQObjective()).plan(sc, CONSTS)
    assert (markov.n_c, markov.rate) != (stat.n_c, stat.rate)

    def exact_ebt(n_c, rate):
        return float(link.exact_expected_block_time(
            n_c, sc.union_overhead, rate))

    assert exact_ebt(markov.n_c, markov.rate) \
        < exact_ebt(stat.n_c, stat.rate)
    # the reported schedule carries the objective's OWN (exact) overhead
    assert markov.schedule.n_o == pytest.approx(
        exact_ebt(markov.n_c, markov.rate) - markov.n_c, rel=1e-12)
    assert markov.schedule.n_o > float(
        sc.effective_overhead(markov.n_c, markov.rate))


def test_markov_arq_fleet_matches_scalar():
    scs = _mixed_scenarios()
    G = 40
    fm = FleetPlanner(grid_size=G).plan_batch(
        ScenarioBatch.from_scenarios(scs), CONSTS,
        objective=MarkovARQObjective())
    assert fm.objective == "markov_arq"
    for i, sc in enumerate(scs):
        sp = ObjectivePlanner(objective=MarkovARQObjective(),
                              grid=fleet_grid(sc.N, G)).plan(sc, CONSTS)
        assert int(fm.n_c[i]) == sp.n_c and float(fm.rate[i]) == sp.rate
        assert np.isclose(float(fm.bound_value[i]), sp.bound_value,
                          rtol=1e-12)
        assert np.isclose(float(fm.n_o_eff[i]), sp.schedule.n_o,
                          rtol=1e-12)


# ---------------------------------------------------------------------------
# MonteCarloObjective: batched == scalar, seed-for-seed
# ---------------------------------------------------------------------------


def _assert_mc_plans_match(scs, objective, grid, tol=1e-5):
    fleet = FleetPlanner(grid_size=len(grid)).plan_batch(
        ScenarioBatch.from_scenarios(scs), CONSTS, grid=np.asarray(grid),
        objective=objective)
    assert fleet.objective == "montecarlo"
    for i, sc in enumerate(scs):
        scalar = MonteCarloPlanner(
            X=objective.X, y=objective.y, lam=objective.lam,
            alpha=objective.alpha, n_runs=objective.n_runs,
            seed=objective.seed, grid=grid).plan(sc, CONSTS)
        assert int(fleet.n_c[i]) == scalar.n_c, (i, sc)
        assert float(fleet.rate[i]) == scalar.rate, (i, sc)
        assert np.isclose(float(fleet.bound_value[i]), scalar.bound_value,
                          rtol=tol)
        np.testing.assert_allclose(np.asarray(fleet.bound_grid[i]),
                                   scalar.bound_grid, rtol=tol)


@pytest.mark.slow
def test_montecarlo_fleet_matches_scalar_planner_fixed_cases():
    """ISSUE acceptance: batched MC planning matches the scalar MC path
    seed-for-seed across links, topologies, and per-scenario deadlines."""
    X, y = _ridge_data()
    scs = [
        Scenario(N=128, T=200.0, n_o=8.0,
                 link=ErasureLink(beta=0.5, p_base=0.1, rates=(1.0, 2.0))),
        Scenario(N=128, T=150.0, n_o=4.0, tau_p=0.5),
        Scenario(N=128, T=180.0, n_o=12.0,
                 link=GilbertElliottLink(p_gb=0.1, p_bg=0.4, p_good=0.05,
                                         p_bad=0.5, beta=0.4,
                                         rates=(1.0, 1.5, 3.0)),
                 topology=MultiDevice(2)),
    ]
    objective = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=7)
    _assert_mc_plans_match(scs, objective, grid=[1, 4, 16, 64])


@pytest.mark.slow
def test_montecarlo_fleet_default_grid_capped():
    """With grid=None the fleet planner honours the MC objective's coarse
    default width (every grid point is a simulated training run) instead
    of the bound-sized ``grid_size`` default."""
    X, y = _ridge_data(n=64, d=4)
    obj = MonteCarloObjective(X=X, y=y, n_runs=2, grid_points=4)
    scs = [Scenario(N=64, T=100.0, n_o=4.0)]
    fp = FleetPlanner(grid_size=128).plan_batch(
        ScenarioBatch.from_scenarios(scs), CONSTS, objective=obj)
    assert fp.grid.shape == (1, 4)
    # an explicit grid and a smaller planner grid_size still win
    fp2 = FleetPlanner(grid_size=2).plan_batch(
        ScenarioBatch.from_scenarios(scs), CONSTS, objective=obj)
    assert fp2.grid.shape == (1, 2)


@pytest.mark.slow
def test_montecarlo_default_grid_and_planner_facade():
    X, y = _ridge_data(n=64, d=4)
    obj = MonteCarloObjective(X=X, y=y, n_runs=2, grid_points=4)
    grid = obj.default_grid(64)
    assert grid[0] == 1 and grid[-1] == 64 and len(grid) <= 4
    sc = Scenario(N=64, T=100.0, n_o=4.0)
    a = ObjectivePlanner(objective=obj).plan(sc)      # no consts needed
    b = MonteCarloPlanner(X=X, y=y, n_runs=2, grid_points=4).plan(sc)
    assert (a.n_c, a.rate) == (b.n_c, b.rate)
    assert a.objective == b.objective == "montecarlo"


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def _mc_scenario(draw):
        N = draw(st.sampled_from([64, 128]))
        T = draw(st.sampled_from([0.8, 1.3, 1.9])) * N
        n_o = draw(st.sampled_from([2.0, 8.0, 24.0]))
        tau_p = draw(st.sampled_from([0.5, 1.0]))
        link = draw(st.sampled_from([
            IdealLink(rates=(1.0, 2.0)),
            ErasureLink(beta=0.6, p_base=0.2, rates=(1.0, 2.0)),
            GilbertElliottLink(p_gb=0.08, p_bg=0.3, p_good=0.02, p_bad=0.7,
                               beta=0.5, rates=(1.0, 2.0)),
        ]))
        D = draw(st.sampled_from([1, 2]))
        topology = MultiDevice(D) if D > 1 else SingleDevice()
        return Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p, link=link,
                        topology=topology)

    _MC_X, _MC_Y = _ridge_data(n=128, d=5, seed=11)
    _MC_OBJECTIVE = MonteCarloObjective(X=_MC_X, y=_MC_Y, n_runs=2,
                                        alpha=1e-3, seed=3)

    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(scs=st.lists(_mc_scenario(), min_size=1, max_size=2))
    def test_montecarlo_batched_property_matches_scalar(scs):
        """ISSUE satellite: batched MonteCarloObjective plan == scalar
        MonteCarloPlanner plan for shared seeds on random scenarios."""
        _assert_mc_plans_match(scs, _MC_OBJECTIVE, grid=[2, 32])

    _ge_probs = st.floats(0.0, 0.9)

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(p=_ge_probs, p_gb=st.floats(0.01, 1.0), p_bg=st.floats(0.01, 1.0),
           beta=st.floats(0.0, 2.0), n_o=st.floats(0.0, 1500.0),
           tn=st.floats(0.5, 2.5))
    def test_markov_arq_property_reduces_to_stationary(p, p_gb, p_bg, beta,
                                                       n_o, tn):
        """ISSUE satellite: MarkovARQObjective == stationary-loss plan
        whenever p_good == p_bad, whatever the transition probabilities."""
        link = GilbertElliottLink(p_gb=p_gb, p_bg=p_bg, p_good=p, p_bad=p,
                                  beta=beta, rates=RATES5)
        sc = Scenario(N=4096, T=tn * 4096, n_o=n_o, link=link)
        a = BoundPlanner().plan(sc, CONSTS)
        m = ObjectivePlanner(objective=MarkovARQObjective()).plan(sc, CONSTS)
        assert (a.n_c, a.rate, a.bound_value) == (m.n_c, m.rate,
                                                  m.bound_value)
        # and the fleet kernel agrees bitwise with the bound kernel
        G = 24
        fa = FleetPlanner(grid_size=G).plan_batch([sc], CONSTS)
        fm = FleetPlanner(grid_size=G).plan_batch(
            [sc], CONSTS, objective=MarkovARQObjective())
        assert int(fa.n_c[0]) == int(fm.n_c[0])
        assert float(fa.bound_value[0]) == float(fm.bound_value[0])


# ---------------------------------------------------------------------------
# PlanCache: objectives can never alias one entry
# ---------------------------------------------------------------------------


def test_cache_never_shared_across_objectives():
    """ISSUE satellite: two objectives on the same scenario never share a
    cache entry (the objective token is part of the quantised key)."""
    cache = PlanCache(maxsize=64)
    planner = FleetPlanner(grid_size=24)
    X, y = _ridge_data(n=64, d=4)
    mc = MonteCarloObjective(X=X, y=y, n_runs=2)
    sc = Scenario(N=64, T=100.0, n_o=4.0,
                  link=ErasureLink(beta=0.5, rates=(1.0, 2.0)))
    objectives = [BoundObjective(), MarkovARQObjective(), mc]
    keys = {cache.key(sc, context=("ctx",), objective=o)
            for o in objectives}
    assert len(keys) == 3
    recs = [planner.plan_many([sc], CONSTS, cache=cache, objective=o)[0]
            for o in objectives]
    assert len(cache) == 3
    assert {r.objective for r in recs} == {"corollary1", "markov_arq",
                                           "montecarlo"}
    # replays hit their own entry and only their own
    for o, rec in zip(objectives, recs):
        assert planner.plan_many([sc], CONSTS, cache=cache,
                                 objective=o)[0] == rec
    assert len(cache) == 3
    # MC hyperparams scope entries too: a different seed count, and a
    # different grid_points (it sets the DEFAULT search grid, so the
    # cached record's n_c can differ)
    mc5 = MonteCarloObjective(X=X, y=y, n_runs=3)
    planner.plan_many([sc], CONSTS, cache=cache, objective=mc5)
    assert len(cache) == 4
    mc_coarse = MonteCarloObjective(X=X, y=y, n_runs=2, grid_points=4)
    assert objective_token(mc) != objective_token(mc_coarse)
    planner.plan_many([sc], CONSTS, cache=cache, objective=mc_coarse)
    assert len(cache) == 5


def test_cache_objective_scoping_on_sticky_chain_records_differ():
    cache = PlanCache(maxsize=16)
    planner = FleetPlanner(grid_size=64)
    a = planner.plan_many([STICKY_SC], CONSTS, cache=cache,
                          objective=BoundObjective())[0]
    b = planner.plan_many([STICKY_SC], CONSTS, cache=cache,
                          objective=MarkovARQObjective())[0]
    assert (a.n_c, a.rate) != (b.n_c, b.rate)
    assert a.objective == "corollary1" and b.objective == "markov_arq"


# ---------------------------------------------------------------------------
# plan server: mixed-objective streams
# ---------------------------------------------------------------------------


def test_serve_mixed_objective_stream():
    requests = synth_requests(48, seed=7, dup_frac=0.3, n_max=2048)
    catalogue = resolve_objectives(("corollary1", "markov_arq"))
    instances = list(catalogue.values())
    objectives = [instances[i % 2] for i in range(len(requests))]
    stats = serve(requests, planner=FleetPlanner(grid_size=16),
                  consts=default_consts(), cache=PlanCache(maxsize=256),
                  batch_size=16, objectives=objectives)
    assert len(stats.records) == 48
    assert stats.requests_per_objective == {"corollary1": 24,
                                            "markov_arq": 24}
    for i, (rec, obj) in enumerate(zip(stats.records, objectives)):
        assert rec.objective == obj.objective_id
        assert rec.n_c >= 1 and np.isfinite(rec.bound_value)
        sp = ObjectivePlanner(objective=obj,
                              grid=fleet_grid(requests[i].N, 16)
                              ).plan(requests[i], default_consts())
        assert (rec.n_c, rec.rate) == (sp.n_c, sp.rate) \
            or abs(rec.bound_value - sp.bound_value) \
            <= 1e-9 * abs(sp.bound_value)
    with pytest.raises(ValueError, match="one per request"):
        serve(requests, planner=FleetPlanner(), consts=default_consts(),
              objectives=[instances[0]])


def test_resolve_objectives_unknown_and_empty():
    with pytest.raises(ValueError, match="unregistered planning objective"):
        resolve_objectives("nope")
    with pytest.raises(ValueError, match="no planning objective"):
        resolve_objectives(())
    assert set(resolve_objectives("all")) == {"corollary1", "markov_arq",
                                              "montecarlo"}


def test_plan_server_cli_unknown_objective_exit_code():
    """ISSUE satellite: requesting an unregistered objective exits with a
    non-zero status and a clear error (matches the unknown-bench
    behaviour of benchmarks.run)."""
    from repro.launch import plan_server

    assert plan_server.main(["--objective", "nope", "--requests", "1"]) == 2


# ---------------------------------------------------------------------------
# custom objective plugin: scalar + fleet, end to end
# ---------------------------------------------------------------------------


def test_custom_objective_plugs_into_scalar_and_fleet_paths():
    """ISSUE tentpole: registering (numpy reference + grid value function)
    is ALL a new objective needs — the scalar planner minimises it, the
    shared grid kernel solves it batched next to the built-ins, and the
    cache keys it."""
    import jax.numpy as jnp  # noqa: F401  (grid kernel runs under jax)

    @dataclass(frozen=True)
    class ThroughputObjective:
        """Expected delivery time per sample — README's worked example."""

        objective_id: ClassVar[str] = "throughput"

        def evaluate(self, scenario, consts, grid, rates):
            grid = np.asarray(grid, np.float64)
            n_o_eff = self.effective_overhead(
                scenario, grid[None, :],
                np.asarray(rates, np.float64)[:, None])
            return (grid[None, :] + n_o_eff) / grid[None, :]

        def effective_overhead(self, scenario, n_c, rate):
            return scenario.effective_overhead(n_c, rate)

        def cache_token(self):
            return (self.objective_id,)

    register_objective(ThroughputObjective)
    register_objective_kernel(
        "throughput",
        grid_objective_builder(
            lambda g, N, T, n_o_eff, tau_p, sigma, e0, contraction:
                (g + n_o_eff) / g))
    try:
        obj = ThroughputObjective()
        scs = _mixed_scenarios()
        G = 24
        fp = FleetPlanner(grid_size=G).plan_batch(
            ScenarioBatch.from_scenarios(scs), CONSTS, objective=obj)
        assert fp.objective == "throughput"
        for i, sc in enumerate(scs):
            sp = ObjectivePlanner(objective=obj,
                                  grid=fleet_grid(sc.N, G)).plan(sc, CONSTS)
            assert int(fp.n_c[i]) == sp.n_c
            assert float(fp.rate[i]) == sp.rate
            assert np.isclose(float(fp.bound_value[i]), sp.bound_value,
                              rtol=1e-12)
        # throughput ignores the bound: it prefers the largest blocks
        assert int(fp.n_c[0]) == scs[0].N
        cache = PlanCache(maxsize=8)
        assert cache.key(scs[0], objective=obj) \
            != cache.key(scs[0], objective=BoundObjective())
    finally:
        unregister_objective_kernel("throughput")
        unregister_objective("throughput")
