"""Observability layer: log-spaced mergeable histograms, request spans,
the event journal, Prometheus render/parse round-trips, solve/trace
delta brackets, and the StatsRecorder throughput-baseline fix."""
import json
import math
import threading

import numpy as np
import pytest

from repro.fleet.tracing import record_trace, trace_delta
from repro.obs import (EventJournal, LogHistogram, Metric, MetricsRegistry,
                       RequestSpan, Reservoir, SpanRecorder, parse_exposition,
                       percentiles, read_jsonl, record_solve, solve_delta,
                       render_prometheus)
from repro.serve.stats import StatsRecorder


# ---------------------------------------------------------------------------
# LogHistogram
# ---------------------------------------------------------------------------

def test_histogram_percentile_accuracy_vs_exact():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-5.0, sigma=1.5, size=4000)
    h = LogHistogram(lo=1e-6, hi=1e3, per_decade=100)
    for s in samples:
        h.record(float(s))
    # bucket-interpolated percentiles within one bucket width (10^(1/100)
    # ~ 2.3%) of the exact sample percentiles
    width = 10.0 ** (1.0 / 100)
    for q in (10.0, 50.0, 90.0, 99.0):
        exact = float(np.percentile(samples, q))
        approx = h.percentile(q)
        assert exact / width <= approx <= exact * width, (q, exact, approx)
    assert h.percentile(100.0) == pytest.approx(float(samples.max()))
    assert h.count == 4000
    assert h.sum == pytest.approx(float(samples.sum()))


def test_histogram_empty_and_input_validation():
    h = LogHistogram()
    assert h.percentile(50.0) == 0.0
    with pytest.raises(ValueError):
        h.record(-1.0)
    with pytest.raises(ValueError):
        h.record(float("nan"))
    with pytest.raises(ValueError):
        h.percentile(101.0)
    with pytest.raises(ValueError):
        LogHistogram(lo=1.0, hi=0.5)


def test_histogram_under_and_overflow_buckets():
    h = LogHistogram(lo=1e-3, hi=1e0, per_decade=5)
    h.record(1e-6)          # underflow
    h.record(50.0)          # overflow
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.percentile(0.0) <= h.lo
    assert h.percentile(100.0) == 50.0
    cum = h.cumulative()
    assert math.isinf(cum[-1][0]) and cum[-1][1] == h.count == 2
    counts = [n for _, n in cum]
    assert counts == sorted(counts)  # cumulative is monotone


def test_histogram_merge_is_associative_and_matches_union():
    rng = np.random.default_rng(11)
    chunks = [rng.lognormal(-4.0, 1.0, size=200) for _ in range(3)]
    hists = []
    for chunk in chunks:
        h = LogHistogram(per_decade=20)
        for s in chunk:
            h.record(float(s))
        hists.append(h)
    a, b, c = hists
    left = a.copy().merge(b).merge(c)
    right = a.copy().merge(b.copy().merge(c))
    assert left.counts == right.counts
    assert left.count == right.count == 600
    assert left.sum == pytest.approx(right.sum)
    assert left.max == right.max
    # merge result is identical to recording the union into one histogram
    union = LogHistogram(per_decade=20)
    for s in np.concatenate(chunks):
        union.record(float(s))
    assert union.counts == left.counts
    assert LogHistogram.merged(hists).counts == left.counts
    assert LogHistogram.merged([]).count == 0


def test_histogram_merge_rejects_layout_mismatch():
    with pytest.raises(ValueError, match="different layouts"):
        LogHistogram(per_decade=10).merge(LogHistogram(per_decade=20))
    with pytest.raises(ValueError, match="different layouts"):
        LogHistogram(lo=1e-6).merge(LogHistogram(lo=1e-5))


def test_histogram_dict_round_trip():
    h = LogHistogram(lo=1e-5, hi=1e2, per_decade=30)
    for s in (1e-6, 3e-4, 0.02, 0.02, 7.0, 500.0):
        h.record(s)
    d = json.loads(json.dumps(h.to_dict()))   # must be JSON-serialisable
    back = LogHistogram.from_dict(d)
    assert back.counts == h.counts
    assert back.count == h.count
    assert back.sum == pytest.approx(h.sum)
    assert back.max == h.max
    assert back.percentile(99.0) == h.percentile(99.0)


# ---------------------------------------------------------------------------
# Reservoir
# ---------------------------------------------------------------------------

def test_reservoir_halving_keeps_percentiles_continuous():
    rng = np.random.default_rng(3)
    r = Reservoir(max_samples=1000)
    stream = rng.normal(100.0, 10.0, size=1000)
    for s in stream:
        r.record(float(s))
    p50_before, p99_before = r.percentiles()
    r.record(float(rng.normal(100.0, 10.0)))   # trips the halving
    assert len(r) == 501
    p50_after, p99_after = r.percentiles()
    # stationary stream: dropping the older half cannot jump percentiles
    assert p50_after == pytest.approx(p50_before, rel=0.05)
    assert p99_after == pytest.approx(p99_before, rel=0.05)


def test_reservoir_and_percentiles_edge_cases():
    assert percentiles([]) == (0.0, 0.0)
    assert percentiles([2.0], qs=(50.0,)) == (2.0,)
    with pytest.raises(ValueError):
        Reservoir(max_samples=0)
    r = Reservoir(max_samples=4)
    for i in range(6):
        r.record(i)
    assert r.samples == [2.0, 3.0, 4.0, 5.0]   # recent half survives


# ---------------------------------------------------------------------------
# SpanRecorder
# ---------------------------------------------------------------------------

def _span(i=0, batch_wait=0.004, solve=0.002, device=0.0015):
    return RequestSpan(objective="corollary1", grid_mode="dense", bucket=8,
                       enqueue_t=float(i), admit_s=1e-5,
                       batch_wait_s=batch_wait, pad_s=0.001,
                       cache_lookup_s=0.0005, solve_s=solve,
                       solve_device_s=device, resolve_s=0.0005,
                       latency_s=batch_wait + 0.001 + 0.0005 + solve + 0.0005)


def test_span_phases_partition_latency():
    s = _span()
    assert s.phase_sum == pytest.approx(s.latency_s)
    assert set(s.phases()) == {"batch_wait", "pad", "cache_lookup",
                               "solve", "resolve"}
    assert sum(s.phases().values()) == pytest.approx(s.latency_s)


def test_span_recorder_ring_evicts_but_totals_survive():
    rec = SpanRecorder(capacity=4)
    for i in range(10):
        rec.record(_span(i))
    assert len(rec) == 4
    assert rec.recorded == 10
    window = rec.snapshot()
    assert [s.enqueue_t for s in window] == [6.0, 7.0, 8.0, 9.0]
    totals = rec.totals()
    assert totals["count"] == 10                       # lifetime, not window
    assert totals["solve"] == pytest.approx(10 * 0.002)
    assert totals["solve_device"] == pytest.approx(10 * 0.0015)
    assert totals["latency"] == pytest.approx(10 * _span().latency_s)
    assert rec.solve_fraction == pytest.approx(
        totals["solve"] / totals["latency"])
    means = rec.phase_means_ms()
    assert means["solve"] == pytest.approx(2.0)        # 0.002 s -> 2 ms
    assert means["latency"] == pytest.approx(_span().latency_s * 1e3)


def test_span_recorder_empty_and_validation():
    rec = SpanRecorder(capacity=8)
    assert rec.solve_fraction == 0.0
    assert rec.phase_means_ms()["latency"] == 0.0
    assert rec.snapshot() == []
    with pytest.raises(ValueError):
        SpanRecorder(capacity=0)


# ---------------------------------------------------------------------------
# EventJournal + JSONL
# ---------------------------------------------------------------------------

def test_event_journal_ring_counts_and_file_sink(tmp_path):
    path = tmp_path / "events.jsonl"
    with EventJournal(capacity=3, path=str(path)) as journal:
        for i in range(5):
            journal.emit("drift_detected", session="dev-0", ewma=0.1 * i)
        journal.emit("warmup", traces=4)
    assert journal.emitted == 6
    assert journal.counts() == {"drift_detected": 5, "warmup": 1}
    tail = journal.tail(2)
    assert [e["kind"] for e in tail] == ["drift_detected", "warmup"]
    assert tail[-1]["traces"] == 4
    # the file keeps EVERY event (the ring only bounds memory), stamped
    # with a wall-clock ts
    events = read_jsonl(str(path))
    assert len(events) == 6
    assert all(e["ts"] > 0 for e in events)
    assert events[0]["ewma"] == 0.0
    # close() detached the sink; in-memory emission still works
    journal.emit("session_close", session="dev-0")
    assert journal.emitted == 7
    assert len(read_jsonl(str(path))) == 6


def test_read_jsonl_is_strict(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "ok"}\nnot json\n')
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        read_jsonl(str(path))


def test_event_journal_serialises_non_json_fields(tmp_path):
    path = tmp_path / "e.jsonl"
    journal = EventJournal(path=str(path))
    journal.emit("session_open", key=("corollary1", "dense", 8))
    journal.close()
    (event,) = read_jsonl(str(path))
    assert event["kind"] == "session_open"   # default=str made it through


def test_event_journal_rotates_by_size_and_reads_back_in_order(tmp_path):
    import os
    path = tmp_path / "rot.jsonl"
    # each event line is ~60 bytes: 2-3 events per rotated file
    with EventJournal(path=str(path), max_bytes=150, keep=2) as journal:
        for i in range(20):
            journal.emit("tick", i=i)
    assert journal.rotations > 1
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["rot.jsonl", "rot.jsonl.1", "rot.jsonl.2"]
    # keep=2 bounded the disk: older rotations were DROPPED...
    events = read_jsonl(str(path))
    assert len(events) < 20
    # ... and the survivors read back as one contiguous, ordered stream
    idx = [e["i"] for e in events]
    assert idx == list(range(idx[0], 20))
    assert os.path.getsize(path) < 150 + 80   # live file stays bounded


def test_event_journal_fsync_and_validation(tmp_path):
    path = tmp_path / "durable.jsonl"
    with EventJournal(path=str(path), fsync=True) as journal:
        journal.emit("decision", what="replan")
        # durable before emit returns: visible without close()/flush()
        assert read_jsonl(str(path)) == journal.tail(1)
    with pytest.raises(ValueError, match="max_bytes"):
        EventJournal(max_bytes=-1)
    with pytest.raises(ValueError, match="keep"):
        EventJournal(keep=0)


# ---------------------------------------------------------------------------
# Prometheus render / parse
# ---------------------------------------------------------------------------

def _families():
    hist = LogHistogram(lo=1e-3, hi=1e0, per_decade=3)
    for s in (0.002, 0.02, 0.02, 0.4, 9.0):
        hist.record(s)
    return [
        Metric("test_requests_total", "counter", "requests served")
        .add(12, objective="corollary1", grid_mode="dense")
        .add(30, objective="markov_arq", grid_mode="refine"),
        Metric("test_queue_depth", "gauge").add(3.5),
        Metric("test_latency_seconds", "histogram", "e2e latency").add(hist),
        Metric("test_weird_label_total", "counter")
        .add(1, note='quote " backslash \\ newline \n done'),
    ]


def test_prometheus_round_trip_preserves_every_sample():
    text = render_prometheus(_families())
    snap = parse_exposition(text)
    key = (("grid_mode", "dense"), ("objective", "corollary1"))
    assert snap["test_requests_total"][key] == 12
    assert snap["test_queue_depth"][()] == 3.5
    assert snap["test_latency_seconds_count"][()] == 5
    assert snap["test_latency_seconds_sum"][()] == pytest.approx(9.442)
    assert snap["test_latency_seconds_bucket"][(("le", "+Inf"),)] == 5
    # label escaping survives the round trip
    (labels,) = snap["test_weird_label_total"]
    assert dict(labels)["note"] == 'quote " backslash \\ newline \n done'
    # rendering is deterministic (textfile dumps must diff cleanly)
    assert text == render_prometheus(_families())


def test_parse_exposition_rejects_malformed_input():
    with pytest.raises(ValueError, match="malformed sample"):
        parse_exposition("no value here\n")
    with pytest.raises(ValueError, match="bad sample value"):
        parse_exposition("ok_metric twelve\n")
    with pytest.raises(ValueError, match="unknown metric type"):
        parse_exposition("# TYPE m summary\nm 1\n")
    with pytest.raises(ValueError, match="malformed labels"):
        parse_exposition('m{a="1", b=} 1\n')
    with pytest.raises(ValueError, match="no _bucket"):
        parse_exposition("# TYPE h histogram\nh_sum 1\nh_count 1\n")
    with pytest.raises(ValueError, match="missing _sum"):
        parse_exposition('# TYPE h histogram\nh_bucket{le="+Inf"} 1\n')
    with pytest.raises(ValueError, match="non-monotone"):
        parse_exposition('# TYPE h histogram\n'
                         'h_bucket{le="0.1"} 5\nh_bucket{le="+Inf"} 3\n'
                         'h_sum 1\nh_count 3\n')
    with pytest.raises(ValueError, match=r"lacks a \+Inf"):
        parse_exposition('# TYPE h histogram\nh_bucket{le="0.1"} 1\n'
                         'h_sum 1\nh_count 1\n')


def test_prometheus_client_cross_check():
    """When prometheus_client happens to be installed, its parser must
    agree with ours on our own output (we are not inventing a dialect)."""
    prom = pytest.importorskip("prometheus_client")
    from prometheus_client.parser import text_string_to_metric_families
    text = render_prometheus(_families())
    theirs = {}
    for fam in text_string_to_metric_families(text):
        for sample in fam.samples:
            labels = tuple(sorted(sample.labels.items()))
            theirs[(sample.name, labels)] = sample.value
    ours = parse_exposition(text)
    for name, series in ours.items():
        for labels, value in series.items():
            assert theirs[(name, labels)] == pytest.approx(value), name
    del prom


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_registry_merges_sources_and_snapshots():
    reg = MetricsRegistry()
    reg.register_source("a", lambda: [
        Metric("test_reg_total", "counter").add(2, src="a")])
    reg.register_source("b", lambda: [
        Metric("test_reg_total", "counter").add(3, src="b"),
        Metric("test_reg_gauge", "gauge").add(1.25)])
    assert reg.sources() == ["a", "b"]
    snap = reg.snapshot()
    assert snap["test_reg_total"][(("src", "a"),)] == 2
    assert snap["test_reg_total"][(("src", "b"),)] == 3
    assert reg.value("test_reg_total", src="b") == 3
    assert reg.value("test_reg_gauge") == 1.25
    assert reg.value("test_reg_missing", default=-1.0) == -1.0
    reg.unregister_source("a")
    assert (("src", "a"),) not in reg.snapshot().get("test_reg_total", {})
    with pytest.raises(KeyError):
        reg.unregister_source("a")
    with pytest.raises(ValueError, match="already registered"):
        reg.register_source("b", list)


def test_registry_rejects_kind_conflicts():
    reg = MetricsRegistry()
    reg.register_source("a", lambda: [Metric("test_x", "counter").add(1)])
    reg.register_source("b", lambda: [Metric("test_x", "gauge").add(2)])
    with pytest.raises(ValueError, match="both"):
        reg.collect()


def test_registry_write_textfile_is_parseable(tmp_path):
    reg = MetricsRegistry()
    reg.register_source("s", lambda: [
        Metric("test_file_total", "counter").add(7)])
    path = tmp_path / "metrics.prom"
    text = reg.write_textfile(str(path))
    assert path.read_text() == text
    assert parse_exposition(path.read_text())["test_file_total"][()] == 7
    assert not list(tmp_path.glob("*.tmp.*"))   # atomic rename cleaned up


# ---------------------------------------------------------------------------
# solve_delta / trace_delta brackets
# ---------------------------------------------------------------------------

def test_solve_delta_is_per_thread():
    noise_done = threading.Event()

    def other_thread():
        record_solve(100.0, 50.0)   # must NOT leak into our delta
        noise_done.set()

    with solve_delta() as delta:
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
        assert noise_done.wait(5.0)
        record_solve(0.25, 0.05)
        record_solve(0.75)
    assert delta.calls == 2
    assert delta.device_s == pytest.approx(1.0)
    assert delta.host_s == pytest.approx(0.05)
    assert delta.total_s == pytest.approx(1.05)


def test_record_solve_clamps_negative_durations():
    with solve_delta() as delta:
        record_solve(-1.0, -2.0)
    assert delta.calls == 1
    assert delta.device_s == 0.0 and delta.host_s == 0.0


def test_trace_delta_counts_only_inner_traces():
    record_trace(("test_obs_outer", 1))
    with trace_delta() as d:
        record_trace(("test_obs_inner", 8))
        record_trace(("test_obs_inner", 8))
        record_trace(("test_obs_other", 16))
    assert d.total == 3
    assert bool(d) is True
    assert d.by_tag == {("test_obs_inner", 8): 2, ("test_obs_other", 16): 1}
    with trace_delta() as empty:
        pass
    assert empty.total == 0 and not empty.by_tag and bool(empty) is False


# ---------------------------------------------------------------------------
# StatsRecorder: histogram percentiles, restart baseline, thread-safety
# ---------------------------------------------------------------------------

def test_stats_recorder_restart_clock_resets_throughput_baseline():
    rec = StatsRecorder()
    for _ in range(5):
        rec.count("planned")
        rec.record_latency(0.01)
    assert rec.snapshot().plans_per_sec > 0.0
    # the satellite fix: restarting the clock must also re-baseline the
    # planned counter, else 5 pre-restart plans divided by a microsecond
    # of post-restart uptime reports absurd throughput
    rec.restart_clock()
    snap = rec.snapshot()
    assert snap.plans_per_sec == 0.0
    assert snap.n_planned == 5            # lifetime counter is untouched
    rec.count("planned", 3)
    assert rec.snapshot().plans_per_sec > 0.0


def test_stats_recorder_per_key_histograms_roll_up():
    rec = StatsRecorder()
    k1, k2 = ("corollary1", "dense", 8), ("markov_arq", "refine", 16)
    for i in range(10):
        rec.record_latency(0.001 * (i + 1), key=k1 if i % 2 else k2)
    hists = rec.latency_histograms()
    assert set(hists) == {None, k1, k2}
    merged = hists[k1].copy().merge(hists[k2])
    assert merged.counts == hists[None].counts   # per-key sums to global
    snap = rec.snapshot()
    assert set(snap.histograms) == {"corollary1/dense/8",
                                    "markov_arq/refine/16"}
    back = LogHistogram.from_dict(snap.latency_hist)
    assert back.count == 10
    assert snap.latency_p99_ms >= snap.latency_p50_ms > 0.0
    assert snap.latency_max_ms == pytest.approx(10.0)


def test_stats_recorder_concurrent_record_and_snapshot():
    rec = StatsRecorder()
    stop = threading.Event()
    errors = []

    def writer(tid):
        try:
            for i in range(2000):
                rec.record_latency(1e-4 * (i % 50 + 1),
                                   key=("corollary1", "dense", 4))
                rec.count("planned")
        except Exception as e:            # pragma: no cover
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                snap = rec.snapshot()
                assert snap.latency_p99_ms >= 0.0
                rec.latency_histograms()
        except Exception as e:            # pragma: no cover
            errors.append(e)

    writers = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    r = threading.Thread(target=reader)
    r.start()
    for w in writers:
        w.start()
    for w in writers:
        w.join()
    stop.set()
    r.join()
    assert not errors
    snap = rec.snapshot()
    assert snap.n_planned == 8000
    hist = LogHistogram.from_dict(snap.latency_hist)
    assert hist.count == 8000             # no lost updates
