"""Per-architecture smoke tests: REDUCED variants (2 layers, d_model<=256,
<=4 experts) run one train step + one decode step on CPU, asserting output
shapes and finiteness — the same code path the full configs lower in the
multi-pod dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import InputShape
from repro.models import (init_params, make_batch, make_decode_step,
                          make_prefill_step, make_train_step)
from repro.models.decode import init_cache
from repro.optim.optimizers import make_optimizer

SMOKE_TRAIN = InputShape("smoke_train", 64, 2, "train")
SMOKE_DECODE = InputShape("smoke_decode", 96, 2, "decode")


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = init_params(cfg, seed=0)
    return cfg, params


def test_train_step(arch_setup):
    cfg, params = arch_setup
    opt = make_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, grad_accum=2))
    batch = make_batch(cfg, SMOKE_TRAIN, jax.random.PRNGKey(1))
    params2, opt_state, metrics = step(params, opt_state,
                                       jnp.zeros((), jnp.int32), batch)
    assert jnp.isfinite(metrics["loss"]), cfg.name
    # params actually changed
    leaves_before = jax.tree.leaves(params)
    leaves_after = jax.tree.leaves(params2)
    changed = any(bool(jnp.any(a != b)) for a, b in
                  zip(leaves_before, leaves_after))
    assert changed, cfg.name


def test_prefill_step(arch_setup):
    cfg, params = arch_setup
    prefill = jax.jit(make_prefill_step(cfg))
    batch = make_batch(cfg, SMOKE_TRAIN, jax.random.PRNGKey(2))
    logits, caches = prefill(params, batch)
    assert logits.shape == (SMOKE_TRAIN.global_batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), cfg.name


def test_decode_step(arch_setup):
    cfg, params = arch_setup
    step = jax.jit(make_decode_step(cfg, SMOKE_DECODE))
    cache = init_cache(cfg, SMOKE_DECODE)
    batch = {"token": jnp.ones((SMOKE_DECODE.global_batch, 1), jnp.int32),
             "pos": jnp.asarray(SMOKE_DECODE.seq_len - 1, jnp.int32)}
    logits, new_cache = step(params, cache, batch)
    assert logits.shape == (SMOKE_DECODE.global_batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), cfg.name
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_long_context_decode_variants():
    """Ring-cache decode for the archs that run long_500k."""
    long_small = InputShape("long_small", 2048, 1, "decode")
    for arch in ("mamba2-780m", "zamba2-1.2b", "mixtral-8x7b", "gemma2-9b"):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, seed=0)
        step = jax.jit(make_decode_step(cfg, long_small))
        cache = init_cache(cfg, long_small)
        batch = {"token": jnp.ones((1, 1), jnp.int32),
                 "pos": jnp.asarray(long_small.seq_len - 1, jnp.int32)}
        logits, _ = step(params, cache, batch)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
