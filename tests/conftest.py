import os

# Smoke tests and benches must see the single real CPU device — the 512-way
# device-count override belongs ONLY to repro.launch.dryrun (see the system
# design notes).  Keep threads modest on the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
