"""Unified Scenario/Planner/Simulator API: equivalence with the legacy
entry points, the vectorised joint (n_c, rate) sweep, and the previously
inexpressible erasure-channel x multi-device cross product."""
import numpy as np
import pytest

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import (BoundConstants, BoundPlanner, ErasureLink, IdealLink,
                        MonteCarloPlanner, MultiDevice, Plan, RidgeTask,
                        Scenario, SimReport, Simulator, SingleDevice,
                        StreamingTask, optimize_block_size)
from repro.core.bounds import corollary1_bound
from repro.core.channel import ErasureChannel, plan_with_channel
from repro.core.multidevice import plan_multi_device
from repro.core.planner import default_grid
from repro.core.protocol import BlockSchedule
from repro.data.synthetic import make_regression_dataset

CONSTS = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=1.0, alpha=EP.alpha)
N, T = EP.n_samples, 1.5 * EP.n_samples


# ---------------------------------------------------------------------------
# equivalence with the legacy planners
# ---------------------------------------------------------------------------


def test_bound_planner_reproduces_optimize_block_size_exactly():
    """BoundPlanner on IdealLink/SingleDevice == the seed planner: same
    grid, same bound values (bitwise), same chosen n_c."""
    grid = default_grid(N)
    for n_o in (10.0, 500.0, 5000.0):
        vals = corollary1_bound(grid, N=N, T=T, n_o=n_o, tau_p=1.0,
                                consts=CONSTS)
        i = int(np.argmin(vals))
        plan = BoundPlanner().plan(Scenario(N=N, T=T, n_o=n_o), CONSTS)
        assert plan.n_c == int(grid[i])
        assert plan.bound_value == float(vals[i])
        np.testing.assert_array_equal(plan.bound_grid, vals)
        # and the compatibility wrapper goes through the same path
        legacy = optimize_block_size(N=N, T=T, n_o=n_o, tau_p=1.0,
                                     consts=CONSTS)
        assert legacy.n_c == plan.n_c
        assert legacy.bound_value == plan.bound_value
        assert legacy.boundary == plan.boundary
        assert legacy.full_transfer == plan.full_transfer


def test_vectorised_joint_search_matches_seed_loop():
    """The broadcast (n_c, rate) sweep picks the same (n_c, rate, bound)
    as the seed per-grid-point Python loop."""
    channel = ErasureChannel(beta=0.4)
    rates = (1.0, 1.25, 1.5, 2.0, 3.0)
    grid = default_grid(N)
    n_o = 500.0
    best = None
    for rate in rates:  # the seed implementation, verbatim
        p = channel.p_err(rate)
        dur = (grid / rate + n_o) / (1.0 - p)
        n_o_eff = dur - grid
        vals = np.array([
            corollary1_bound(np.asarray([nc]), N=N, T=T, n_o=float(no),
                             tau_p=1.0, consts=CONSTS)[0]
            for nc, no in zip(grid, n_o_eff)
        ])
        i = int(np.argmin(vals))
        cand = (float(vals[i]), int(grid[i]), float(rate), float(p))
        if best is None or cand[0] < best[0]:
            best = cand
    out = plan_with_channel(N=N, T=T, n_o=n_o, tau_p=1.0, consts=CONSTS,
                            channel=channel, rates=rates)
    assert out["n_c"] == best[1]
    assert out["rate"] == best[2]
    assert out["bound"] == pytest.approx(best[0], rel=1e-12)
    assert out["p_err"] == pytest.approx(best[3], rel=1e-12)


def test_corollary1_accepts_array_n_o():
    """Array n_o broadcasts exactly like repeated scalar calls."""
    grid = np.array([16, 64, 256, 1024], np.float64)
    n_os = np.array([10.0, 100.0, 300.0, 900.0])
    batched = corollary1_bound(grid, N=N, T=T, n_o=n_os, tau_p=1.0,
                               consts=CONSTS)
    pointwise = np.array([
        corollary1_bound(np.asarray([nc]), N=N, T=T, n_o=float(no),
                         tau_p=1.0, consts=CONSTS)[0]
        for nc, no in zip(grid, n_os)
    ])
    np.testing.assert_array_equal(batched, pointwise)


def test_multi_device_wrapper_matches_scenario_plan():
    out = plan_multi_device(n_devices=4, samples_per_device=N // 4, T=T,
                            n_o=100.0, tau_p=1.0, consts=CONSTS)
    plan = BoundPlanner().plan(
        Scenario(N=N, T=T, n_o=100.0, topology=MultiDevice(4)), CONSTS)
    assert out["n_c_union"] == plan.n_c
    assert out["n_c_per_device"] == plan.n_c_per_device
    assert out["bound"] == plan.bound_value
    assert plan.n_c_per_device == max(1, plan.n_c // 4)


# ---------------------------------------------------------------------------
# cross-product scenarios (previously inexpressible)
# ---------------------------------------------------------------------------


def test_erasure_times_multidevice_end_to_end():
    """A single Scenario composes ErasureLink x MultiDevice and plans +
    simulates through the unified facade."""
    X, y, _ = make_regression_dataset(n=2048, d=8, seed=2)
    scenario = Scenario(N=2048, T=1.5 * 2048, n_o=20.0,
                        link=ErasureLink(beta=0.4, rates=(1.0, 1.5, 2.0)),
                        topology=MultiDevice(4))
    plan = BoundPlanner().plan(scenario, CONSTS)
    assert isinstance(plan, Plan)
    assert 1 <= plan.n_c <= 2048
    assert plan.rate in (1.0, 1.5, 2.0)
    assert 0.0 <= plan.p_err < 1.0
    assert plan.n_c_per_device == max(1, plan.n_c // 4)
    assert np.isfinite(plan.bound_value)
    # the joint search can never do worse than forcing rate = 1
    forced = BoundPlanner().plan(
        Scenario(N=2048, T=1.5 * 2048, n_o=20.0,
                 link=ErasureLink(beta=0.4, rates=(1.0,)),
                 topology=MultiDevice(4)), CONSTS)
    assert plan.bound_value <= forced.bound_value + 1e-12

    report = Simulator().run(scenario, plan, RidgeTask(X=X, y=y, alpha=1e-3))
    assert isinstance(report, SimReport)
    assert np.isfinite(report.final_loss)
    assert 0 < report.delivered <= 2048
    # lossy link -> a realised ARQ delivery timeline is attached
    assert report.arq_times is not None and report.arq_counts is not None
    assert (np.diff(report.arq_counts) >= 0).all()
    # effective block duration reflects both the TDMA union (D n_o) and
    # the ARQ inflation 1/(1-p) over the lossless duration at that rate
    assert report.schedule.n_o == pytest.approx(
        float(scenario.effective_overhead(plan.n_c, plan.rate)))
    lossless = plan.n_c / plan.rate + 4 * 20.0
    block_time = plan.n_c + report.schedule.n_o
    assert block_time == pytest.approx(lossless / (1.0 - plan.p_err))
    if plan.p_err > 0:
        assert block_time > lossless


def test_noisier_link_never_improves_bound():
    base = BoundPlanner().plan(
        Scenario(N=N, T=T, n_o=500.0, link=ErasureLink(beta=0.4)), CONSTS)
    noisy = BoundPlanner().plan(
        Scenario(N=N, T=T, n_o=500.0,
                 link=ErasureLink(beta=0.4, p_base=0.3)), CONSTS)
    assert noisy.bound_value >= base.bound_value - 1e-12


def test_ideal_single_device_defaults():
    sc = Scenario(N=N, T=T, n_o=100.0)
    assert isinstance(sc.link, IdealLink)
    assert isinstance(sc.topology, SingleDevice)
    assert sc.n_devices == 1
    assert float(sc.effective_overhead(128)) == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# link / scenario edge cases (regression tests)
# ---------------------------------------------------------------------------


def test_link_and_scenario_validation():
    """Nonsense parameters raise instead of silently producing inf/garbage
    (rate 0 used to emit a divide-by-zero inf block time; p_base >= 1 was
    masked by the p_err cap)."""
    for bad in (0.0, -1.0):
        with pytest.raises(ValueError):
            ErasureLink(rates=(bad,))
        with pytest.raises(ValueError):
            IdealLink(rates=(1.0, bad))
    with pytest.raises(ValueError):
        ErasureLink(rates=())
    with pytest.raises(ValueError):
        ErasureLink(p_base=1.0)
    with pytest.raises(ValueError):
        ErasureLink(beta=-0.1)
    for kw in (dict(N=0, T=10.0, n_o=1.0), dict(N=10, T=0.0, n_o=1.0),
               dict(N=10, T=10.0, n_o=-1.0),
               dict(N=10, T=10.0, n_o=1.0, tau_p=0.0)):
        with pytest.raises(ValueError):
            Scenario(**kw)
    with pytest.raises(ValueError):
        Scenario(N=64, T=96.0, n_o=1.0).effective_overhead(8, rate=0.0)


def test_p_err_below_nominal_rate_and_at_clamp():
    """Rates below 1 are never MORE reliable than nominal (no negative
    probabilities), and the 0.999 cap keeps the ARQ inflation finite."""
    from repro.core.scenario import P_ERR_MAX

    link = ErasureLink(beta=0.4, p_base=0.2, rates=(0.5, 1.0, 3.0))
    assert float(link.p_err(0.5)) == pytest.approx(0.2)   # == p_base
    assert float(link.p_err(0.25)) == float(link.p_err(1.0))
    extreme = ErasureLink(beta=50.0)
    assert float(extreme.p_err(3.0)) == P_ERR_MAX
    block = float(extreme.expected_block_time(100, 10.0, 3.0))
    assert np.isfinite(block)
    assert block == pytest.approx((100 / 3.0 + 10.0) / (1.0 - P_ERR_MAX))
    # legacy channel shares the same cap
    assert ErasureChannel(beta=50.0).p_err(3.0) == P_ERR_MAX


def test_negative_effective_overhead_plans_cleanly():
    """A fast lossless rate makes n_o_eff negative; the plan stays finite
    and the regime boundary clamps to 0 (it used to go negative)."""
    sc = Scenario(N=1000, T=1500.0, n_o=1.0,
                  link=ErasureLink(beta=0.0, rates=(1.0, 4.0)))
    n_o_eff = float(sc.effective_overhead(500, 4.0))
    assert n_o_eff < 0.0
    assert 500 + n_o_eff > 0.0            # block duration stays positive
    plan = BoundPlanner().plan(sc, CONSTS)
    assert np.isfinite(plan.bound_value)
    assert plan.boundary >= 0.0
    val = corollary1_bound(np.asarray([500.0]), N=1000, T=1500.0,
                           n_o=n_o_eff, tau_p=1.0, consts=CONSTS)[0]
    assert np.isfinite(val) and val > 0


def test_boundary_n_c_edges():
    from repro.core.protocol import boundary_n_c

    assert boundary_n_c(1000, 1500.0, 0.0) == 0.0
    assert boundary_n_c(1000, 1500.0, -5.0) == 0.0   # negative n_o_eff
    assert boundary_n_c(1000, 1000.0, 10.0) == np.inf
    assert boundary_n_c(1000, 800.0, 10.0) == np.inf
    assert boundary_n_c(1000, 1500.0, 10.0) == pytest.approx(20.0)


def test_bound_continuous_at_regime_boundary():
    """At n_c == boundary_n_c (integer B_d) eq. 14 at B == B_d equals
    eq. 15 at tau_l == 0: the strict-inequality regime split is continuous
    at the equality.  Just BELOW the boundary the bound steps up because a
    whole block no longer completes (floor in B) — inherent to the
    published formula, so assert monotonicity there, not continuity."""
    N_, n_o = 1000, 10.0
    T_ = 1500.0
    nc = 20.0                    # boundary: B_d = 50 blocks exactly fill T
    at = corollary1_bound(np.asarray([nc]), N=N_, T=T_, n_o=n_o,
                          tau_p=1.0, consts=CONSTS)[0]
    above = corollary1_bound(np.asarray([nc]), N=N_, T=T_ + 1e-9, n_o=n_o,
                             tau_p=1.0, consts=CONSTS)[0]
    below = corollary1_bound(np.asarray([nc]), N=N_, T=T_ - 1e-9, n_o=n_o,
                             tau_p=1.0, consts=CONSTS)[0]
    assert at == pytest.approx(above, rel=1e-9)
    assert below >= at           # less time can never improve the bound
    # the schedule's delivered-count flag agrees with the bound's regime
    # reading at the exact boundary (whole set delivered at exactly T)
    sched = BlockSchedule(N=N_, n_c=20, n_o=n_o, T=T_, tau_p=1.0)
    assert sched.full_transfer


# ---------------------------------------------------------------------------
# simulator dispatch
# ---------------------------------------------------------------------------


def test_simulator_ridge_matches_run_pipelined_sgd():
    from repro.core.pipeline import run_pipelined_sgd

    X, y, _ = make_regression_dataset(n=2048, d=8, seed=1)
    sc = Scenario(N=2048, T=1.5 * 2048, n_o=32.0)
    plan = BoundPlanner().plan(sc, CONSTS)
    report = Simulator().run(sc, plan, RidgeTask(X=X, y=y, alpha=1e-3))
    ref = run_pipelined_sgd(X, y, n_c=plan.n_c, n_o=32.0, T=1.5 * 2048,
                            alpha=1e-3)
    assert report.final_loss == ref.final_loss
    assert report.delivered == ref.delivered
    np.testing.assert_array_equal(report.w_final, ref.w_final)
    assert report.arq_times is None  # ideal link: no ARQ timeline


def test_simulator_streaming_task():
    """The generic trainer composes with any scenario (here: multi-device)
    through the same facade."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((64, 4)).astype(np.float32)

    def train_step(params, opt_state, step, batch):
        x = batch["x"]
        loss = float(np.mean((x @ params) ** 2))
        return params * 0.99, opt_state, {"loss": loss}

    sc = Scenario(N=64, T=48.0, n_o=2.0, topology=MultiDevice(2))
    plan = BoundPlanner(grid=[4, 8, 16]).plan(sc, CONSTS)
    task = StreamingTask(train_step=train_step,
                         params=np.ones(4, np.float32), opt_state=None,
                         dataset=data, batch_size=4,
                         make_batch=lambda tok: {"x": tok}, log_every=1)
    report = Simulator().run(sc, plan, task)
    assert report.history, "streaming run produced no update log"
    assert report.delivered > 0
    assert np.isfinite(report.final_loss)


def test_simulator_rejects_unknown_task():
    sc = Scenario(N=64, T=48.0, n_o=2.0)
    plan = BoundPlanner(grid=[8]).plan(sc, CONSTS)
    with pytest.raises(TypeError):
        Simulator().run(sc, plan, object())


# ---------------------------------------------------------------------------
# Monte-Carlo planner (vmapped seeds)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_montecarlo_planner_returns_plan():
    X, y, _ = make_regression_dataset(n=2048, d=8, seed=3)
    planner = MonteCarloPlanner(X=X, y=y, alpha=1e-3, n_runs=2,
                                grid=[64, 256, 1024])
    plan = planner.plan(Scenario(N=2048, T=1.5 * 2048, n_o=200.0))
    assert isinstance(plan, Plan)
    assert plan.objective == "montecarlo"
    assert plan.n_c in (64, 256, 1024)
    assert plan.bound_value == float(np.min(plan.bound_grid))


def test_average_final_loss_vmap_matches_seed_loop():
    """The vmapped MC seed loop matches a sequential per-run loop under
    BOTH seed streams: the collision-free fold_in default (per-run keys
    from mc_run_key) and the legacy compat mode, which must still
    reproduce the historical seed + 97r runs bit-for-bit."""
    from repro.core.pipeline import (average_final_loss, mc_run_key,
                                     run_pipelined_sgd)

    X, y, _ = make_regression_dataset(n=1024, d=8, seed=4)
    ref = np.mean([
        run_pipelined_sgd(X, y, n_c=64, n_o=16.0, T=1.5 * 1024, alpha=1e-3,
                          lam=0.05, key=mc_run_key(5, r)).final_loss
        for r in range(3)
    ])
    got = average_final_loss(X, y, n_c=64, n_o=16.0, T=1.5 * 1024, n_runs=3,
                             alpha=1e-3, lam=0.05, seed=5)
    assert got == pytest.approx(float(ref), rel=1e-5)

    legacy_ref = np.mean([
        run_pipelined_sgd(X, y, n_c=64, n_o=16.0, T=1.5 * 1024, alpha=1e-3,
                          lam=0.05, seed=5 + 97 * r).final_loss
        for r in range(3)
    ])
    legacy = average_final_loss(X, y, n_c=64, n_o=16.0, T=1.5 * 1024,
                                n_runs=3, alpha=1e-3, lam=0.05, seed=5,
                                seed_stream="legacy")
    assert legacy == pytest.approx(float(legacy_ref), rel=1e-5)
    assert legacy != got        # the streams really are different
