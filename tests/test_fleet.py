"""Fleet planning engine: ScenarioBatch round-trips, batched == scalar plan
equivalence (fixed cases + hypothesis property), the jnp bound port's
lockstep with the numpy reference, PlanCache semantics, plan_many dedup,
the micro-batching server, and the NamedSharding path (subprocess with a
forced multi-device host platform)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (BoundConstants, BoundPlanner, ErasureLink, FadingLink,
                        GilbertElliottLink, IdealLink, MultiDevice, Scenario,
                        SingleDevice)
from repro.core.bounds import corollary1_bound
from repro.core.planner import fleet_grid
from repro.fleet import (FleetPlanner, PlanCache, ScenarioBatch,
                         corollary1_bound_jax, scenario_key)
from repro.launch.plan_server import (ALL_MODELS, default_consts, serve,
                                      synth_requests)

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)
RATES5 = (1.0, 1.25, 1.5, 2.0, 3.0)


def _mixed_scenarios():
    """A deterministic batch covering every registered link model (ideal,
    erasure, fading, Gilbert-Elliott) x topology cross product, ragged rate
    sets, and both regimes — the ISSUE-3 acceptance population."""
    return [
        Scenario(N=2048, T=1.5 * 2048, n_o=100.0),
        Scenario(N=18576, T=1.2 * 18576, n_o=500.0,
                 link=ErasureLink(beta=0.4, rates=RATES5)),
        Scenario(N=512, T=0.8 * 512, n_o=10.0, tau_p=2.0,
                 link=ErasureLink(beta=1.0, p_base=0.3, rates=(1.0, 2.0))),
        Scenario(N=4096, T=2.5 * 4096, n_o=50.0,
                 link=ErasureLink(beta=0.0, rates=(1.0, 4.0)),  # lossless fast
                 topology=MultiDevice(4)),
        Scenario(N=100, T=130.0, n_o=1.0, tau_p=0.5,
                 link=IdealLink(rates=(1.0, 1.5)), topology=MultiDevice(8)),
        Scenario(N=30000, T=1.1 * 30000, n_o=2000.0,
                 link=ErasureLink(beta=1.5, p_base=0.5, rates=RATES5),
                 topology=MultiDevice(2)),
        Scenario(N=4096, T=1.4 * 4096, n_o=200.0,
                 link=FadingLink(snr=8.0, rates=RATES5)),
        Scenario(N=1024, T=0.9 * 1024, n_o=20.0, tau_p=2.0,
                 link=FadingLink(snr=2.5, rates=(1.0, 1.5)),
                 topology=MultiDevice(4)),
        Scenario(N=8192, T=1.3 * 8192, n_o=300.0,
                 link=GilbertElliottLink(p_gb=0.1, p_bg=0.6, p_good=0.05,
                                         p_bad=0.6, beta=0.3, rates=RATES5),
                 topology=MultiDevice(2)),
        Scenario(N=20000, T=2.0 * 20000, n_o=1000.0,
                 link=GilbertElliottLink(p_gb=0.4, p_bg=0.1, p_good=0.0,
                                         p_bad=0.85, beta=0.0,
                                         rates=(1.0, 2.0, 3.0))),
    ]


def _assert_record_matches_scalar(sc, n_c, rate, bound, consts, grid_size):
    """Batched pick == scalar pick, or (on an argmin tie at float64
    resolution) scalar-near-optimal at the batched pick."""
    sp = BoundPlanner(grid=fleet_grid(sc.N, grid_size)).plan(sc, consts)
    assert np.isclose(bound, sp.bound_value, rtol=1e-9, atol=0.0), \
        (sc, bound, sp.bound_value)
    if int(n_c) == sp.n_c and float(rate) == sp.rate:
        return
    # tie fallback: evaluate the SCALAR objective at the batched choice
    n_o_eff = float(sc.effective_overhead(int(n_c), float(rate)))
    at_pick = float(corollary1_bound(
        np.asarray([float(n_c)]), N=sc.N, T=sc.T, n_o=n_o_eff,
        tau_p=sc.tau_p, consts=consts)[0])
    assert at_pick <= sp.bound_value * (1.0 + 1e-9), \
        f"batched pick (n_c={n_c}, rate={rate}) not scalar-optimal: " \
        f"{at_pick} vs {sp.bound_value}"


# ---------------------------------------------------------------------------
# ScenarioBatch round-trip
# ---------------------------------------------------------------------------


def test_scenario_batch_round_trip():
    scs = _mixed_scenarios()
    batch = ScenarioBatch.from_scenarios(scs)
    assert len(batch) == len(scs)
    assert batch.n_rates == 5
    for i, sc in enumerate(scs):
        assert batch[i] == sc
    assert batch.scenarios() == scs
    # padded rate columns are masked, never argmin candidates
    assert batch.rate_mask[0].sum() == 1      # IdealLink default (1.0,)
    assert batch.rate_mask[2].sum() == 2
    np.testing.assert_array_equal(batch.union_overhead,
                                  [100.0, 500.0, 10.0, 200.0, 8.0, 4000.0,
                                   200.0, 80.0, 600.0, 1000.0])
    # the registry flattening: ids follow the class table, params are the
    # packed vectors right-padded with zeros
    np.testing.assert_array_equal(batch.link_model_id,
                                  [0, 1, 1, 1, 0, 1, 2, 2, 3, 3])
    np.testing.assert_array_equal(batch.link_params[0], 0.0)     # ideal
    np.testing.assert_array_equal(batch.link_params[1][:2], [0.4, 0.0])
    np.testing.assert_array_equal(batch.link_params[6][:1], [8.0])
    np.testing.assert_array_equal(batch.link_params[8][:5],
                                  [0.3, 0.05, 0.6, 0.1, 0.6])
    np.testing.assert_array_equal(batch.link_params[8][5:], 0.0)  # padding


def test_scenario_batch_multidevice_one_normalises_to_single():
    sc = Scenario(N=64, T=96.0, n_o=1.0, topology=MultiDevice(1))
    back = ScenarioBatch.from_scenarios([sc])[0]
    assert back.topology == SingleDevice()
    assert back.N == sc.N and back.T == sc.T


def test_scenario_batch_rejects_empty_and_unknown_link():
    with pytest.raises(ValueError):
        ScenarioBatch.from_scenarios([])

    class WeirdLink:
        rates = (1.0,)

    with pytest.raises(TypeError):
        ScenarioBatch.from_scenarios(
            [Scenario(N=8, T=12.0, n_o=1.0, link=WeirdLink())])


# ---------------------------------------------------------------------------
# batched == scalar equivalence
# ---------------------------------------------------------------------------


def test_plan_batch_matches_scalar_planner_fixed_cases():
    scs = _mixed_scenarios()
    batch = ScenarioBatch.from_scenarios(scs)
    G = 40
    fp = FleetPlanner(grid_size=G).plan_batch(batch, CONSTS)
    assert len(fp) == len(scs)
    for i, sc in enumerate(scs):
        sp = BoundPlanner(grid=fleet_grid(sc.N, G)).plan(sc, CONSTS)
        assert int(fp.n_c[i]) == sp.n_c
        assert float(fp.rate[i]) == sp.rate
        assert np.isclose(fp.bound_value[i], sp.bound_value, rtol=1e-12)
        assert np.isclose(fp.p_err[i], sp.p_err, rtol=1e-12, atol=1e-300)
        assert bool(fp.full_transfer[i]) == sp.full_transfer
        assert int(fp.n_c_per_device[i]) == sp.n_c_per_device
        b1, b2 = sp.boundary, float(fp.boundary[i])
        assert (np.isinf(b1) and np.isinf(b2)) or np.isclose(b1, b2,
                                                             rtol=1e-12)
        # full Plan materialisation carries the whole grid across
        plan = fp.to_plan(batch, i)
        assert plan.n_c == sp.n_c and plan.rate == sp.rate
        np.testing.assert_allclose(plan.bound_grid, sp.bound_grid,
                                   rtol=1e-12)
        assert plan.schedule.n_o == pytest.approx(sp.schedule.n_o,
                                                  rel=1e-12)


def test_plan_batch_accepts_scenario_list_and_shared_grid():
    scs = _mixed_scenarios()[:2]
    shared = np.array([1, 8, 64, 512], np.int64)
    fp = FleetPlanner().plan_batch(scs, CONSTS, grid=shared)
    assert fp.grid.shape == (2, 4)
    for i, sc in enumerate(scs):
        sp = BoundPlanner(grid=shared).plan(sc, CONSTS)
        assert int(fp.n_c[i]) == sp.n_c and float(fp.rate[i]) == sp.rate


def test_bounds_jax_port_matches_numpy_reference():
    """The jnp port agrees with the numpy evaluator on a broadcast grid
    including negative effective overheads and both regimes."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    rng = np.random.default_rng(3)
    n_c = np.maximum(rng.uniform(1, 3e4, (5, 64)), 1.0)
    n_o = rng.uniform(0.0, 2000.0, (5, 64))
    neg = rng.random((5, 64)) < 0.15
    # negative EFFECTIVE overheads (rate > 1 links) keep dur = n_c + n_o > 0
    n_o[neg] = -rng.uniform(0.0, 0.9, neg.sum()) * n_c[neg]
    for consts in (CONSTS,
                   BoundConstants(L=1.908, c=2000.0, M=1.0, M_G=1.0,
                                  D=1.0, alpha=1e-3),      # contraction == 0
                   BoundConstants(L=0.5, c=1e-9, M=1.0, M_G=1.0,
                                  D=2.0, alpha=1e-6)):     # contraction ~ 1
        ref = corollary1_bound(n_c, N=18576, T=1.5 * 18576, n_o=n_o,
                               tau_p=1.0, consts=consts)
        with enable_x64():
            got = np.asarray(corollary1_bound_jax(
                jnp.asarray(n_c), N=18576.0, T=1.5 * 18576, n_o=jnp.asarray(n_o),
                tau_p=1.0, sigma=consts.variance_floor, e0=consts.init_gap,
                contraction=consts.contraction))
        # 1e-10: the contraction ~ 1 - 1e-15 extreme sits right at the
        # geom-sum tie threshold where 1 - r^k cancels in both paths
        np.testing.assert_allclose(got, ref, rtol=1e-10, atol=0.0)


# ---------------------------------------------------------------------------
# hypothesis property: plan_batch == scalar BoundPlanner loop
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _rate_sets = st.sets(st.sampled_from(RATES5), min_size=1).map(
        lambda s: tuple(sorted(s)))

    @st.composite
    def _link(draw):
        """Draw a link from EVERY registered channel family."""
        rates = draw(_rate_sets)
        kind = draw(st.sampled_from(["ideal", "erasure", "fading", "ge"]))
        if kind == "erasure":
            return ErasureLink(beta=draw(st.floats(0.0, 2.0)),
                               p_base=draw(st.floats(0.0, 0.9)),
                               rates=rates)
        if kind == "fading":
            return FadingLink(snr=draw(st.floats(0.5, 100.0)), rates=rates)
        if kind == "ge":
            return GilbertElliottLink(
                p_gb=draw(st.floats(0.01, 1.0)),
                p_bg=draw(st.floats(0.01, 1.0)),
                p_good=draw(st.floats(0.0, 0.9)),
                p_bad=draw(st.floats(0.0, 0.9)),
                beta=draw(st.floats(0.0, 2.0)), rates=rates)
        return IdealLink(rates=rates)

    @st.composite
    def _scenario(draw):
        N = draw(st.integers(32, 30000))
        T = draw(st.floats(0.4, 3.0)) * N
        n_o = draw(st.floats(0.0, 2000.0))
        tau_p = draw(st.sampled_from([0.5, 1.0, 2.0]))
        D = draw(st.integers(1, 8))
        return Scenario(N=N, T=T, n_o=n_o, tau_p=tau_p, link=draw(_link()),
                        topology=MultiDevice(D) if D > 1 else SingleDevice())

    @settings(max_examples=15, deadline=None)
    @given(scs=st.lists(_scenario(), min_size=1, max_size=6))
    def test_plan_batch_property_matches_scalar_loop(scs):
        """ISSUE acceptance: FleetPlanner.plan_batch agrees with a scalar
        BoundPlanner loop on randomly drawn heterogeneous scenarios from
        ALL registered link models (payload, rate, and bound value within
        tolerance)."""
        G = 24
        planner = FleetPlanner(grid_size=G)
        records = planner.plan_many(scs, CONSTS)   # pads to pow2 internally
        assert len(records) == len(scs)
        for sc, rec in zip(scs, records):
            _assert_record_matches_scalar(sc, rec.n_c, rec.rate,
                                          rec.bound_value, CONSTS, G)


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------


def _sc(T=2048.0 * 1.5, n_o=100.0, **kw):
    return Scenario(N=2048, T=T, n_o=n_o, **kw)


def test_cache_quantised_key_collapses_jitter():
    a, b = _sc(T=3072.0), _sc(T=3072.0 * (1 + 1e-5))   # sub-quantisation
    c = _sc(T=3400.0)                                  # distinct
    assert scenario_key(a) == scenario_key(b)
    assert scenario_key(a) != scenario_key(c)
    # link params are part of the key
    assert scenario_key(_sc(link=ErasureLink(beta=0.4))) != \
        scenario_key(_sc(link=ErasureLink(beta=0.5)))
    assert scenario_key(_sc()) != scenario_key(_sc(link=ErasureLink()))


def test_cache_key_separates_link_model_families():
    """The (model_id, params) link signature keeps every registered family
    apart even when the packed parameter values coincide — mixed-model
    request streams can never alias across channel physics."""
    keys = [scenario_key(_sc(link=link)) for link in (
        IdealLink(rates=RATES5),
        ErasureLink(beta=0.25, p_base=0.0, rates=RATES5),
        FadingLink(snr=0.25, rates=RATES5),      # param collides with beta
        GilbertElliottLink(p_gb=0.25, p_bg=0.5, rates=RATES5),
    )]
    assert len(set(keys)) == len(keys)
    # same family, same physics, different quantised params -> distinct
    assert scenario_key(_sc(link=FadingLink(snr=8.0))) != \
        scenario_key(_sc(link=FadingLink(snr=12.0)))
    # unregistered links raise instead of silently aliasing by class name
    class Unregistered:
        rates = RATES5
    with pytest.raises(KeyError):
        scenario_key(_sc(link=Unregistered()))


def test_cache_lru_eviction_and_counters():
    cache = PlanCache(maxsize=2)
    s1, s2, s3 = _sc(n_o=1.0), _sc(n_o=2.0), _sc(n_o=3.0)
    assert cache.get(s1) is None and cache.misses == 1
    cache.put(s1, "r1")
    cache.put(s2, "r2")
    assert cache.get(s1) == "r1"            # s1 now most-recent
    cache.put(s3, "r3")                     # evicts s2 (LRU)
    assert cache.get(s2) is None
    assert cache.get(s3) == "r3"
    assert len(cache) == 2
    assert cache.hits == 2 and cache.misses == 2
    assert cache.hit_rate == pytest.approx(0.5)
    cache.clear()
    assert len(cache) == 0 and cache.hits == 0 and cache.misses == 0
    with pytest.raises(ValueError):
        PlanCache(maxsize=0)


def test_plan_many_cache_dedupes_and_replays():
    planner = FleetPlanner(grid_size=24)
    cache = PlanCache(maxsize=64)
    scs = _mixed_scenarios()
    # duplicate every scenario (with sub-quantisation jitter on T)
    stream = scs + [Scenario(N=s.N, T=s.T * (1 + 1e-6), n_o=s.n_o,
                             tau_p=s.tau_p, link=s.link, topology=s.topology)
                    for s in scs]
    recs = planner.plan_many(stream, CONSTS, cache=cache)
    assert len(recs) == len(stream)
    # the jittered duplicates were answered by the first solve
    for i, s in enumerate(scs):
        assert recs[i] == recs[len(scs) + i]
    assert len(cache) == len(scs)
    # a replay is served entirely from cache
    before = cache.hits
    again = planner.plan_many(stream, CONSTS, cache=cache)
    assert again == recs
    assert cache.hits == before + len(stream)
    # and matches the uncached batched solve
    uncached = planner.plan_many(scs, CONSTS)
    assert uncached == recs[:len(scs)]


def test_plan_many_empty():
    assert FleetPlanner().plan_many([], CONSTS) == []


def test_cache_scoped_by_consts_and_grid():
    """A shared cache must never serve a plan optimised under different
    bound constants or a different grid resolution (regression: records
    used to be keyed on the scenario alone)."""
    cache = PlanCache(maxsize=64)
    sc = Scenario(N=4096, T=1.3 * 4096, n_o=300.0,
                  link=ErasureLink(beta=0.4, rates=RATES5))
    other = BoundConstants(L=1.908, c=0.061, M=5.0, M_G=2.0, D=3.0,
                           alpha=5e-4)
    rec_a = FleetPlanner(grid_size=24).plan_many([sc], CONSTS, cache=cache)[0]
    rec_b = FleetPlanner(grid_size=24).plan_many([sc], other, cache=cache)[0]
    rec_c = FleetPlanner(grid_size=48).plan_many([sc], CONSTS, cache=cache)[0]
    assert rec_b.bound_value != rec_a.bound_value   # different constants
    assert len(cache) == 3                          # three scoped entries
    # each configuration replays from its own entry
    assert FleetPlanner(grid_size=24).plan_many([sc], CONSTS,
                                                cache=cache)[0] == rec_a
    assert FleetPlanner(grid_size=48).plan_many([sc], CONSTS,
                                                cache=cache)[0] == rec_c
    # and every record matches its own scalar solve
    _assert_record_matches_scalar(sc, rec_b.n_c, rec_b.rate,
                                  rec_b.bound_value, other, 24)


def test_plan_many_pad_to_fixed_shape():
    scs = _mixed_scenarios()[:3]
    recs = FleetPlanner(grid_size=16).plan_many(scs, CONSTS, pad_to=8)
    assert len(recs) == 3
    assert recs == FleetPlanner(grid_size=16).plan_many(scs, CONSTS)
    with pytest.raises(ValueError):
        FleetPlanner(grid_size=16).plan_many(scs, CONSTS, pad_to=2)


def test_pad_batch_repeats_smallest_scenario():
    """Padding repeats the batch's smallest-N scenario (for the simulated
    Monte-Carlo objective an arbitrary pad pick could fill the padding
    with the batch's most expensive training run), and padded/unpadded
    batches return identical records."""
    from repro.fleet.planner import _pad_batch

    scs = _mixed_scenarios()
    smallest = min(scs, key=lambda sc: sc.N)
    padded = _pad_batch(scs, pad_to=16)
    assert len(padded) == 16
    assert padded[:len(scs)] == scs
    assert all(sc == smallest for sc in padded[len(scs):])
    # shape-only padding: records are unaffected by pad_to
    planner = FleetPlanner(grid_size=16)
    for pad_to in (None, 16, 32):
        assert planner.plan_many(scs, CONSTS, pad_to=pad_to) == \
            planner.plan_many(scs, CONSTS)


def test_boundary_clamps_to_inf_at_deadline_equal_dataset():
    """Regression: the T == N, zero-effective-overhead corner must report
    the +inf regime boundary (matching the scalar ``boundary_n_c``), with
    no NaN leaking from the masked division."""
    from repro.core.protocol import boundary_n_c

    scs = [
        Scenario(N=64, T=64.0, n_o=0.0),                  # T == N, n_o == 0
        Scenario(N=128, T=128.0, n_o=5.0),                # T == N, n_o > 0
        Scenario(N=64, T=32.0, n_o=1.0),                  # T < N
        Scenario(N=64, T=640.0, n_o=3.0),                 # T > N (finite)
    ]
    fp = FleetPlanner(grid_size=16).plan_batch(scs, CONSTS)
    assert not np.isnan(fp.boundary).any()
    assert np.isinf(fp.boundary[:3]).all()
    assert np.isfinite(fp.boundary[3])
    for i, sc in enumerate(scs):
        want = boundary_n_c(sc.N, sc.T,
                            float(sc.effective_overhead(int(fp.n_c[i]),
                                                        float(fp.rate[i]))))
        assert fp.boundary[i] == want or \
            np.isclose(fp.boundary[i], want, rtol=1e-12)


# ---------------------------------------------------------------------------
# plan server
# ---------------------------------------------------------------------------


def test_serve_micro_batches_request_stream():
    requests = synth_requests(96, seed=5, dup_frac=0.5)
    assert len(requests) == 96
    cache = PlanCache(maxsize=256)
    stats = serve(requests, planner=FleetPlanner(grid_size=16),
                  consts=default_consts(), cache=cache, batch_size=32)
    assert stats.n_requests == 96 and stats.n_batches == 3
    assert len(stats.records) == 96
    assert stats.plans_per_sec > 0
    assert 0.0 < stats.cache_hit_rate < 1.0
    assert stats.requests_per_model == {ErasureLink.model_id: 96}
    for rec in stats.records:
        assert rec.n_c >= 1 and np.isfinite(rec.bound_value)
        assert rec.rate in RATES5
    with pytest.raises(ValueError):
        serve(requests, planner=FleetPlanner(), consts=default_consts(),
              batch_size=0)


def test_serve_mixed_model_stream_one_kernel():
    """A stream mixing EVERY registered channel family is served through
    the same micro-batch loop: each record matches its scalar solve and
    the per-model counts cover all four families."""
    requests = synth_requests(48, seed=7, dup_frac=0.3, models=ALL_MODELS)
    stats = serve(requests, planner=FleetPlanner(grid_size=16),
                  consts=default_consts(), cache=PlanCache(maxsize=256),
                  batch_size=16)
    assert len(stats.records) == 48
    assert sum(stats.requests_per_model.values()) == 48
    assert set(stats.requests_per_model) == {
        IdealLink.model_id, ErasureLink.model_id, FadingLink.model_id,
        GilbertElliottLink.model_id}
    for sc, rec in zip(requests, stats.records):
        _assert_record_matches_scalar(sc, rec.n_c, rec.rate,
                                      rec.bound_value, default_consts(), 16)


def test_serve_empty_and_fully_cached_streams_report_finite_stats():
    """Regression: hit-rate / throughput reporting must stay finite (no
    0/0 NaN) on an empty stream, and a fully-cached replay reports a 1.0
    PER-STREAM hit rate (counter deltas, not cache lifetime totals)."""
    planner = FleetPlanner(grid_size=16)
    cache = PlanCache(maxsize=64)
    empty = serve([], planner=planner, consts=default_consts(), cache=cache,
                  batch_size=8)
    assert empty.n_requests == 0 and empty.n_batches == 0
    assert empty.records == [] and empty.requests_per_model == {}
    assert empty.cache_hit_rate == 0.0 and np.isfinite(empty.plans_per_sec)
    # no-cache path is equally well-defined on an empty stream
    nocache = serve([], planner=planner, consts=default_consts(), cache=None)
    assert nocache.cache_hit_rate == 0.0

    requests = synth_requests(24, seed=9, dup_frac=0.0, models=ALL_MODELS)
    first = serve(requests, planner=planner, consts=default_consts(),
                  cache=cache, batch_size=8)
    replay = serve(requests, planner=planner, consts=default_consts(),
                   cache=cache, batch_size=8)
    assert replay.cache_hit_rate == 1.0      # lifetime rate would be ~0.5
    assert [r.n_c for r in replay.records] == [r.n_c for r in first.records]


# ---------------------------------------------------------------------------
# registry plugin: a custom channel goes end-to-end in ~30 lines
# ---------------------------------------------------------------------------


def test_custom_link_model_plugs_into_scalar_and_fleet_paths():
    """ISSUE tentpole: registering (numpy model + jax kernel) is ALL a new
    channel needs — ScenarioBatch packs it, the jitted kernel dispatches to
    it via lax.switch next to the built-ins, the cache keys it, and the
    batched plan matches the scalar BoundPlanner."""
    import jax.numpy as jnp
    from dataclasses import dataclass
    from typing import ClassVar, Tuple

    from repro.core.links import (P_ERR_MAX, register_link_model,
                                  unregister_link_model, _validate_rates)
    from repro.fleet import register_link_kernel, unregister_link_kernel

    @dataclass(frozen=True)
    class LinearLossLink:
        """Toy channel: p_err grows linearly with rate."""

        model_id: ClassVar[int] = 4
        N_PARAMS: ClassVar[int] = 1

        slope: float = 0.1
        rates: Tuple[float, ...] = RATES5

        def __post_init__(self):
            _validate_rates(self.rates)

        def p_err(self, rate):
            return np.minimum(self.slope * np.asarray(rate, np.float64),
                              P_ERR_MAX)

        def expected_block_time(self, n_c, n_o, rate):
            raw = np.asarray(n_c, np.float64) / rate + n_o
            return raw / (1.0 - self.p_err(rate))

        def pack_params(self):
            return np.asarray([self.slope], np.float64)

        @classmethod
        def from_params(cls, params, rates):
            return cls(slope=float(params[0]), rates=tuple(rates))

        def make_loss_process(self, rate, rng):
            p = float(self.p_err(rate))
            return lambda: bool(rng.random() < p)

    register_link_model(LinearLossLink)
    register_link_kernel(LinearLossLink.model_id, lambda params, rate:
                         jnp.minimum(params[..., 0] * rate, P_ERR_MAX))
    try:
        scs = _mixed_scenarios() + [
            Scenario(N=6000, T=1.4 * 6000, n_o=250.0,
                     link=LinearLossLink(slope=0.12, rates=RATES5))]
        batch = ScenarioBatch.from_scenarios(scs)
        assert int(batch.link_model_id[-1]) == 4
        assert batch[len(scs) - 1] == scs[-1]            # lossless round-trip
        assert scenario_key(scs[-1]) != scenario_key(scs[0])
        G = 24
        fp = FleetPlanner(grid_size=G).plan_batch(batch, CONSTS)
        for i, sc in enumerate(scs):                     # plugin AND built-ins
            _assert_record_matches_scalar(
                sc, int(fp.n_c[i]), float(fp.rate[i]),
                float(fp.bound_value[i]), CONSTS, G)
    finally:
        unregister_link_kernel(LinearLossLink.model_id)
        unregister_link_model(LinearLossLink.model_id)


# ---------------------------------------------------------------------------
# sharding across (forced) multiple host devices
# ---------------------------------------------------------------------------


_SHARD_SCRIPT = """
import numpy as np, jax
assert jax.device_count() == 4, jax.devices()
from repro.core import BoundConstants
from repro.fleet import FleetPlanner, ScenarioBatch
from repro.launch.plan_server import ALL_MODELS, default_consts, synth_requests
scs = synth_requests(8, seed=3, dup_frac=0.0, models=ALL_MODELS)
batch = ScenarioBatch.from_scenarios(scs)
sharded = FleetPlanner(grid_size=16, shard=True).plan_batch(batch, default_consts())
local = FleetPlanner(grid_size=16, shard=False).plan_batch(batch, default_consts())
np.testing.assert_array_equal(sharded.n_c, local.n_c)
np.testing.assert_array_equal(sharded.rate, local.rate)
np.testing.assert_array_equal(sharded.bound_value, local.bound_value)
print("SHARDED-OK")
"""


def _run_forced_device_script(script: str, marker: str):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=300, cwd=repo)
    assert out.returncode == 0, out.stderr
    assert marker in out.stdout


def test_plan_batch_sharded_matches_unsharded():
    """NamedSharding over 4 forced host devices returns bitwise-identical
    plans (separate process: the device-count flag must precede jax init)."""
    _run_forced_device_script(_SHARD_SCRIPT, "SHARDED-OK")


_MC_SHARD_SCRIPT = """
import numpy as np, jax
assert jax.device_count() == 4, jax.devices()
from repro.core.objectives import MonteCarloObjective
from repro.core.scenario import ErasureLink, Scenario
from repro.fleet import FleetPlanner, ScenarioBatch
from repro.launch.plan_server import default_consts
rng = np.random.default_rng(0)
X = rng.normal(size=(48, 4))
y = X @ rng.normal(size=4) + 0.1 * rng.normal(size=48)
mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0)
scs = [Scenario(N=int(n), T=1.3 * n, n_o=float(o), tau_p=1.0,
                link=ErasureLink(beta=0.4, p_base=0.05, rates=(1.0, 2.0)))
       for n, o in zip((256, 384, 512, 320, 288, 448, 352, 400),
                       (20, 90, 45, 150, 60, 10, 120, 75))]
batch = ScenarioBatch.from_scenarios(scs)   # S = 8 divides 4 devices
consts = default_consts()
sharded = FleetPlanner(grid_size=8, shard=True).plan_batch(
    batch, consts, objective=mc)
local = FleetPlanner(grid_size=8, shard=False).plan_batch(
    batch, consts, objective=mc)
np.testing.assert_array_equal(sharded.n_c, local.n_c)
np.testing.assert_array_equal(sharded.rate, local.rate)
np.testing.assert_allclose(sharded.bound_value, local.bound_value,
                           rtol=1e-7, atol=0.0)
print("MC-SHARDED-OK")
"""


def test_montecarlo_sharded_kernel_matches_unsharded():
    """ISSUE tentpole: the Monte-Carlo objective kernel lays its
    (S, R, G) simulation-lane axis over the forced 4-device mesh
    (scenario-sharded inputs + lane-axis sharding constraint) and its
    plans match the unsharded kernel argmin-exactly."""
    _run_forced_device_script(_MC_SHARD_SCRIPT, "MC-SHARDED-OK")
