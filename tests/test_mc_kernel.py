"""The Monte-Carlo simulation engines (ISSUE: Pallas kernel + CRN +
seed schedules).

Pins the three-engine contract of the fleet Monte-Carlo solve:

  * the Pallas slab kernel (interpret mode on CPU) against the numpy
    oracle and BITWISE against the ``lax.scan`` engines, for both the
    exact-RNG and the common-random-numbers update forms;
  * the fold_in / legacy per-run seed streams — fleet-vs-scalar
    seed-for-seed parity for both, the legacy collision regression, and
    the CRN-off path staying scalar-identical;
  * the seed schedules: the ``mc_seeds`` static override, the
    multi-level ``coarse_strides`` refine path (stage-for-stage equal to
    a hand-rolled schedule), its AOT warmup (zero post-warmup traces),
    and the cache keys that keep every estimator variant apart.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (BoundConstants, ErasureLink, MonteCarloObjective,
                        MonteCarloPlanner, Scenario)
from repro.core.pipeline import mc_run_key
from repro.core.planner import coarse_indices, fleet_grid, refine_grid
from repro.fleet import FleetPlanner, ScenarioBatch, objective_token
from repro.fleet.objective_kernels import fleet_solve
from repro.fleet.tracing import trace_delta
from repro.kernels import mc_ridge_slab
from repro.kernels.ref import mc_ridge_ref

CONSTS = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=1.0, alpha=1e-4)


def _ridge_data(n=48, d=4, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = X @ rng.normal(size=d) + 0.1 * rng.normal(size=n)
    return X, y


def _scenarios(n=3):
    link = ErasureLink(beta=0.4, p_base=0.05, rates=(1.0, 2.0))
    return [Scenario(N=int(N), T=1.3 * N, n_o=float(o), tau_p=2.0,
                     link=link)
            for N, o in zip((256, 384, 512, 320), (20.0, 90.0, 45.0, 60.0))
            ][:n]


def _plan(objective, scs, grid, mc_impl="scan", **planner_kw):
    pl = FleetPlanner(grid_size=8, mc_impl=mc_impl, **planner_kw)
    return pl.plan_batch(ScenarioBatch.from_scenarios(scs), CONSTS,
                         grid=np.asarray(grid), objective=objective)


# ---------------------------------------------------------------------------
# Pallas slab kernel vs the numpy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [False, True])
def test_mc_ridge_slab_matches_numpy_ref(fused):
    """Interpret-mode kernel vs the sequential numpy oracle, both update
    forms, including a padded (L not a block multiple) lane count."""
    rng = np.random.default_rng(3)
    L, d, n, slab = 21, 4, 16, 12
    W = rng.normal(size=(L, d)).astype(np.float32)
    Xs = rng.normal(size=(n, d)).astype(np.float32)
    ys = rng.normal(size=n).astype(np.float32)
    ix = rng.integers(0, n, size=(slab, L)).astype(np.int32)
    m = (rng.random(size=(slab, L)) < 0.7).astype(np.float32)
    out = mc_ridge_slab(W, Xs, ys, ix, m, alpha=1e-3, lam=0.1,
                        fused=fused, interpret=True)
    ref = mc_ridge_ref(W, Xs, ys, ix, m, alpha=1e-3, lam=0.1, fused=fused)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-6)


def test_mc_ridge_slab_dead_lane_passthrough():
    """An all-masked lane's weights come back bitwise-unchanged (what
    makes zero-padded lanes safe)."""
    rng = np.random.default_rng(4)
    L, d, n, slab = 5, 4, 8, 6
    W = rng.normal(size=(L, d)).astype(np.float32)
    Xs = rng.normal(size=(n, d)).astype(np.float32)
    ys = rng.normal(size=n).astype(np.float32)
    ix = rng.integers(0, n, size=(slab, L)).astype(np.int32)
    m = np.ones((slab, L), np.float32)
    m[:, 2] = 0.0
    for fused in (False, True):
        out = np.asarray(mc_ridge_slab(W, Xs, ys, ix, m, alpha=1e-3,
                                       lam=0.1, fused=fused,
                                       interpret=True))
        np.testing.assert_array_equal(out[2], W[2])


# ---------------------------------------------------------------------------
# engine equivalence: pallas (interpret) bitwise == lax.scan
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("crn", [False, True])
def test_pallas_engine_bitwise_matches_scan(crn):
    """The ``mc_impl="pallas"`` fleet solve returns BITWISE the scan
    engine's plans — exact-RNG and CRN forms both (the shared host-side
    tables + one-hot MXU gather make the kernel exact, not approximate)."""
    X, y = _ridge_data()
    mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0,
                             crn=crn)
    scs = _scenarios()
    grid = [1, 4, 16, 64]
    scan = _plan(mc, scs, grid, mc_impl="scan")
    pallas = _plan(mc, scs, grid, mc_impl="pallas")
    np.testing.assert_array_equal(np.asarray(scan.n_c),
                                  np.asarray(pallas.n_c))
    np.testing.assert_array_equal(np.asarray(scan.rate),
                                  np.asarray(pallas.rate))
    np.testing.assert_array_equal(np.asarray(scan.bound_value),
                                  np.asarray(pallas.bound_value))
    np.testing.assert_array_equal(np.asarray(scan.bound_grid),
                                  np.asarray(pallas.bound_grid))


# ---------------------------------------------------------------------------
# seed streams: fleet == scalar seed-for-seed; legacy collision pin
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed_stream", ["fold_in", "legacy"])
def test_fleet_matches_scalar_seed_for_seed(seed_stream):
    """Batched MC planning matches the scalar planner seed-for-seed in
    BOTH stream modes — i.e. the CRN-off default stays scalar-identical
    and the legacy compat mode still reproduces the historical streams."""
    X, y = _ridge_data()
    mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=7,
                             seed_stream=seed_stream)
    scs = _scenarios()
    grid = [1, 4, 16, 64]
    fleet = _plan(mc, scs, grid)
    for i, sc in enumerate(scs):
        scalar = MonteCarloPlanner(X=X, y=y, lam=mc.lam, alpha=mc.alpha,
                                   n_runs=2, seed=7, grid=grid,
                                   seed_stream=seed_stream).plan(sc, CONSTS)
        assert int(fleet.n_c[i]) == scalar.n_c
        assert float(fleet.rate[i]) == scalar.rate
        assert np.isclose(float(fleet.bound_value[i]), scalar.bound_value,
                          rtol=1e-5)


def test_legacy_stream_collision_and_fold_in_fix():
    """Regression pin: the historical ``seed0 + 97 r`` streams ALIAS
    (seed 0 run 1 == seed 97 run 0) and stay bitwise-reproducible under
    ``seed_stream="legacy"``; the fold_in default is collision-free."""
    legacy_01 = mc_run_key(0, 1, "legacy")
    np.testing.assert_array_equal(np.asarray(legacy_01),
                                  np.asarray(jax.random.PRNGKey(97)))
    np.testing.assert_array_equal(np.asarray(legacy_01),
                                  np.asarray(mc_run_key(97, 0, "legacy")))
    fold_01 = np.asarray(mc_run_key(0, 1))
    assert not np.array_equal(fold_01, np.asarray(mc_run_key(97, 0)))
    assert not np.array_equal(fold_01, np.asarray(jax.random.PRNGKey(97)))
    with pytest.raises(ValueError):
        mc_run_key(0, 0, "bogus")


def test_objective_validates_stream_and_schedule_fields():
    X, y = _ridge_data(n=16, d=3)
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, seed_stream="bogus")
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, coarse_seeds=-1)
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, refine_rates=0)
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, coarse_strides=())
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, coarse_strides=(6, 12))  # ascending
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, coarse_strides=(12, 0))
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, fine_radius=0)
    with pytest.raises(ValueError):
        MonteCarloObjective(X=X, y=y, coarse_updates=0)
    ok = MonteCarloObjective(X=X, y=y, coarse_strides=[12, 4])
    assert ok.coarse_strides == (12, 4)  # normalised to a tuple


def test_estimator_variants_never_share_cache_tokens():
    """crn / seed_stream / seed+rate/stride schedules all key the cache:
    no estimator variant may alias a reference plan."""
    X, y = _ridge_data(n=16, d=3)
    base = MonteCarloObjective(X=X, y=y)
    variants = [
        MonteCarloObjective(X=X, y=y, crn=True),
        MonteCarloObjective(X=X, y=y, seed_stream="legacy"),
        MonteCarloObjective(X=X, y=y, coarse_seeds=1),
        MonteCarloObjective(X=X, y=y, refine_rates=1),
        MonteCarloObjective(X=X, y=y, coarse_strides=(12, 4)),
        MonteCarloObjective(X=X, y=y, fine_radius=10),
        MonteCarloObjective(X=X, y=y, coarse_updates=2048),
    ]
    tokens = [objective_token(o) for o in [base] + variants]
    assert len(set(tokens)) == len(tokens)


def test_cache_context_tags_non_default_engine():
    ctx_scan = FleetPlanner(mc_impl="scan").cache_context(CONSTS)
    ctx_pallas = FleetPlanner(mc_impl="pallas").cache_context(CONSTS)
    assert ctx_pallas[-2:] == ("mc_impl", "pallas")
    assert "mc_impl" not in ctx_scan


# ---------------------------------------------------------------------------
# seed schedules: mc_seeds override + the multi-level refine path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_mc_seeds_override_matches_fewer_runs():
    """The ``mc_seeds`` static override truncates the seed loop to a
    PREFIX of the fold_in streams: a 2-run objective solved with
    ``mc_seeds=1`` is bitwise a 1-run objective's solve."""
    X, y = _ridge_data()
    scs = _scenarios()
    batch = ScenarioBatch.from_scenarios(scs)
    grid = np.broadcast_to(np.asarray([1, 4, 16, 64]), (len(scs), 4))
    arrays = FleetPlanner._solve_arrays(batch, grid)
    mc2 = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0)
    mc1 = MonteCarloObjective(X=X, y=y, n_runs=1, alpha=1e-3, seed=0)
    out_sched = fleet_solve(mc2)(dict(arrays, mc_seeds=1), CONSTS, False,
                                 batch)
    out_1run = fleet_solve(mc1)(arrays, CONSTS, False, batch)
    np.testing.assert_array_equal(np.asarray(out_sched["bound_value"]),
                                  np.asarray(out_1run["bound_value"]))
    np.testing.assert_array_equal(np.asarray(out_sched["n_c"]),
                                  np.asarray(out_1run["n_c"]))


@pytest.mark.slow
@pytest.mark.parametrize("hints", [{}, dict(fine_radius=6,
                                            coarse_updates=8)])
def test_multi_level_refine_matches_hand_rolled_schedule(hints):
    """The ``coarse_strides`` planner path IS the documented schedule:
    stage-for-stage equal to a hand-rolled stage0 -> rate-prune ->
    mid-stage -> fine-window sequence over the same solve.  The hinted
    variant adds the horizon schedule (``mc_updates`` cap on the coarse
    stages only, never the fine pass) and the decoupled fine-window
    radius."""
    X, y = _ridge_data()
    fast = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0,
                               grid_points=48, crn=True, coarse_seeds=1,
                               refine_rates=1, coarse_strides=(12, 4),
                               **hints)
    scs = _scenarios()
    batch = ScenarioBatch.from_scenarios(scs)
    G = 48
    grids = fleet_grid(batch.N, G)
    planner = FleetPlanner(objective=fast, grid_size=G, grid_mode="refine",
                           pow2_refine_widths=True)
    plan = planner.plan_batch(batch, CONSTS, grid=grids)

    # hand-rolled reference over the same fleet solve
    solve = fleet_solve(fast)
    arrays = FleetPlanner._solve_arrays(batch, grids)
    s0, s1 = 12, 4
    hz = ({"mc_updates": hints["coarse_updates"]} if hints else {})
    cpos = coarse_indices(G, s0)
    out0 = solve(dict(arrays, grid=np.ascontiguousarray(grids[:, cpos]),
                      mc_seeds=1, **hz), CONSTS, False, batch)
    vpr = np.asarray(out0["val_per_rate"])
    sel = np.sort(np.argsort(vpr, axis=1, kind="stable")[:, :1], axis=1)
    centers = np.take_along_axis(
        cpos[np.asarray(out0["gi_per_rate"], np.int64)], sel, axis=1)
    rates = np.ascontiguousarray(
        np.take_along_axis(np.asarray(arrays["rates"]), sel, 1))
    rmask = np.ascontiguousarray(
        np.take_along_axis(np.asarray(arrays["rate_mask"]), sel, 1))
    offs = np.arange(-(s0 // s1), s0 // s1 + 1) * s1
    win = np.clip(centers[:, :, None] + offs, 0, G - 1)
    out1 = solve(dict(arrays,
                      grid=np.ascontiguousarray(np.take_along_axis(
                          grids[:, None, :], win, axis=2)),
                      rates=rates, rate_mask=rmask, mc_seeds=1, **hz),
                 CONSTS, False, batch)
    centers = np.take_along_axis(
        win, np.asarray(out1["gi_per_rate"], np.int64)[:, :, None],
        axis=2)[..., 0]
    fine = hints.get("fine_radius", s1)    # pow2ceil(2*6+1) == pow2ceil(
    _, win_grid, _ = refine_grid(grids, centers, fine, tail_start=None,
                                 width=16)  # 2*4+1) == 16 for both cases
    out2 = solve(dict(arrays, grid=np.ascontiguousarray(win_grid),
                      rates=rates, rate_mask=rmask), CONSTS, False, batch)
    np.testing.assert_array_equal(np.asarray(plan.n_c),
                                  np.asarray(out2["n_c"]))
    np.testing.assert_array_equal(np.asarray(plan.rate),
                                  np.asarray(out2["rate"]))
    np.testing.assert_array_equal(np.asarray(plan.bound_value),
                                  np.asarray(out2["bound_value"]))


@pytest.mark.slow
def test_coarse_horizon_cap_is_a_timeline_prefix():
    """``mc_updates`` at or above the padded horizon is a bitwise no-op;
    a real cap trains a strict PREFIX of the same CRN slot stream (the
    counter-based draws make the truncated timeline a prefix, not a
    different stream)."""
    X, y = _ridge_data()
    mc = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0,
                             crn=True)
    scs = _scenarios()
    batch = ScenarioBatch.from_scenarios(scs)
    grid = np.broadcast_to(np.asarray([1, 4, 16, 64]), (len(scs), 4))
    arrays = FleetPlanner._solve_arrays(batch, grid)
    solve = fleet_solve(mc)
    full = solve(dict(arrays), CONSTS, False, batch)
    nop = solve(dict(arrays, mc_updates=1 << 20), CONSTS, False, batch)
    np.testing.assert_array_equal(np.asarray(full["bound_grid"]),
                                  np.asarray(nop["bound_grid"]))
    capped = solve(dict(arrays, mc_updates=8), CONSTS, False, batch)
    assert not np.array_equal(np.asarray(full["bound_grid"]),
                              np.asarray(capped["bound_grid"]))


@pytest.mark.slow
@pytest.mark.parametrize("hints", [{}, dict(fine_radius=6,
                                            coarse_updates=8)])
def test_multi_level_warmup_is_exhaustive(hints):
    """AOT warmup covers every shape the multi-level schedule can hit —
    including the horizon-capped coarse stages and the widened fine
    window: zero post-warmup traces for a planned batch (the serving
    SLO).  The batch is a pow2 length — warmup pads to the pow2 / bucket
    signature exactly like the serving layer's request batches."""
    X, y = _ridge_data()
    fast = MonteCarloObjective(X=X, y=y, n_runs=2, alpha=1e-3, seed=0,
                               grid_points=48, crn=True, coarse_seeds=1,
                               refine_rates=1, coarse_strides=(12, 4),
                               **hints)
    scs = _scenarios(4)
    planner = FleetPlanner(objective=fast, grid_size=48,
                           grid_mode="refine", pow2_refine_widths=True)
    assert planner.warm(scs, CONSTS) > 0
    with trace_delta() as traces:
        plan = planner.plan_batch(scs, CONSTS)
    assert traces.total == 0
    assert np.all(np.asarray(plan.n_c) >= 1)
