"""Blockwise (XLA flash) attention vs the plain oracle, incl. every mask
variant the architectures use, plus MLA shape checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (causal_mask, dot_product_attention,
                                    window_mask, window_sink_mask)
from repro.models.blockwise import flash_attention


@pytest.fixture(scope="module")
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, Hkv, D = 2, 320, 8, 4, 32
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, Hkv, D)),
            jax.random.normal(ks[2], (B, S, Hkv, D)))


def _ref(q, k, v, mask, softcap=None):
    return dot_product_attention(q, k, v, mask=mask[None, None, None],
                                 logit_softcap=softcap)


def test_causal(qkv):
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    out = flash_attention(q, k, v, causal=True, q_block=64, k_block=64)
    np.testing.assert_allclose(out, _ref(q, k, v, causal_mask(pos, pos)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 160])
def test_window(qkv, window):
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_block=64, k_block=64)
    np.testing.assert_allclose(out, _ref(q, k, v, window_mask(pos, pos, window)),
                               rtol=2e-5, atol=2e-5)


def test_window_sink(qkv):
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    out = flash_attention(q, k, v, causal=True, window=64, sink=16,
                          q_block=64, k_block=64)
    np.testing.assert_allclose(
        out, _ref(q, k, v, window_sink_mask(pos, pos, 64, 16)),
        rtol=2e-5, atol=2e-5)


def test_softcap(qkv):
    q, k, v = qkv
    pos = jnp.arange(q.shape[1])
    out = flash_attention(q, k, v, causal=True, logit_softcap=50.0,
                          q_block=64, k_block=64)
    np.testing.assert_allclose(
        out, _ref(q, k, v, causal_mask(pos, pos), softcap=50.0),
        rtol=2e-5, atol=2e-5)


def test_gradients_flow(qkv):
    """Blockwise attention must be differentiable (it sits inside remat)."""
    q, k, v = qkv

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True,
                                       q_block=64, k_block=64) ** 2)

    def f_ref(q, k, v):
        pos = jnp.arange(q.shape[1])
        return jnp.sum(_ref(q, k, v, causal_mask(pos, pos)) ** 2)

    g = jax.grad(f)(q, k, v)
    gr = jax.grad(f_ref)(q, k, v)
    np.testing.assert_allclose(g, gr, rtol=5e-4, atol=5e-4)


def test_mla_attention_shapes():
    from repro.configs import get_config, reduced
    from repro.models.attention import init_mla, mla_attention
    cfg = reduced(get_config("minicpm3-4b"))
    p = init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    pos = jnp.arange(32)
    out, (latent, krope) = mla_attention(p, x, pos, cfg, mask=None)
    assert out.shape == x.shape
    assert latent.shape == (2, 32, cfg.mla.kv_lora_rank)
    assert krope.shape == (2, 32, cfg.mla.qk_rope_head_dim)
