"""Pipelined streaming-SGD trainer (paper Sec. 5) + streaming buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import ridge_loss_full, run_pipelined_sgd
from repro.core.streaming import make_buffer, receive_block, sample
from repro.data.synthetic import make_regression_dataset


@pytest.fixture(scope="module")
def dataset():
    return make_regression_dataset(n=4_096, d=8, seed=1)


def test_loss_decreases(dataset):
    X, y, _ = dataset
    r = run_pipelined_sgd(X, y, n_c=128, n_o=32.0, T=1.5 * len(X), alpha=1e-3)
    assert r.loss_trace[-1] < r.loss_trace[0] * 0.5
    assert np.isfinite(r.final_loss)


def test_pipelining_beats_sequential(dataset):
    """The paper's motivating claim: block streaming (pipelined) beats
    transmitting the entire dataset first (n_c = N, one overhead)."""
    X, y, _ = dataset
    n = len(X)
    piped = run_pipelined_sgd(X, y, n_c=256, n_o=200.0, T=1.5 * n, alpha=1e-3)
    seq = run_pipelined_sgd(X, y, n_c=n, n_o=200.0, T=1.5 * n, alpha=1e-3)
    assert piped.final_loss < seq.final_loss


def test_delivered_counts(dataset):
    X, y, _ = dataset
    n = len(X)
    r = run_pipelined_sgd(X, y, n_c=256, n_o=8.0, T=1.5 * n)
    assert r.delivered == n  # small overhead: everything arrives
    r2 = run_pipelined_sgd(X, y, n_c=64, n_o=1000.0, T=0.5 * n)
    assert r2.delivered < n


def test_reproducible(dataset):
    X, y, _ = dataset
    a = run_pipelined_sgd(X, y, n_c=128, n_o=16.0, T=1.2 * len(X), seed=7)
    b = run_pipelined_sgd(X, y, n_c=128, n_o=16.0, T=1.2 * len(X), seed=7)
    assert a.final_loss == b.final_loss
    np.testing.assert_array_equal(a.w_final, b.w_final)


def test_streaming_buffer_prefix():
    buf = make_buffer(10, (3,))
    xb = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    yb = jnp.arange(4, dtype=jnp.float32)
    buf = receive_block(buf, xb, yb)
    assert int(buf.available) == 4
    buf = receive_block(buf, xb + 100, yb + 100)
    assert int(buf.available) == 8
    np.testing.assert_array_equal(buf.x[:4], xb)
    np.testing.assert_array_equal(buf.x[4:8], xb + 100)
    # samples only come from the available prefix
    xs, ys = sample(buf, jax.random.PRNGKey(0), 64)
    assert float(jnp.max(ys)) <= 103.0
